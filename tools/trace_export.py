#!/usr/bin/env python
"""Export a flight-recorder event log to Chrome-trace / Perfetto JSON.

The flight recorder (``repro.core.telemetry.FlightRecorder``) serializes
its ring buffer to JSONL — one JSON event per line.  This tool converts
that event list to the Chrome Trace Event format (the ``traceEvents``
JSON that chrome://tracing and https://ui.perfetto.dev both open):

* ``span`` events become complete-duration events (``"ph": "X"``) with
  their ``ts``/``dur`` microsecond timestamps and any extra attributes
  under ``args``;
* ``round`` events become counter events (``"ph": "C"``) tracking the
  foreign-pick count, Eq.-7 score aggregates, and pool staleness per
  exchange round;
* ``mark`` events become instant events (``"ph": "i"``).

The exported JSON also carries the recorder's counter registry snapshot
under a top-level ``"metrics"`` key (trace viewers ignore unknown keys).

Stdlib-only on purpose: runnable anywhere, importable by tests / CI
assertions without a JAX install.

Usage:
    python tools/trace_export.py --in run.jsonl --out run.trace.json
    python tools/trace_export.py --in run.jsonl --validate
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterable, List, Optional

PID = 1
TID_SPANS = 1
TID_ROUNDS = 2


def load_jsonl(path) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _args_of(ev: dict, skip=("type", "name", "ts", "dur", "depth")) -> dict:
    return {k: v for k, v in ev.items() if k not in skip}


def chrome_trace(events: Iterable[dict],
                 metrics: Optional[Dict] = None) -> dict:
    """Convert flight-recorder events to a Chrome-trace JSON object."""
    out: List[dict] = []
    for ev in events:
        kind = ev.get("type")
        if kind == "span":
            out.append({"name": ev["name"], "ph": "X", "cat": "host",
                        "ts": ev["ts"], "dur": ev.get("dur", 0),
                        "pid": PID, "tid": TID_SPANS,
                        "args": _args_of(ev)})
        elif kind == "round":
            args = {k: ev[k] for k in ("foreign_picks", "self_keeps",
                                       "score_min", "score_mean",
                                       "age_mean", "age_max")
                    if ev.get(k) is not None}
            out.append({"name": "round", "ph": "C", "cat": "rounds",
                        "ts": ev.get("ts", 0), "pid": PID,
                        "tid": TID_ROUNDS, "args": args})
        elif kind == "mark":
            out.append({"name": ev["name"], "ph": "i", "cat": "host",
                        "ts": ev.get("ts", 0), "s": "g",
                        "pid": PID, "tid": TID_SPANS,
                        "args": _args_of(ev)})
    trace = {"traceEvents": out, "displayTimeUnit": "ms"}
    if metrics is not None:
        trace["metrics"] = dict(metrics)
    return trace


def validate_trace(trace: dict) -> None:
    """Raise ValueError unless ``trace`` is structurally valid Chrome-trace
    JSON: a traceEvents list whose entries carry the mandatory fields with
    sane types and non-negative timestamps."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace: missing traceEvents")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("trace: traceEvents must be a list")
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: not an object")
        for key, types in (("name", (str,)), ("ph", (str,)),
                           ("ts", (int, float)), ("pid", (int,)),
                           ("tid", (int,))):
            if key not in ev:
                raise ValueError(f"{where}: missing {key!r}")
            if not isinstance(ev[key], types):
                raise ValueError(f"{where}[{key!r}]: expected {types}, "
                                 f"got {type(ev[key]).__name__}")
        if ev["ts"] < 0:
            raise ValueError(f"{where}: negative ts {ev['ts']}")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                raise ValueError(f"{where}: X event needs dur >= 0")


def assert_spans_nest(trace_events: Iterable[dict]) -> None:
    """Raise ValueError if any two duration spans on the same (pid, tid)
    partially overlap — intervals must either be disjoint or properly
    contained, the flight recorder's single-threaded nesting invariant."""
    by_track: Dict[tuple, List[dict]] = {}
    for ev in trace_events:
        if ev.get("ph") == "X":
            by_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for track, spans in by_track.items():
        spans.sort(key=lambda e: (e["ts"], -e.get("dur", 0)))
        stack: List[dict] = []
        for ev in spans:
            end = ev["ts"] + ev.get("dur", 0)
            while stack and ev["ts"] >= stack[-1]["ts"] \
                    + stack[-1].get("dur", 0):
                stack.pop()
            if stack and end > stack[-1]["ts"] + stack[-1].get("dur", 0):
                raise ValueError(
                    f"track {track}: span {ev['name']!r} "
                    f"[{ev['ts']}, {end}) partially overlaps "
                    f"{stack[-1]['name']!r}")
            stack.append(ev)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--in", dest="inp", required=True,
                    help="flight-recorder JSONL event log")
    ap.add_argument("--out", default=None,
                    help="write Chrome-trace/Perfetto JSON here")
    ap.add_argument("--validate", action="store_true",
                    help="validate the converted trace (and span nesting)")
    args = ap.parse_args(argv)

    events = load_jsonl(args.inp)
    trace = chrome_trace(events)
    if args.validate or args.out:
        validate_trace(trace)
        assert_spans_nest(trace["traceEvents"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump(trace, f)
        print(f"wrote {len(trace['traceEvents'])} trace events -> "
              f"{args.out}")
    else:
        print(f"{len(events)} events, {len(trace['traceEvents'])} trace "
              f"events; valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
