#!/usr/bin/env python
"""Docs link check: every RELATIVE markdown link in README.md, docs/*.md and
examples/README.md must resolve to an existing file or directory, so the
docs can't rot silently as the tree moves.  External (http/mailto) links
and pure in-page anchors are skipped; `path#anchor` links are checked for
the path part only.

  python tools/check_links.py        # exits 1 and lists broken links
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_md_files():
    yield ROOT / "README.md"
    yield from sorted((ROOT / "docs").glob("*.md"))
    ex = ROOT / "examples" / "README.md"
    if ex.exists():
        yield ex


def check(md: Path) -> list:
    bad = []
    text = md.read_text()
    # strip fenced code blocks — command snippets aren't links
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            bad.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return bad


def main() -> int:
    broken = [b for md in iter_md_files() for b in check(md)]
    if broken:
        print("\n".join(broken))
        return 1
    n = len(list(iter_md_files()))
    print(f"docs link check OK ({n} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
