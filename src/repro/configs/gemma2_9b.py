"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Alternating local(4096)/global attention, attn logit softcap 50, final logit
softcap 30, GeGLU, sqrt(d) embedding scale.  [arXiv:2408.00118]
"""
from repro.configs.base import AttnConfig, LayerSpec, ModelConfig, Segment, register

_LOCAL = LayerSpec(mixer="attn_local", ffn="mlp")
_GLOBAL = LayerSpec(mixer="attn", ffn="mlp")


@register(name="gemma2-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense",
        vocab_size=256_000, d_model=3584, d_ff=14_336,
        segments=(Segment((_LOCAL, _GLOBAL), 21),),
        attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=256,
                        rope_theta=10_000.0, logit_softcap=50.0),
        act="gelu", tie_embeddings=True, final_softcap=30.0,
        local_window=4096, scale_embed=True,
        citation="arXiv:2408.00118",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke", family="dense",
        vocab_size=512, d_model=128, d_ff=256,
        segments=(Segment((_LOCAL, _GLOBAL), 1),),
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        logit_softcap=50.0),
        act="gelu", tie_embeddings=True, final_softcap=30.0,
        local_window=64, scale_embed=True,
    )
