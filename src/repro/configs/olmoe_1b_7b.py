"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (kv=16) d_ff=1024(expert) vocab=50304.

64 experts, top-8, softmax router, no shared experts, qk-norm.
[arXiv:2409.02060]
"""
from repro.configs.base import (AttnConfig, LayerSpec, MoEConfig, ModelConfig,
                                Segment, register)

_MOE = LayerSpec(mixer="attn", ffn="moe")


@register(name="olmoe-1b-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        vocab_size=50_304, d_model=2048, d_ff=1024,
        segments=(Segment((_MOE,), 16),),
        attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                        rope_theta=10_000.0, qk_norm=True),
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
        act="silu", tie_embeddings=False,
        citation="arXiv:2409.02060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe",
        vocab_size=512, d_model=128, d_ff=128,
        segments=(Segment((_MOE,), 2),),
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32, qk_norm=True),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        act="silu", tie_embeddings=False,
    )
