"""Architecture config system.

A :class:`ModelConfig` fully determines the decoder model: embedding,
a sequence of *segments* (a repeating pattern of layers, scanned), final norm
and output head(s).  Every assigned architecture gets one file in this package
with the exact published hyper-parameters (citation in the docstring) plus a
``smoke()`` reduced variant used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False              # qwen3
    logit_softcap: float = 0.0         # gemma2 (50.0)
    window: Optional[int] = None       # sliding-window size; None = global
    mla: Optional[MLAConfig] = None    # deepseek
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # Head-count padding for tensor parallelism (EXPERIMENTS §Perf iter D1):
    # head counts that don't divide the model axis leave attention fully
    # replicated.  Zero-padded heads are exactly inert (zero contribution
    # AND zero gradient — the wo rows are zero), so padding to a multiple of
    # the mesh restores 16-way sharding at the cost of the pad fraction of
    # extra (sharded) attention FLOPs.  Valid for MHA (pad q+kv together)
    # and MQA (kv=1; grouping is trivially preserved); unsupported for
    # grouped GQA where padding would change the q->kv mapping.
    n_heads_padded: Optional[int] = None
    n_kv_heads_padded: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    aux_loss_weight: float = 0.001
    capacity_factor: float = 1.25
    min_capacity: int = 4
    router_score: str = "softmax"      # softmax | sigmoid (deepseek-v3)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block [arXiv:2402.19427]."""
    width: int            # d_rnn (= d_model in recurrentgemma)
    n_heads: int          # block-diagonal gate heads
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM sLSTM/mLSTM blocks [arXiv:2405.04517]."""
    n_heads: int
    proj_factor_m: float = 2.0   # mLSTM up-projection factor
    proj_factor_s: float = 1.333  # sLSTM ffn factor
    conv_width: int = 4


# Mixer kinds: "attn" (global), "attn_local" (windowed), "rglru", "mlstm", "slstm"
# FFN kinds:   "mlp", "moe", "none"
@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str
    ffn: str


@dataclasses.dataclass(frozen=True)
class Segment:
    """`repeats` copies of `pattern`, executed as one lax.scan."""
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    vocab_size: int
    d_model: int
    d_ff: int
    segments: Tuple[Segment, ...]
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    act: str = "silu"               # silu (SwiGLU) | gelu (GeGLU) | gelu_plain
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    final_softcap: float = 0.0      # gemma2 final-logit softcap (30.0)
    n_codebooks: int = 1            # musicgen: 4
    vlm: bool = False               # consumes precomputed patch embeddings
    local_window: int = 4096        # window used by "attn_local" layers
    long_ctx_window: Optional[int] = 8192  # sliding-window override for long_500k
    mtp_depth: int = 0              # deepseek multi-token-prediction heads
    fsdp: bool = False              # use PARAM_RULES_FSDP
    scale_embed: bool = False       # gemma-style sqrt(d_model) embed scaling
    citation: str = ""

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        out = []
        for s in self.segments:
            out.extend(s.pattern * s.repeats)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(fullcfg_fn=None, *, smoke_fn=None, name=None):
    def deco(fn):
        _REGISTRY[name or fn.__module__.rsplit(".", 1)[-1].replace("_", "-")] = fn
        return fn
    if fullcfg_fn is not None:
        return deco(fullcfg_fn)
    return deco


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        qwen3_0_6b, deepseek_v3_671b, olmoe_1b_7b, recurrentgemma_2b,
        gemma2_9b, granite_3_2b, granite_3_8b, qwen2_vl_7b,
        musicgen_medium, xlstm_350m,
    )
    _LOADED = True


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    _ensure_loaded()
    mod_name = "repro.configs." + arch_id.replace("-", "_").replace(".", "_")
    import importlib
    mod = importlib.import_module(mod_name)
    return mod.smoke()
