"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

Decoder-only over EnCodec tokens, 4 codebooks (summed input embeddings, one
output head per codebook, delay-pattern handled by the data pipeline).  The
EnCodec conv frontend is a STUB.  RoPE replaces the original sinusoidal
embedding (TPU-idiomatic; noted in DESIGN.md).  [arXiv:2306.05284]
"""
from repro.configs.base import AttnConfig, LayerSpec, ModelConfig, Segment, register

_LAYER = LayerSpec(mixer="attn", ffn="mlp")


@register(name="musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        vocab_size=2048, d_model=1536, d_ff=6144,
        segments=(Segment((_LAYER,), 48),),
        attn=AttnConfig(n_heads=24, n_kv_heads=24, head_dim=64,
                        rope_theta=10_000.0,
                        # 24 heads don't divide the 16-wide model axis; pad
                        # with inert zero heads to restore attention TP
                        # (EXPERIMENTS §Perf iter D1)
                        n_heads_padded=32, n_kv_heads_padded=32),
        act="gelu_plain", tie_embeddings=False, n_codebooks=4,
        citation="arXiv:2306.05284",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        vocab_size=128, d_model=128, d_ff=256,
        segments=(Segment((_LAYER,), 2),),
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32),
        act="gelu_plain", tie_embeddings=False, n_codebooks=4,
    )
