"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.

Griffin pattern: (RG-LRU, RG-LRU, local-attn) x 8 + (RG-LRU, RG-LRU), local
window 2048, GeGLU, sqrt(d) embedding scale.  [arXiv:2402.19427]
"""
from repro.configs.base import (AttnConfig, LayerSpec, ModelConfig,
                                RGLRUConfig, Segment, register)

_RG = LayerSpec(mixer="rglru", ffn="mlp")
_LA = LayerSpec(mixer="attn_local", ffn="mlp")


@register(name="recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        vocab_size=256_000, d_model=2560, d_ff=7680,
        segments=(Segment((_RG, _RG, _LA), 8), Segment((_RG, _RG), 1)),
        attn=AttnConfig(n_heads=10, n_kv_heads=1, head_dim=256,
                        rope_theta=10_000.0,
                        # MQA: pad q heads to the mesh width with inert zero
                        # heads (grouping trivially preserved, kv stays 1)
                        n_heads_padded=16),
        rglru=RGLRUConfig(width=2560, n_heads=10, conv_width=4),
        act="gelu", tie_embeddings=True, local_window=2048,
        scale_embed=True,
        citation="arXiv:2402.19427",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        vocab_size=512, d_model=128, d_ff=256,
        segments=(Segment((_RG, _LA), 1),),
        attn=AttnConfig(n_heads=4, n_kv_heads=1, head_dim=32),
        rglru=RGLRUConfig(width=128, n_heads=4, conv_width=4),
        act="gelu", tie_embeddings=True, local_window=64, scale_embed=True,
    )
