"""xlstm-350m [ssm] — 24L d_model=1024 4H vocab=50304 d_ff=0.

xLSTM[7:1]: 7 mLSTM blocks per 1 sLSTM block; blocks carry their own up/down
projections so there is no separate FFN (d_ff=0).  [arXiv:2405.04517]
"""
from repro.configs.base import (LayerSpec, ModelConfig, Segment, XLSTMConfig,
                                register)

_M = LayerSpec(mixer="mlstm", ffn="none")
_S = LayerSpec(mixer="slstm", ffn="none")


@register(name="xlstm-350m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        vocab_size=50_304, d_model=1024, d_ff=0,
        segments=(Segment((_M, _M, _M, _M, _M, _M, _M, _S), 3),),
        attn=None,
        xlstm=XLSTMConfig(n_heads=4),
        act="gelu", tie_embeddings=True,
        citation="arXiv:2405.04517",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        vocab_size=512, d_model=128, d_ff=0,
        segments=(Segment((_M, _S), 1),),
        attn=None,
        xlstm=XLSTMConfig(n_heads=4),
        act="gelu", tie_embeddings=True,
    )
