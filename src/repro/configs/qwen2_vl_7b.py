"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (temporal/height/width sections 16/24/24), dynamic-resolution vision
frontend is a STUB (``input_specs`` supplies precomputed patch embeddings).
[arXiv:2409.12191]
"""
from repro.configs.base import AttnConfig, LayerSpec, ModelConfig, Segment, register

_LAYER = LayerSpec(mixer="attn", ffn="mlp")


@register(name="qwen2-vl-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        vocab_size=152_064, d_model=3584, d_ff=18_944,
        segments=(Segment((_LAYER,), 28),),
        attn=AttnConfig(n_heads=28, n_kv_heads=4, head_dim=128,
                        rope_theta=1_000_000.0, mrope_sections=(16, 24, 24)),
        act="silu", tie_embeddings=False, vlm=True,
        citation="arXiv:2409.12191",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2vl-smoke", family="vlm",
        vocab_size=512, d_model=128, d_ff=256,
        segments=(Segment((_LAYER,), 2),),
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        mrope_sections=(4, 6, 6)),
        act="silu", tie_embeddings=False, vlm=True,
    )
