from repro.configs.base import (  # noqa: F401
    AttnConfig, InputShape, INPUT_SHAPES, LayerSpec, MLAConfig, ModelConfig,
    MoEConfig, RGLRUConfig, Segment, XLSTMConfig, get_config, list_archs,
    smoke_config,
)
