"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.

qk_norm + GQA, head_dim=128 (decoupled from d_model/n_heads as in the Qwen3
family).  [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import AttnConfig, LayerSpec, ModelConfig, Segment, register

_LAYER = LayerSpec(mixer="attn", ffn="mlp")


@register(name="qwen3-0.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        vocab_size=151_936, d_model=1024, d_ff=3072,
        segments=(Segment((_LAYER,), 28),),
        attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128,
                        rope_theta=1_000_000.0, qk_norm=True),
        act="silu", tie_embeddings=True,
        citation="hf:Qwen/Qwen3-8B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        vocab_size=512, d_model=128, d_ff=256,
        segments=(Segment((_LAYER,), 2),),
        attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=32,
                        rope_theta=1_000_000.0, qk_norm=True),
        act="silu", tie_embeddings=True,
    )
