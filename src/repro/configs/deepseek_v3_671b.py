"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048(expert) vocab=129280.

MLA (q_lora 1536 / kv_lora 512 / rope 64), 1 shared + 256 routed experts
top-8 with sigmoid scoring, first 3 layers dense (d_ff 18432), MTP depth 1.
[arXiv:2412.19437]
"""
from repro.configs.base import (AttnConfig, LayerSpec, MLAConfig, MoEConfig,
                                ModelConfig, Segment, register)

_DENSE = LayerSpec(mixer="attn", ffn="mlp")
_MOE = LayerSpec(mixer="attn", ffn="moe")


@register(name="deepseek-v3-671b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        vocab_size=129_280, d_model=7168, d_ff=18_432,
        segments=(Segment((_DENSE,), 3), Segment((_MOE,), 58)),
        attn=AttnConfig(n_heads=128, n_kv_heads=128, head_dim=128,
                        rope_theta=10_000.0, mla=MLAConfig()),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                      n_shared_experts=1, router_score="sigmoid"),
        act="silu", tie_embeddings=False, mtp_depth=1, fsdp=True,
        citation="arXiv:2412.19437",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe",
        vocab_size=512, d_model=128, d_ff=256,
        segments=(Segment((_DENSE,), 1), Segment((_MOE,), 1)),
        attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=32,
                        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                                      v_head_dim=32)),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      n_shared_experts=1, router_score="sigmoid"),
        act="silu", tie_embeddings=False, mtp_depth=1,
    )
