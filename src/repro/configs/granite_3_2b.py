"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.

[hf:ibm-granite/granite-3.0-2b-base]
"""
from repro.configs.base import AttnConfig, LayerSpec, ModelConfig, Segment, register

_LAYER = LayerSpec(mixer="attn", ffn="mlp")


@register(name="granite-3-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        vocab_size=49_155, d_model=2048, d_ff=8192,
        segments=(Segment((_LAYER,), 40),),
        attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=64,
                        rope_theta=10_000.0),
        act="silu", tie_embeddings=True,
        citation="hf:ibm-granite/granite-3.0-2b-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite2b-smoke", family="dense",
        vocab_size=512, d_model=128, d_ff=256,
        segments=(Segment((_LAYER,), 2),),
        attn=AttnConfig(n_heads=8, n_kv_heads=2, head_dim=16),
        act="silu", tie_embeddings=True,
    )
