"""Msgpack pytree checkpointing with save-best support (paper §5.2).

Layout: <dir>/<name>.msgpack holds {tree: nested lists/dicts of tensor
descriptors, arrays: concatenated raw buffers}.  Works for any pytree of jax
or numpy arrays + scalars; device arrays are gathered to host first.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Optional

import jax
import msgpack
import numpy as np

_SENTINEL = "__tensor__"


def _encode(tree):
    buffers = []

    def enc(node):
        if isinstance(node, (jax.Array, np.ndarray, np.generic)):
            arr = np.asarray(node)
            buffers.append(arr.tobytes())
            return {_SENTINEL: len(buffers) - 1, "dtype": str(arr.dtype),
                    "shape": list(arr.shape)}
        if isinstance(node, dict):
            return {"d": {k: enc(v) for k, v in node.items()}}
        if isinstance(node, (list, tuple)):
            return {"l" if isinstance(node, list) else "t":
                    [enc(v) for v in node]}
        if node is None or isinstance(node, (bool, int, float, str)):
            return {"v": node}
        raise TypeError(f"cannot checkpoint {type(node)}")

    return enc(tree), buffers


def _decode(node, buffers):
    if _SENTINEL in node:
        arr = np.frombuffer(buffers[node[_SENTINEL]],
                            dtype=np.dtype(node["dtype"]))
        return arr.reshape(node["shape"]).copy()
    if "d" in node:
        return {k: _decode(v, buffers) for k, v in node["d"].items()}
    if "l" in node:
        return [_decode(v, buffers) for v in node["l"]]
    if "t" in node:
        return tuple(_decode(v, buffers) for v in node["t"])
    return node["v"]


def save(path, tree) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tree = jax.tree_util.tree_map(lambda x: x, tree)  # shallow copy
    enc, buffers = _encode(jax.device_get(tree))
    payload = msgpack.packb({"tree": enc, "buffers": buffers},
                            use_bin_type=True)
    tmp = path.with_suffix(".tmp")
    tmp.write_bytes(payload)
    os.replace(tmp, path)


def load(path) -> Any:
    payload = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    return _decode(payload["tree"], payload["buffers"])


class CheckpointManager:
    """Step checkpoints + the paper's save-best-on-validation policy."""

    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.best_metric = float("inf")

    def save_step(self, step: int, tree) -> Path:
        p = self.dir / f"step_{step:08d}.msgpack"
        save(p, tree)
        ckpts = sorted(self.dir.glob("step_*.msgpack"))
        for old in ckpts[:-self.keep]:
            old.unlink()
        return p

    def save_best(self, metric: float, tree) -> bool:
        if metric < self.best_metric:
            self.best_metric = metric
            save(self.dir / "best.msgpack", tree)
            (self.dir / "best.json").write_text(
                json.dumps({"metric": metric}))
            return True
        return False

    def latest(self) -> Optional[Any]:
        ckpts = sorted(self.dir.glob("step_*.msgpack"))
        return load(ckpts[-1]) if ckpts else None

    def best(self) -> Optional[Any]:
        p = self.dir / "best.msgpack"
        return load(p) if p.exists() else None
