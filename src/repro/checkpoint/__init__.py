"""Msgpack pytree checkpointing (see checkpoint.py).  Re-exported here so
consumers — notably Federation.save/restore — can use the package name."""
from repro.checkpoint.checkpoint import CheckpointManager, load, save

__all__ = ["CheckpointManager", "load", "save"]
