"""Chunkwise-parallel mLSTM Pallas-TPU kernel [arXiv:2405.04517].

Grid = (B*H, S/CHUNK); the chunk axis is sequential per core, carrying the
stabilized (C, n, m) inter-chunk state in VMEM scratch.  Within a chunk the
recurrence is evaluated in closed form: an intra-chunk gated attention matrix
(CHUNK x CHUNK, MXU matmuls) plus a rank-`dh` contribution from the carried
matrix memory — the TPU-native replacement for a CUDA scan over time.

Math (matches the sequential oracle exactly):
    lf = logsigmoid(f~),  b_t = cumsum(lf)  (inclusive, within chunk)
    m_t   = max(m_prev + b_t, max_{s<=t}(b_t - b_s + li_s))
    w_ts  = exp(b_t - b_s + li_s - m_t)          (s <= t, else 0)
    coef_t = exp(m_prev + b_t - m_t)
    num_t = coef_t (q_t C_prev) + sum_s w_ts (q_t.k_s) v_s
    den_t = max(|coef_t (q_t.n_prev) + sum_s w_ts (q_t.k_s)|, exp(-m_t))
    h_t   = num_t / den_t
    chunk-end state update with the same weights at t = L.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1.0e30


def _mlstm_kernel(q_ref, k_ref, v_ref, ig_ref, fg_ref, o_ref,
                  C_scr, n_scr, m_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        C_scr[...] = jnp.zeros_like(C_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    q = q_ref[0].astype(jnp.float32)            # (L, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = ig_ref[0].astype(jnp.float32)          # (L,)
    lf = jax.nn.log_sigmoid(fg_ref[0].astype(jnp.float32))
    L = chunk

    b = jnp.cumsum(lf)                           # (L,) inclusive
    m_prev = m_scr[0, 0]
    C_prev = C_scr[...]                          # (dh, dh)
    n_prev = n_scr[0]                            # (dh,)

    # intra-chunk log-weights D[t, s] = b_t - b_s + li_s   (s <= t)
    Dmat = b[:, None] - b[None, :] + li[None, :]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    Dmat = jnp.where(spos <= tpos, Dmat, NEG)

    m_intra = jnp.max(Dmat, axis=1)              # (L,)
    m_t = jnp.maximum(m_prev + b, m_intra)
    w = jnp.exp(Dmat - m_t[:, None])             # (L, L)
    coef = jnp.exp(m_prev + b - m_t)             # (L,)

    s_qk = q @ k.T                               # (L, L)
    inter_num = coef[:, None] * (q @ C_prev)     # (L, dh)
    num = inter_num + (w * s_qk) @ v
    den = coef * (q @ n_prev) + jnp.sum(w * s_qk, axis=1)
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)

    # ---- inter-chunk state update (evaluate the same closed form at t=L) --
    bL = b[-1]
    m_next = jnp.maximum(m_prev + bL, jnp.max(bL - b + li))
    wL = jnp.exp(bL - b + li - m_next)           # (L,)
    decay = jnp.exp(m_prev + bL - m_next)
    C_scr[...] = decay * C_prev + (k * wL[:, None]).T @ v
    n_scr[0] = decay * n_prev + jnp.sum(k * wL[:, None], axis=0)
    m_scr[0, 0] = m_next


def mlstm_chunkwise_bh(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                       interpret: bool = True):
    """q,k,v: (BH, S, dh); gates: (BH, S).  Returns h: (BH, S, dh)."""
    BH, S, dh = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, i_pre, f_pre)
