"""Oracle for the chunkwise mLSTM kernel: the stabilized sequential
recurrence from repro.models.layers.xlstm (re-exported for locality)."""
from __future__ import annotations

from repro.models.layers.xlstm import mlstm_recurrence


def mlstm_ref(q, k, v, i_pre, f_pre):
    """q,k,v: (B, S, H, dh); gates: (B, S, H).  Returns h: (B, S, H, dh)."""
    h, _ = mlstm_recurrence(q, k, v, i_pre, f_pre)
    return h
