"""Jitted wrapper: model layout (B, S, H, dh) -> kernel layout (B*H, S, dh)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm.kernel import mlstm_chunkwise_bh


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise(q, k, v, i_pre, f_pre, *, chunk: int = 128,
                    interpret: bool = True):
    """q,k,v: (B, S, H, dh); i_pre,f_pre: (B, S, H).  Returns (B, S, H, dh)."""
    B, S, H, dh = q.shape

    def bh(x):
        return x.swapaxes(1, 2).reshape(B * H, S, -1)

    def bh1(x):
        return x.swapaxes(1, 2).reshape(B * H, S)

    out = mlstm_chunkwise_bh(bh(q), bh(k), bh(v), bh1(i_pre), bh1(f_pre),
                             chunk=chunk, interpret=interpret)
    return out.reshape(B, H, S, dh).swapaxes(1, 2)
