"""Pure-jnp oracle for the flash-attention kernel.

Layout convention for the kernel stack: q (B, H, S, D), k/v (B, KV, S, D),
GQA group = H // KV, causal, optional sliding window and logit softcap.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, window: Optional[int] = None,
                  logit_softcap: float = 0.0):
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    kq = jnp.repeat(k, G, axis=1)     # (B, H, S, D)
    vq = jnp.repeat(v, G, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if logit_softcap:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vq.astype(jnp.float32))
    return out.astype(q.dtype)
