"""Flash attention Pallas-TPU kernel.

Online-softmax tiling: grid = (B, H, S/BQ); each cell streams KV in BKV-sized
VMEM tiles with running (max, sum, acc) carried in registers/VMEM.  BlockSpecs
keep one (BQ, D) query tile + the full (S, D) K/V stripe of the matching KV
head in VMEM; D and BQ/BKV are multiples of the 128-lane MXU tiling for the
real-hardware path (validated here with interpret=True on CPU).

GQA is handled in the BlockSpec index_map (query head h reads KV head h//G),
sliding windows / causality by masking each tile, gemma-style softcap applied
pre-mask.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bkv: int, seq: int,
                 window: Optional[int], softcap: float, scale: float):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, D)
    q_start = qi * bq

    n_kv = seq // bkv

    def body(j, carry):
        acc, m_run, l_run = carry
        k = k_ref[0, 0, pl.ds(j * bkv, bkv), :].astype(jnp.float32)  # (BKV,D)
        v = v_ref[0, 0, pl.ds(j * bkv, bkv), :].astype(jnp.float32)
        s = q @ k.T                                       # (BQ, BKV)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    D = q.shape[-1]
    acc0 = jnp.zeros((bq, D), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # skip tiles that are entirely masked: causal upper bound
    hi = jnp.minimum((q_start + bq + bkv - 1) // bkv, n_kv)
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (q_start - window) // bkv)
    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, window: Optional[int] = None,
                         logit_softcap: float = 0.0, bq: int = 256,
                         bkv: int = 256, interpret: bool = True):
    """q: (B, H, S, D); k/v: (B, KV, S, D).  Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    bq = min(bq, S)
    bkv = min(bkv, S)
    assert S % bq == 0 and S % bkv == 0, (S, bq, bkv)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_attn_kernel, bq=bq, bkv=bkv, seq=S,
                               window=window, softcap=logit_softcap,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(B, H, S // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i, _G=G: (b, h // _G, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, i, _G=G: (b, h // _G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
