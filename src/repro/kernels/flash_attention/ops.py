"""Jitted public wrapper: model layout (B, S, H, D) -> kernel layout."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("window", "logit_softcap",
                                             "interpret"))
def flash_attention(q, k, v, *, window: Optional[int] = None,
                    logit_softcap: float = 0.0, interpret: bool = True):
    """q: (B, S, H, D), k/v: (B, S, KV, D) — the model-side layout."""
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_bhsd(qt, kt, vt, window=window,
                               logit_softcap=logit_softcap,
                               interpret=interpret)
    return out.swapaxes(1, 2)
