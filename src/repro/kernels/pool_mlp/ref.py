"""Oracle for the fused pool-scoring kernel: vmap of the Table-4 head MLP
over the pool (Eq. 7 errors)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.networks import head_apply


def pool_errors_ref(pool_stacked, xd, y):
    """pool_stacked: head params stacked to (ns, ...); xd: (R, w); y: (R,).
    Returns (ns,) mean squared preliminary-prediction errors."""
    def one(head):
        return jnp.mean((y - head_apply(head, xd)) ** 2)

    return jax.vmap(one)(pool_stacked)
