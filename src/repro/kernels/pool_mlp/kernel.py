"""Fused heterogeneous-domain-selection kernel (paper Eq. 7).

The paper flags model selection as the expensive part of HFL ("requires
additional computation (for model selection)") — it evaluates EVERY pool head
(ns = NS x nf models) on the client's last R dense vectors: ns x R tiny MLP
forwards.  A GPU implementation launches ns tiny GEMM chains; on TPU that is
dominated by launch/HBM latency.  This kernel fuses the whole sweep: one grid
cell scores a BP-sized block of pool heads, keeping all five Table-4 layers
(16-256-64-16-1) and the (R, w) probe batch resident in VMEM, with the
(BP*R, d) matmuls shaped for the MXU.  Outputs the (ns,) error vector that
feeds argmin selection.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.networks import LRELU_SLOPE


def _pool_kernel(xd_ref, y_ref, w0, b0, w1, b1, w2, b2, w3, b3, w4, b4,
                 o_ref):
    xd = xd_ref[...].astype(jnp.float32)          # (R, w)
    y = y_ref[0].astype(jnp.float32)              # (R,)

    def sig(x):
        return jax.nn.sigmoid(x)

    def lrelu(x):
        return jnp.where(x >= 0, x, LRELU_SLOPE * x)

    # (BP, R, .) batched forward, everything VMEM-resident
    h = sig(jnp.einsum("rw,pwk->prk", xd, w0[...].astype(jnp.float32))
            + b0[...][:, None, :])
    h = sig(jnp.einsum("prk,pkj->prj", h, w1[...].astype(jnp.float32))
            + b1[...][:, None, :])
    h = lrelu(jnp.einsum("prk,pkj->prj", h, w2[...].astype(jnp.float32))
              + b2[...][:, None, :])
    h = lrelu(jnp.einsum("prk,pkj->prj", h, w3[...].astype(jnp.float32))
              + b3[...][:, None, :])
    out = (jnp.einsum("prk,pkj->prj", h, w4[...].astype(jnp.float32))
           + b4[...][:, None, :])[..., 0]         # (BP, R)
    err = jnp.mean((y[None, :] - out) ** 2, axis=1)
    o_ref[...] = err.astype(o_ref.dtype)


def pool_mlp_pallas(xd, y, weights, *, block_pool: int = 8,
                    interpret: bool = True):
    """xd: (R, w); y: (R,); weights: tuple (w0,b0,...,w4,b4) each with leading
    pool dim ns (multiple of block_pool).  Returns (ns,) errors."""
    ns = weights[0].shape[0]
    BP = min(block_pool, ns)
    assert ns % BP == 0, (ns, BP)
    R, w = xd.shape

    w_specs = []
    for t in weights:
        blk = (BP,) + t.shape[1:]
        w_specs.append(pl.BlockSpec(blk, lambda p, _n=len(t.shape): (p,) + (0,) * (_n - 1)))
    return pl.pallas_call(
        _pool_kernel,
        grid=(ns // BP,),
        in_specs=[
            pl.BlockSpec((R, w), lambda p: (0, 0)),
            pl.BlockSpec((1, R), lambda p: (0, 0)),
        ] + w_specs,
        out_specs=pl.BlockSpec((BP,), lambda p: (p,)),
        out_shape=jax.ShapeDtypeStruct((ns,), jnp.float32),
        interpret=interpret,
    )(xd, y[None], *weights)
