"""Fused heterogeneous-domain-selection kernel (paper Eq. 7).

The paper flags model selection as the expensive part of HFL ("requires
additional computation (for model selection)") — it evaluates EVERY pool head
(ns = NS x nf models) on the client's last R dense vectors: ns x R tiny MLP
forwards.  A GPU implementation launches ns tiny GEMM chains; on TPU that is
dominated by launch/HBM latency.  This kernel fuses the whole sweep: one grid
cell scores a BP-sized block of pool heads against one target feature's
probe batch, keeping all five Table-4 layers (16-256-64-16-1) and the (R, w)
probe batch resident in VMEM, with the (BP*R, d) matmuls shaped for the MXU.

The grid is (nf, ns // BP): the multi-feature sweep the batched engine needs
is ONE pallas_call whose first grid dimension walks the target features, not
a trace-time Python loop of nf single-feature sweeps.  Outputs the (nf, ns)
error matrix that feeds argmin selection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.networks import LRELU_SLOPE


def _pool_kernel(xd_ref, y_ref, w0, b0, w1, b1, w2, b2, w3, b3, w4, b4,
                 o_ref):
    xd = xd_ref[0].astype(jnp.float32)            # (R, w): this cell's feature
    y = y_ref[0].astype(jnp.float32)              # (R,)

    def sig(x):
        return jax.nn.sigmoid(x)

    def lrelu(x):
        return jnp.where(x >= 0, x, LRELU_SLOPE * x)

    # (BP, R, .) batched forward, everything VMEM-resident
    h = sig(jnp.einsum("rw,pwk->prk", xd, w0[...].astype(jnp.float32))
            + b0[...][:, None, :])
    h = sig(jnp.einsum("prk,pkj->prj", h, w1[...].astype(jnp.float32))
            + b1[...][:, None, :])
    h = lrelu(jnp.einsum("prk,pkj->prj", h, w2[...].astype(jnp.float32))
              + b2[...][:, None, :])
    h = lrelu(jnp.einsum("prk,pkj->prj", h, w3[...].astype(jnp.float32))
              + b3[...][:, None, :])
    out = (jnp.einsum("prk,pkj->prj", h, w4[...].astype(jnp.float32))
           + b4[...][:, None, :])[..., 0]         # (BP, R)
    err = jnp.mean((y[None, :] - out) ** 2, axis=1)
    o_ref[0, :] = err.astype(o_ref.dtype)


def pool_mlp_features_pallas(xd_feats, y, weights, *, block_pool: int = 8,
                             interpret: bool = True):
    """Score the pool against every target feature in one fused sweep.

    xd_feats: (nf, R, w); y: (R,); weights: tuple (w0,b0,...,w4,b4) each with
    leading pool dim ns.  Returns (nf, ns) errors.  ns must be a multiple of
    block_pool — the jitted wrapper in ``ops.py`` owns the padding; this raw
    entry point refuses ragged pools rather than silently mis-tiling."""
    ns = weights[0].shape[0]
    BP = min(block_pool, ns)
    if ns % BP:
        raise ValueError(
            f"pool size ns={ns} is not a multiple of block_pool={BP}; pad "
            f"the pool to a block multiple first (ops.pool_mlp_errors / "
            f"ops.pool_mlp_errors_features do this for you)")
    nf, R, w = xd_feats.shape

    w_specs = []
    for t in weights:
        blk = (BP,) + t.shape[1:]
        w_specs.append(pl.BlockSpec(
            blk, lambda f, p, _n=len(t.shape): (p,) + (0,) * (_n - 1)))
    return pl.pallas_call(
        _pool_kernel,
        grid=(nf, ns // BP),
        in_specs=[
            pl.BlockSpec((1, R, w), lambda f, p: (f, 0, 0)),
            pl.BlockSpec((1, R), lambda f, p: (0, 0)),
        ] + w_specs,
        out_specs=pl.BlockSpec((1, BP), lambda f, p: (f, p)),
        out_shape=jax.ShapeDtypeStruct((nf, ns), jnp.float32),
        interpret=interpret,
    )(xd_feats, y[None], *weights)


def pool_mlp_pallas(xd, y, weights, *, block_pool: int = 8,
                    interpret: bool = True):
    """Single-feature sweep: xd: (R, w); y: (R,); weights as above (ns a
    multiple of block_pool).  Returns (ns,) errors — the nf=1 slice of the
    feature-batched grid."""
    return pool_mlp_features_pallas(xd[None], y, weights,
                                    block_pool=block_pool,
                                    interpret=interpret)[0]
