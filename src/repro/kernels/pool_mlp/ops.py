"""Jitted wrapper mapping the HeadPool's stacked param dict onto the fused
pool-scoring kernel.  Pool padding to the block size lives HERE, and only
here — the raw kernel entry points refuse ragged pools."""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.pool_mlp.kernel import (pool_mlp_features_pallas,
                                           pool_mlp_pallas)

_KEYS = ("w0", "b0", "w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4")

# Backends with a Pallas lowering for this kernel: Mosaic on TPU (the tuned
# target) and Triton on GPU (EXPERIMENTAL: the batched-einsum body is
# untested against Triton's dot lowering — if it fails to lower on your
# GPU, set REPRO_POOL_KERNEL_INTERPRET=1 to force interpret mode without a
# code change).  Everywhere else (CPU tests, exotic backends) the kernel
# runs in interpret mode.
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def _resolve_interpret(interpret):
    """None -> compiled kernel on TPU and GPU, interpret-mode emulation
    elsewhere (interpret keeps CPU tests running).  The
    REPRO_POOL_KERNEL_INTERPRET env var (0/1) overrides the backend
    heuristic either way."""
    env = os.environ.get("REPRO_POOL_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "")
    if interpret is None:
        return jax.default_backend() not in _COMPILED_BACKENDS
    return interpret


def _padded_weights(pool_stacked, BP: int):
    """The stacked Table-4 param dict as the kernel's weight tuple, zero-
    padded so the pool dim is a multiple of the block size (the single home
    of the padding logic)."""
    ns = pool_stacked["w0"].shape[0]
    pad = (-ns) % BP
    weights = []
    for k in _KEYS:
        t = pool_stacked[k]
        if pad:
            t = jnp.concatenate(
                [t, jnp.zeros((pad,) + t.shape[1:], t.dtype)], axis=0)
        weights.append(t)
    return tuple(weights)


@functools.partial(jax.jit, static_argnames=("block_pool", "interpret"))
def pool_mlp_errors(pool_stacked, xd, y, *, block_pool: int = 8,
                    interpret=None):
    """pool_stacked: dict of stacked Table-4 head params (ns leading dim);
    xd: (R, w); y: (R,).  Returns (ns,) mean squared errors (Eq. 7)."""
    interpret = _resolve_interpret(interpret)
    ns = pool_stacked["w0"].shape[0]
    BP = min(block_pool, ns)
    errs = pool_mlp_pallas(xd, y, _padded_weights(pool_stacked, BP),
                           block_pool=BP, interpret=interpret)
    # Non-finite scores (NaN probes or poisoned pool rows) pin to +inf so
    # argmin never selects them — identical to the vmap fallback's pinning,
    # and an exact pass-through for finite errors.
    errs = jnp.where(jnp.isfinite(errs), errs, jnp.inf)
    return errs[:ns]


@functools.partial(jax.jit, static_argnames=("block_pool", "interpret"))
def pool_mlp_errors_features_masked(pool_stacked, xd_feats, y, valid, *,
                                    block_pool: int = 8, interpret=None):
    """The cohort engine's padded union-pool sweep: score a pool whose rows
    include zero-padded INVALID entries (features beyond a client's native
    nf, padded to ``max_nf``) and return their errors as ``+inf``.

    The kernel itself sweeps the dense padded rectangle — padded rows cost
    one extra block at most and keep the grid regular, which is the whole
    point of padding — and the ``valid`` mask (ns,) is applied inside this
    jitted wrapper so invalid rows can never win a selection, even if a
    backend lowers the zero-weight forward to something non-finite.
    xd_feats: (nf, R, w); y: (R,); valid: (ns,) bool.  Returns (nf, ns)."""
    errs = pool_mlp_errors_features(pool_stacked, xd_feats, y,
                                    block_pool=block_pool,
                                    interpret=interpret)
    return jnp.where(valid[None, :], errs, jnp.inf)


def pool_mlp_errors_shard(pool_chunk, xd_feats, y, valid=None, *,
                          block_pool: int = 8, interpret=None):
    """Score one device's contiguous CHUNK of the flattened pool — the
    client-sharded engine's per-device Eq.-7 sweep (each device scores
    ``ns / D`` rows; `federation.merge_sharded_argmin` reduces the
    per-chunk minima).

    The Eq.-7 error of a pool row depends on nothing but that row's params
    and the probe batch, so sweeping a chunk is BITWISE equal to slicing
    the corresponding columns out of the full sweep — the property the
    sharded/replicated parity tests pin.  The chunk is padded to the block
    size independently of the full pool (``_padded_weights`` keys on the
    chunk's own leading dim), which costs at most one extra block.

    pool_chunk: stacked param dict with a ``chunk``-sized leading dim;
    xd_feats: (nf, R, w); y: (R,); valid: optional (chunk,) bool mask of
    real (non-padded-feature) rows — invalid rows come back ``+inf``.
    Returns (nf, chunk)."""
    if valid is None:
        return pool_mlp_errors_features(pool_chunk, xd_feats, y,
                                        block_pool=block_pool,
                                        interpret=interpret)
    return pool_mlp_errors_features_masked(pool_chunk, xd_feats, y, valid,
                                           block_pool=block_pool,
                                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_pool", "interpret"))
def pool_mlp_errors_features(pool_stacked, xd_feats, y, *,
                             block_pool: int = 8, interpret=None):
    """Score the whole pool against EVERY target feature's probe batch.

    xd_feats: (nf, R, w) — one (R, w) dense-vector batch per target feature;
    y: (R,).  Returns (nf, ns).  ONE pallas_call whose grid walks
    (feature, pool-block) cells — nf sweeps in a single kernel launch, not a
    trace-time Python loop of nf launches."""
    interpret = _resolve_interpret(interpret)
    ns = pool_stacked["w0"].shape[0]
    BP = min(block_pool, ns)
    errs = pool_mlp_features_pallas(xd_feats, y,
                                    _padded_weights(pool_stacked, BP),
                                    block_pool=BP, interpret=interpret)
    # NaN-probe hardening: pin non-finite scores to +inf (NaN propagates
    # through argmin unpredictably across backends; +inf loses to every
    # finite candidate on all of them).  Finite errors pass through exactly.
    errs = jnp.where(jnp.isfinite(errs), errs, jnp.inf)
    return errs[:, :ns]
