"""Jitted wrapper mapping the HeadPool's stacked param dict onto the fused
pool-scoring kernel (pads the pool to the block size)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pool_mlp.kernel import pool_mlp_pallas

_KEYS = ("w0", "b0", "w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4")


@functools.partial(jax.jit, static_argnames=("block_pool", "interpret"))
def pool_mlp_errors(pool_stacked, xd, y, *, block_pool: int = 8,
                    interpret: bool = True):
    """pool_stacked: dict of stacked Table-4 head params (ns leading dim);
    xd: (R, w); y: (R,).  Returns (ns,) mean squared errors (Eq. 7)."""
    ns = pool_stacked["w0"].shape[0]
    BP = min(block_pool, ns)
    pad = (-ns) % BP
    weights = []
    for k in _KEYS:
        t = pool_stacked[k]
        if pad:
            t = jnp.concatenate(
                [t, jnp.zeros((pad,) + t.shape[1:], t.dtype)], axis=0)
        weights.append(t)
    errs = pool_mlp_pallas(xd, y, tuple(weights), block_pool=BP,
                           interpret=interpret)
    return errs[:ns]
