"""Jitted wrapper mapping the HeadPool's stacked param dict onto the fused
pool-scoring kernel (pads the pool to the block size)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pool_mlp.kernel import pool_mlp_pallas

_KEYS = ("w0", "b0", "w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4")


def _resolve_interpret(interpret):
    """None -> compiled kernel on TPU, interpret-mode emulation elsewhere
    (the kernel targets the MXU; interpret keeps CPU tests running)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("block_pool", "interpret"))
def pool_mlp_errors(pool_stacked, xd, y, *, block_pool: int = 8,
                    interpret=None):
    """pool_stacked: dict of stacked Table-4 head params (ns leading dim);
    xd: (R, w); y: (R,).  Returns (ns,) mean squared errors (Eq. 7)."""
    interpret = _resolve_interpret(interpret)
    ns = pool_stacked["w0"].shape[0]
    BP = min(block_pool, ns)
    pad = (-ns) % BP
    weights = []
    for k in _KEYS:
        t = pool_stacked[k]
        if pad:
            t = jnp.concatenate(
                [t, jnp.zeros((pad,) + t.shape[1:], t.dtype)], axis=0)
        weights.append(t)
    errs = pool_mlp_pallas(xd, y, tuple(weights), block_pool=BP,
                           interpret=interpret)
    return errs[:ns]


def pool_mlp_errors_features(pool_stacked, xd_feats, y, *, block_pool: int = 8,
                             interpret=None):
    """Score the whole pool against EVERY target feature's probe batch.

    xd_feats: (nf, R, w) — one (R, w) dense-vector batch per target feature;
    y: (R,).  Returns (nf, ns).  One fused kernel sweep per feature (nf is
    small and static, so this stays a trace-time loop rather than a vmap over
    the pallas_call)."""
    return jnp.stack([
        pool_mlp_errors(pool_stacked, xd_feats[f], y,
                        block_pool=block_pool, interpret=interpret)
        for f in range(xd_feats.shape[0])])
