"""RG-LRU linear-recurrence Pallas-TPU kernel (chunked scan).

TPU adaptation of the Griffin recurrence: the grid walks (batch, time-chunk)
with the time axis SEQUENTIAL per core; the carried hidden state lives in a
VMEM scratch buffer that persists across grid steps (standard TPU Pallas
carry idiom).  Within a chunk the recurrence h_t = a_t h_{t-1} + b_t is
solved by an associative scan over the VMEM-resident (CHUNK, d) tile —
log-depth on the VPU instead of a CUDA warp-scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scratch):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[0].astype(jnp.float32)           # (CHUNK, d)
    b = b_ref[0].astype(jnp.float32)
    h0 = h_scratch[0]                          # (d,)
    # fold carry into the first step: b'_0 = a_0 h0 + b_0
    b = b.at[0].set(a[0] * h0 + b[0])

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=0)
    o_ref[0] = h.astype(o_ref.dtype)
    h_scratch[0] = h[-1]


def rglru_scan_pallas(a, b, *, chunk: int = 256, interpret: bool = True):
    """a, b: (B, S, d).  Returns h: (B, S, d) with h_t = a_t h_{t-1} + b_t."""
    B, S, d = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    return pl.pallas_call(
        _rglru_kernel,
        grid=(B, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(a, b)
