"""Oracle for the RG-LRU chunked-scan kernel: h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a, b, h0=None):
    """a, b: (B, S, d) float32.  Returns h: (B, S, d)."""
    if h0 is None:
        h0 = jnp.zeros(a[:, 0].shape, a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
