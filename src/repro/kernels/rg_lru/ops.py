"""Jitted wrapper for the RG-LRU chunked-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rg_lru.kernel import rglru_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rglru_scan(a, b, *, chunk: int = 256, interpret: bool = True):
    """Linear recurrence h_t = a_t h_{t-1} + b_t over (B, S, d) tensors."""
    return rglru_scan_pallas(a, b, chunk=chunk, interpret=interpret)
