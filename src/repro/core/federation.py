"""Composable federation API: one policy description, two executors.

:class:`Federation` owns a set of :class:`~repro.core.hfl.FederatedClient`
objects, a :class:`~repro.core.policies.FederationPolicies` bundle (switch /
selection / transfer / pool — see `core/policies.py`), a shared
:class:`RoundSchedule`, and a :class:`Callback` list.  Both executors —
the ``sequential`` reference oracle and the ``batched`` fused engine —
consume the SAME policy description, so a new scenario (partial
participation, staleness bounds, softer selection, per-feature blending)
is one policy object, not two engine edits.

The batched executor fuses the ENTIRE federated epoch into one jitted
``lax.scan`` over sub-rounds (:func:`_make_epoch_fn`): each scan step runs
the vmapped Adam step on that round's R-slice and then the fused policy
round, with the per-epoch eval + save-best merge folded into the same
compiled function and the whole carried state donated, so an epoch is ONE
dispatch and zero host round-trips.  The policy bundle is a *static* jit
argument: every policy is a frozen (hashable) dataclass whose ``*_batched``
methods are traced straight into the scan, which is what preserves the
selection-identical guarantee between the two engines (pinned by
``tests/test_hfl_batched.py`` and ``tests/test_fused_epoch.py``).
Callbacks that need per-round delivery (see :class:`Callback`) fall back to
a chunked scan — the same compiled body dispatched per sub-round.

State — per-client params / optimizer state / validation history / best
snapshot, the head pool with per-entry ages, the host and device RNG
streams, and the epoch/round counters — lives on the Federation and its
clients, so :meth:`Federation.fit` is *resumable*: ``fit(epochs=k)`` runs k
more epochs, and :meth:`Federation.save` / :meth:`Federation.restore`
round-trip everything through ``repro.checkpoint`` for bit-identical
mid-training resumption.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import faults as FT
from repro.core import mesh_federation as MF
from repro.core import telemetry as TEL
from repro.core import trust as TR
from repro.core.hfl import (FederatedClient, HeadPool, HFLConfig,
                            _eval_mse, _pool_kernel_ops, _train_step,
                            pool_errors, pool_errors_kernel,
                            pool_kernel_available)
from repro.core.policies import FederationPolicies, policy_from_spec
from repro.optim import adam


# ---------------------------------------------------------------------------
# Round schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """The paper's training protocol skeleton, shared by every executor and
    by the non-federated benchmark loop: `epochs` epochs, one gradient step
    per R consecutive periods.

    ``exchange_every`` relaxes the pool-exchange cadence (bounded-staleness
    federation): a federated opportunity runs only on every k-th executed
    sub-round — sub-round ``r`` (0-based, counted within the epoch)
    exchanges iff ``(r + 1) % k == 0``, always on the sub-round's OWN probe
    batch.  The default k=1 is the paper's per-sub-round exchange,
    bit-identical to the historical behaviour.  The cadence resets at epoch
    boundaries, so an epoch with fewer than k sub-rounds never exchanges
    (the schedule tells you: ``exchanges(n_sub) == 0``).  Everything
    counted "per federated opportunity" follows the cadence: staleness ages
    (:class:`~repro.core.policies.MaxStaleness` ``max_age`` bounds exchange
    opportunities, not train sub-rounds), ``Federation.n_rounds``, and the
    selection log.  Semantics contract: docs/SCALING.md."""
    epochs: int
    R: int
    exchange_every: int = 1

    def __post_init__(self):
        if self.exchange_every < 1:
            raise ValueError(
                f"exchange_every must be >= 1 (1 = exchange every "
                f"sub-round, the paper's cadence), got {self.exchange_every}")

    def exchange_mask(self, n_sub: int) -> np.ndarray:
        """(n_sub,) bool: which within-epoch sub-rounds run a federated
        opportunity — ``(r + 1) % exchange_every == 0``."""
        return (np.arange(1, n_sub + 1) % self.exchange_every) == 0

    def exchanges(self, n_sub: int) -> int:
        """Federated opportunities per epoch of ``n_sub`` sub-rounds."""
        return n_sub // self.exchange_every

    def slices(self, n: int):
        """Sub-round batch slices over an n-sample train split.

        Only FULL R-batches are yielded: when n is not a multiple of R, the
        trailing partial batch of ``leftover(n)`` events is dropped — those
        events are never trained on, in any epoch.  :meth:`Federation.fit`
        announces this with a UserWarning so population sweeps over ragged
        lengths don't silently lose data (truncate to a multiple of R, or
        pick a divisor R, to silence it)."""
        for start in range(0, n - self.R + 1, self.R):
            yield slice(start, start + self.R)

    def sub_rounds(self, n: int) -> int:
        return max(0, (n - self.R) // self.R + 1)

    def leftover(self, n: int) -> int:
        """Trailing events per epoch that :meth:`slices` drops (0 when n is
        a multiple of R; n itself when n < R)."""
        return n - self.sub_rounds(n) * self.R


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------

class Callback:
    """Training hooks.  `fed` is the running Federation (None when invoked
    from the non-federated :func:`fit_local` loop).

    ``needs_per_round`` declares whether the callback must observe every
    ``on_round``.  The batched executor fuses a WHOLE epoch into one
    compiled dispatch when no callback needs per-round delivery; a callback
    that does forces the chunked path (one dispatch per sub-round, every
    ``on_round`` fired).  The default ``None`` auto-detects: overriding
    :meth:`on_round` opts in, leaving it untouched keeps the fused fast
    path.  Set it to ``False`` explicitly to keep the fused path even with
    an ``on_round`` override (the override then never fires on the batched
    engine), or ``True`` to force per-round delivery."""

    needs_per_round: Optional[bool] = None

    def on_fit_start(self, fed) -> None:
        """Once per :meth:`Federation.fit` call, before any training (and
        before the ragged-length UserWarning check)."""

    def on_round(self, fed, epoch: int, round_idx: int) -> None:
        """After each federated sub-round.  ``round_idx`` counts executed
        sub-rounds from 0 within the epoch.  On the batched engine this
        fires only on the chunked path (see ``needs_per_round``).  To read
        mid-epoch state there, go through :meth:`Federation.results` —
        it syncs the stacked loop state into the clients first; a direct
        ``fed.clients[i].params`` read is stale until then (current only
        on the sequential engine).  :meth:`Federation.save` is not valid
        here (mid-epoch saves raise)."""

    def on_epoch_end(self, fed, epoch: int, val: Dict[str, float],
                     active: Dict[str, bool]) -> None:
        """After each epoch: ``val`` maps client name -> this epoch's
        validation MSE, ``active`` maps client name -> whether its switch
        was active (it federated) this epoch.  Safe point for
        :meth:`Federation.save`."""

    def on_fit_end(self, fed, results) -> None:
        """Once per fit, after training: ``results`` is the
        :meth:`Federation.results` history dict."""


def _wants_per_round(cb: Callback) -> bool:
    """Resolve a callback's effective per-round need: the explicit
    ``needs_per_round`` flag if set, else whether it overrides
    :meth:`Callback.on_round`."""
    flag = getattr(cb, "needs_per_round", None)
    if flag is None:
        return type(cb).on_round is not Callback.on_round
    return bool(flag)


class VerboseLogger(Callback):
    """The engines' legacy per-epoch console line (a `*` marks clients whose
    switch was active this epoch), plus a wall-clock / throughput line:
    per-epoch wall time, client-rounds/s over the epoch (exchange
    opportunities actually run, the benchmarks' throughput unit), and —
    when the federation carries an enabled TelemetryPlan with the in-graph
    round series on — the latest pool staleness-age mean/max from the
    flight recorder."""

    def __init__(self):
        self._t0 = None
        self._rounds0 = None

    def on_fit_start(self, fed):
        self._t0 = time.perf_counter()
        self._rounds0 = (sum(fed.n_rounds.values())
                         if fed is not None else 0)

    def on_epoch_end(self, fed, epoch, val, active):
        engine = getattr(fed, "engine", None)
        tag = "hfl/batched" if engine == "batched" else "hfl"
        msg = " ".join(f"{n}={val[n]:.4f}{'*' if active.get(n) else ''}"
                       for n in val)
        print(f"[{tag}] epoch {epoch:3d} val: {msg}", flush=True)
        now = time.perf_counter()
        dt = now - self._t0 if self._t0 is not None else 0.0
        self._t0 = now
        if fed is None:
            print(f"[{tag}] epoch {epoch:3d} wall: {dt:.3f}s", flush=True)
            return
        total = sum(fed.n_rounds.values())
        done = total - (self._rounds0 or 0)
        self._rounds0 = total
        crs = done / dt if dt > 0 else 0.0
        line = (f"[{tag}] epoch {epoch:3d} wall: {dt:.3f}s "
                f"client-rounds/s: {crs:.1f}")
        rec = getattr(fed, "_recorder", None)
        ev = rec.last_round_event() if rec is not None else None
        if ev is not None and ev.get("age_mean") is not None:
            line += (f" staleness: {ev['age_mean']:.1f}"
                     f"/{ev['age_max']}")
        print(line, flush=True)


class MetricsCapture(Callback):
    """Records the per-epoch validation MSEs and switch activity."""

    def __init__(self):
        self.epochs: List[dict] = []

    def on_epoch_end(self, fed, epoch, val, active):
        self.epochs.append({"epoch": epoch, "val": dict(val),
                            "active": dict(active)})


class SaveBestCallback(Callback):
    """Persist the whole federation (Federation.save) whenever the
    population-mean validation MSE improves — disk-backed save-best."""

    def __init__(self, directory):
        self.directory = directory
        self.best = np.inf
        self.n_saves = 0

    def on_fit_start(self, fed):
        """Seed `best` from an existing checkpoint at `directory`, so a
        resumed run never clobbers a better historical best (the last
        checkpointed epoch is, by construction, the epoch that saved)."""
        m = Path(self.directory) / "manifest.json"
        if self.best == np.inf and m.exists():
            hist = json.loads(m.read_text())["val_histories"].values()
            if hist and all(h for h in hist):
                self.best = float(np.mean([h[-1] for h in hist]))

    def on_epoch_end(self, fed, epoch, val, active):
        if fed is None or not val:
            return
        m = float(np.mean(list(val.values())))
        if m < self.best:
            self.best = m
            fed.save(self.directory)
            self.n_saves += 1


# ---------------------------------------------------------------------------
# Sequential executor: one policy round for one client
# ---------------------------------------------------------------------------

def policy_round(client: FederatedClient, pool: HeadPool,
                 rng: np.random.Generator, policies: FederationPolicies,
                 *, use_kernel: bool = False) -> Optional[List[int]]:
    """One heterogeneous-transfer round for `client` (paper Fig. 6) under an
    explicit policy bundle.  Returns the selected pool indices per feature
    (positions in the sorted foreign pool), or None when there was nothing
    valid to select from."""
    if client._recent is None:
        return None
    stacked, keys = pool.stacked_for(client.name)
    if stacked is None:
        return None
    valid = pool.fresh_mask(client.name, policies.pool.max_age, keys=keys)
    if not valid.any():
        return None
    xd_R, y_R = client._recent
    sel = policies.selection
    chosen, sel_entries = [], []
    for i in range(client.nf):
        if sel.needs_errors:
            score_fn = pool_errors_kernel if use_kernel else pool_errors
            errs = np.asarray(score_fn(stacked, jnp.asarray(xd_R[:, i]),
                                       jnp.asarray(y_R)))
            errs = np.where(valid, errs, np.inf)
        else:
            errs = None
        j = sel.select_host(errs, valid, rng)
        chosen.append(j)
        sel_entries.append(jax.tree_util.tree_map(lambda p: p[j], stacked))
    selected = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sel_entries)
    client.params = dict(client.params)
    client.params["heads"] = policies.transfer.apply(client.params["heads"],
                                                     selected)
    return chosen


def _fit_sequential(fed: "Federation", n_epochs: int, cbs) -> None:
    """The reference oracle: a host-driven Python loop — per-client jitted
    train steps interleaved with per-client :func:`policy_round` calls in
    list order — that defines the semantics the batched engine must
    reproduce.  Handles heterogeneous nf and ragged data lengths."""
    pol = fed.policies
    C = len(fed.clients)
    use_kernel = fed.cfg.use_pool_kernel
    k_ex = fed.schedule.exchange_every
    admission = fed._admission()
    smask = fed._straggler_mask
    trust = fed._trust
    wm = trust.watermark if trust is not None else None
    dpn = trust.dp if trust is not None else None
    sa = trust.secure_agg if trust is not None else None
    gids = {c.name: fed._trust_ids[i] for i, c in enumerate(fed.clients)} \
        if trust is not None else {}
    rec = fed._recorder
    heads_rejected = 0
    n_exchange = 0            # executed sub-rounds that ran an exchange
    n_dispatch = 0            # jitted calls: train steps + Eq.-7 scorings +
                              # per-epoch evals (eager tree ops not counted)

    def publish(c, e_idx: int):
        """One publication opportunity for an active client: watermark
        verify + top-up, DP release, admission guard, pool write — the
        oracle twin of the fused body's publication tail (watermark and
        DP ride the SAME jnp functions the engines trace; only the DP
        noise stream is host-side — noise is engine-specific, like
        stochastic selection)."""
        nonlocal heads_rejected
        cand = c.params["heads"]
        if wm is not None:
            new_h, ok, _ = TR.wm_apply(cand, fed._wm_sig(c),
                                       strength=wm.strength,
                                       threshold=wm.threshold)
            c.params = dict(c.params)
            c.params["heads"] = new_h   # the client keeps its topped-up head
            if not bool(ok):            # tampered: block + count, stale row
                fed._wm_failures[c.name] += 1   # persists as evidence
                return
            cand = new_h
        if dpn is not None:
            cand, clipped = TR.dp_privatize_host(
                cand, dpn, fed._trust_wave_base + fed.epoch, e_idx,
                gids[c.name])
            if clipped:
                fed._clip_events += 1
        if admission is None or FT.heads_admissible(cand, admission):
            fed.pool.publish(c.name, cand, c.nf)
            if dpn is not None:
                fed._dp_counts[c.name] = fed._dp_counts.get(c.name, 0) + 1
        else:           # admission guard: the stale row persists
            heads_rejected += 1

    def secure_exchange(clients, active, e_idx: int):
        """The oracle's masked mean-transfer round: train results for the
        round's clients are stacked (zero-padded to max_nf for mixed
        populations) and handed to the SAME jitted ``trust.secure_round``
        the fused engines trace, so the masked blend matches the batched
        engine to float tolerance by construction; the host then publishes
        the masked payloads y = priv + mask, never a raw head."""
        nonlocal heads_rejected
        max_nf = max(c.nf for c in clients)
        wave = fed._trust_wave_base + fed.epoch
        tmpl = jax.tree_util.tree_map(
            np.asarray, TR.pad_rows(clients[0].params["heads"], max_nf))
        masks = TR.net_masks(sa, wave, 1,
                             [gids[c.name] for c in clients], tmpl,
                             round_offset=e_idx)
        act = np.array([active[c.name] for c in clients])
        corr = TR.mask_correction(masks, act)
        mask0 = jax.tree_util.tree_map(lambda m: jnp.asarray(m[0]), masks)
        corr0 = jax.tree_util.tree_map(lambda m: jnp.asarray(m[0]), corr)
        heads = TR.stack_trees_np(
            [TR.pad_rows(jax.tree_util.tree_map(np.asarray,
                                                c.params["heads"]), max_nf)
             for c in clients])
        heads = jax.tree_util.tree_map(jnp.asarray, heads)
        fv = np.zeros((len(clients), max_nf), bool)
        for i, c in enumerate(clients):
            fv[i, :c.nf] = True
        priv = None
        if dpn is not None:
            rel = [TR.dp_privatize_host(_tree_row(heads, i), dpn, wave,
                                        e_idx, gids[c.name])
                   for i, c in enumerate(clients)]
            fed._clip_events += sum(int(cl and act[i])
                                    for i, (_, cl) in enumerate(rel))
            priv = _stack_trees([r for r, _ in rel])
        dummy_age = jnp.zeros((len(clients),), jnp.int32)
        new_heads, _, _, _, rejected, _ = TR.secure_round_jit(
            heads, heads, dummy_age, jnp.asarray(act), mask0, corr0,
            jax.random.PRNGKey(0), priv=priv, feat_valid=jnp.asarray(fv),
            sa=sa, dp=None, nf=max_nf, admission=admission)
        rej = (np.zeros(len(clients), bool) if rejected is None
               else np.asarray(rejected))
        src = heads if priv is None else priv
        for i, c in enumerate(clients):
            if not act[i]:
                continue
            fed.n_rounds[c.name] += 1
            c.params = dict(c.params)
            c.params["heads"] = jax.tree_util.tree_map(
                lambda l: l[i, :c.nf], new_heads)
            if rej[i]:
                heads_rejected += 1
                continue
            y = jax.tree_util.tree_map(
                lambda p, m: np.asarray(p[i, :c.nf])
                + np.asarray(m[i, :c.nf]).astype(
                    np.asarray(p[i, :c.nf]).dtype),
                src, mask0)
            fed.pool.publish(c.name, y, c.nf)
            if dpn is not None:
                fed._dp_counts[c.name] = fed._dp_counts.get(c.name, 0) + 1

    for _ in range(n_epochs):
        epoch = fed.epoch
        mask = pol.switch.active_mask(
            [c.val_history for c in fed.clients], fed._switch_rng)
        if smask is not None:   # stragglers train but miss every exchange
            mask = np.asarray(mask, bool) & ~np.asarray(smask, bool)
        active = {c.name: bool(mask[i]) for i, c in enumerate(fed.clients)}
        iters = {c.name: c.train_epoch(R=fed.schedule.R)
                 for c in fed.clients}
        live = set(iters)
        rounds_start = sum(fed.n_rounds.values())
        fed._mid_epoch = True
        rnd = 0
        e_idx = 0               # exchange index within the epoch (the
                                # trust layer's mask/noise round key)
        while live:
            # bounded-staleness cadence: only every k-th executed sub-round
            # (within the epoch) is a federated opportunity — on the other
            # rounds clients just train, and the staleness clock stands
            # still (ages count exchange opportunities, not sub-rounds)
            exchange = (rnd + 1) % k_ex == 0
            # staleness clock: tick once per exchange round in which
            # federation can run (mirrors the batched engine's age array)
            ticked = not exchange or not (pol.pool.bounded and C >= 2
                                          and any(active[n] for n in live))
            progressed = False
            stepped = []
            for c in fed.clients:
                if c.name not in live:
                    continue
                try:
                    next(iters[c.name])
                except StopIteration:
                    live.discard(c.name)
                    continue
                progressed = True
                stepped.append(c)
                n_dispatch += 1
                if sa is not None or not exchange:
                    continue    # secure mode exchanges once, after training
                if not ticked:
                    fed.pool.tick()
                    ticked = True
                if active[c.name]:
                    sel = policy_round(c, fed.pool, fed._sel_rng, pol,
                                       use_kernel=use_kernel)
                    if sel is not None:
                        fed.selections[c.name].append(sel)
                        if pol.selection.needs_errors:
                            n_dispatch += c.nf
                    if trust is None:
                        fed.n_rounds[c.name] += 1
                        if admission is None or FT.heads_admissible(
                                c.params["heads"], admission):
                            fed.pool.publish(c.name, c.params["heads"], c.nf)
                        else:   # admission guard: the stale row persists
                            heads_rejected += 1
                    else:
                        fed.n_rounds[c.name] += 1
                        publish(c, e_idx)
            if sa is not None and exchange and progressed:
                # masked secure aggregation: one collective round over the
                # clients that trained this sub-round (mirrors the fused
                # engine's all-clients round)
                if not ticked:
                    fed.pool.tick()
                    ticked = True
                if any(active[c.name] for c in stepped) and C >= 2:
                    secure_exchange(fed.clients,
                                    {c.name: active[c.name]
                                     and c in stepped for c in fed.clients},
                                    e_idx)
                    n_dispatch += 1
            if progressed:
                if exchange and any(active.values()):
                    n_exchange += 1
                    e_idx += 1
                for cb in cbs:
                    cb.on_round(fed, epoch, rnd)
                rnd += 1
        for c in fed.clients:
            c.end_epoch()
        n_dispatch += C
        fed.epoch += 1
        fed._mid_epoch = False
        if rec is not None:
            done = sum(fed.n_rounds.values()) - rounds_start
            if done:
                rec.count("client_rounds", done)
        val = {c.name: c.val_history[-1] for c in fed.clients}
        for cb in cbs:
            cb.on_epoch_end(fed, epoch, val, active)
    if rec is not None and heads_rejected:
        rec.count("heads_rejected", int(heads_rejected))
    fed.dispatch_stats = {"engine": "sequential", "path": "per-round",
                          "devices": 1,
                          "epochs": n_epochs, "dispatches": n_dispatch,
                          "dispatches_per_epoch": n_dispatch / n_epochs,
                          "exchange_every": k_ex,
                          "exchange_rounds": n_exchange,
                          "pool_bytes_gathered": 0,
                          "state_bytes": sum(
                              _tree_bytes((c.params, c.opt_state,
                                           c.best_params))
                              for c in fed.clients),
                          **fed._fault_stats(heads_rejected),
                          **fed._trust_stats()}


# ---------------------------------------------------------------------------
# Batched executor: fused multi-client selection + transfer
# ---------------------------------------------------------------------------

def shard_argmin(errs_loc, offset):
    """One device's contribution to a sharded Eq.-7 argmin: per-feature
    ``(min error, GLOBAL flat index)`` over its contiguous pool chunk.
    ``jnp.argmin`` returns the first occurrence, so within the chunk ties
    already resolve to the lowest local index; adding the chunk ``offset``
    keeps global indices monotone in device order.  errs_loc: (nf, chunk);
    returns ((nf,) float values, (nf,) int32 global indices)."""
    li = jnp.argmin(errs_loc, axis=1)                              # (nf,)
    lv = jnp.take_along_axis(errs_loc, li[:, None], axis=1)[:, 0]
    return lv, (offset + li).astype(jnp.int32)


def merge_sharded_argmin(vals, gidx, ns: int):
    """Merge per-device :func:`shard_argmin` pairs into the GLOBAL argmin,
    reproducing ``jnp.argmin(errs, axis=1)`` on the full (nf, ns) matrix
    exactly — including its tie-break.

    The pinned tie-break rule (tests/test_sharded_policy.py): among tied
    minima the LOWEST flat pool index wins — ``argmin``'s first-occurrence
    semantics.  Chunks are contiguous and offsets monotone in device order,
    so taking the minimum global index among the devices achieving the
    minimum value reproduces it; a fully-stale pool (every error ``inf``,
    which ``inf == inf`` keeps comparable) resolves to index 0 on both
    paths.  vals/gidx: (D, nf); returns (nf,) int32."""
    m = jnp.min(vals, axis=0)                                      # (nf,)
    achieves = vals == m[None, :]
    return jnp.min(jnp.where(achieves, gidx, ns), axis=0).astype(jnp.int32)


def _policy_round_body(heads, pool_heads, pool_age, xd_R, y_R, active, key,
                       *, nf: int, policies: FederationPolicies,
                       use_kernel: bool, feat_valid=None, shard=None,
                       admission=None, trust=None, trust_sig=None,
                       telemetry=None):
    """One federated opportunity for ALL clients as a traceable scan over
    clients — the body both :func:`fused_policy_round` (standalone jit) and
    the fused-epoch scan (:func:`_make_epoch_fn`) trace.  The policy
    bundle's jittable ``select_batched`` / ``apply`` kernels are traced
    straight into the scan body, so a policy swap is a recompile, never an
    engine edit.

    The scan walks clients in their processing order, carrying the pool (and
    its per-publisher age vector) so that client i scores the heads already
    republished by clients < i in the same sub-round — exactly the
    sequential oracle's interleaving.

    heads, pool_heads: head params stacked to (C, nf, ...); pool_age: (C,)
    int32 opportunities-since-publication per pool row; xd_R: (C, R, nf, w);
    y_R: (C, R); active: (C,) bool; key: PRNG key.  Returns (new_heads,
    new_pool, new_age, chosen) where chosen is (C, nf) int32 flat indices
    into the row-major (client, feature) pool (-1 where the client was
    inactive or nothing valid was available).

    ``feat_valid`` opts into the heterogeneous (cohort-engine) form: a
    static (C, nf) bool array — here nf is ``max_nf``, the padded feature
    count — marking which rows of each client's padded head/probe stacks
    are real features.  Invalid rows are excluded from every selection,
    their blend results are discarded (padded head rows stay zero), and
    their ``chosen`` entries are -1.  ``None`` (the homogeneous engines)
    traces exactly the original body.

    ``shard`` opts into client-sharded Eq.-7 scoring (the mesh engines):
    an ``(axis_name, n_devices)`` pair naming the mesh axis this body runs
    under (via ``shard_map``).  Each device then scores only its contiguous
    ``ns / D`` chunk of the flattened pool per scan step — the pool itself
    stays replicated and is updated in lockstep, so the oracle's
    fresh-head visibility (client i sees clients < i's republications) is
    preserved exactly.  Selection policies with ``local_argmin`` reduce via
    per-device minima + :func:`merge_sharded_argmin` (two (D, nf)
    all-gathers per client); other error-based policies all-gather the
    full (nf, ns) error matrix and select replicated.  ``None`` (the
    single-device engines) traces exactly the unsharded body.

    ``admission`` opts into the in-graph pool admission guard (the fault-
    tolerance layer, ``core/faults.py``): a float L2 norm bound on any head
    tree a client tries to publish.  Before the pool write-back each
    candidate head is checked finite-and-within-bound; a rejected
    publication leaves the previous pool row AND its age untouched (the
    stale entry keeps aging under the staleness clock), and rows at the
    :data:`~repro.core.faults.QUARANTINE_AGE` sentinel are excluded from
    selection even under last-write-wins pools.  The body then returns a
    FIFTH output: the (C,) bool per-client rejection mask for this
    opportunity.  ``None`` (the default) traces exactly the original
    4-output body — the no-faults bit-identity pin.

    ``trust`` (a :class:`~repro.core.trust.TrustPlan` without secure_agg —
    the masked round bypasses this body entirely, see
    ``trust.secure_round``) opts into the trust layer's publication tail:
    with ``trust.watermark``, each active client's post-blend head is
    signature-verified and topped up (``trust.wm_apply`` on its row of
    the replicated ``trust_sig`` stack); a failed verification blocks the
    publication (the stale clean row persists) and is counted.  With
    ``trust.dp``, the publication candidate is clip+noise privatized
    in-graph (noise key = ``fold_in(key_i, 0x7D)`` — a stream the
    selection RNG never sees, which is what keeps ``trust=None``
    byte-identical).  The admission guard then checks the PRIVATIZED
    candidate (the actual release).  When ``trust`` is set the body
    returns one extra trailing output: a ``((C,) clip, (C,) wm_failed)``
    bool pair.  ``None`` traces exactly the pre-trust graph.

    ``telemetry`` (a :class:`~repro.core.telemetry.TelemetryPlan` with
    ``rounds`` on, or None) opts into the in-graph metrics carry: the body
    additionally returns, as its LAST output, a ``((C,) score_min, (C,)
    score_mean)`` float32 pair — the Eq.-7 score distribution each client
    saw over its valid candidates this opportunity (``inf`` / 0 when the
    selection policy scores nothing).  On the sharded ``local_argmin``
    path the aggregates reduce with ``pmin`` / ``psum`` so they come back
    replicated.  ``None`` traces exactly the pre-telemetry graph (the
    bit-identity pin, mirroring ``faults=None`` / ``trust=None``)."""
    if trust is not None and trust.secure_agg is not None:
        raise ValueError(
            "masked secure aggregation replaces the selection round "
            "entirely (trust.secure_round) — it never reaches "
            "_policy_round_body")
    C = y_R.shape[0]
    ns = C * nf
    sel, transfer, poolp = policies.selection, policies.transfer, policies.pool
    bounded = poolp.bounded
    if feat_valid is not None:
        fv = jnp.asarray(np.asarray(feat_valid, bool))          # (C, nf)
        valid_flat = fv.reshape(ns)

    def flat(pool):
        return jax.tree_util.tree_map(
            lambda p: p.reshape((ns,) + p.shape[2:]), pool)

    def body(carry, inp):
        heads, pool, age = carry
        i, key_i = inp
        fp = flat(pool)
        own = (jnp.arange(ns) // nf) == i
        if feat_valid is not None:
            own = own | ~valid_flat          # padded rows are never sources
        if bounded:
            # quarantined rows sit at age QUARANTINE_AGE > any max_age, so
            # the staleness exclusion already hides them
            excluded = own | jnp.repeat(age > poolp.max_age, nf)
            any_valid = jnp.any(~excluded)
        elif admission is not None or trust is not None:
            # last-write-wins pool under the admission guard or the trust
            # layer: quarantined seed rows (zeroed, age = QUARANTINE_AGE —
            # inadmissible or watermark-failed at seeding) must still be
            # hidden, exactly as the oracle's fresh_mask hides them
            excluded = own | jnp.repeat(age >= FT.QUARANTINE_AGE, nf)
            any_valid = jnp.any(~excluded)
        else:
            excluded = own
            # C >= 2 enforced by the caller; with a padded pool every
            # foreign client still contributes >= 1 valid feature row
            any_valid = jnp.bool_(True)
        def score(pool_rows, valid_rows):
            """Eq.-7 errors of ``pool_rows`` (full pool or a device chunk)
            against client i's probe batch — row-independent, so a chunk
            sweep equals the corresponding slice of the full sweep."""
            xd_i = jnp.moveaxis(xd_R[i], 1, 0)          # (nf, R, w)
            if use_kernel:
                ops = _pool_kernel_ops()
                if valid_rows is not None:
                    return ops.pool_mlp_errors_shard(pool_rows, xd_i,
                                                     y_R[i], valid_rows)
                return ops.pool_mlp_errors_features(pool_rows, xd_i, y_R[i])
            return jax.vmap(
                lambda xf: pool_errors(pool_rows, xf, y_R[i]))(xd_i)

        valid_arg = valid_flat if feat_valid is not None else None
        if sel.needs_errors:
            if shard is None:
                errs = jnp.where(excluded[None, :], jnp.inf,
                                 score(fp, valid_arg))          # (nf, ns)
            else:
                # client-sharded scoring: this device's contiguous chunk of
                # the flattened pool (C % D == 0 so ns % D == 0)
                axis, D = shard
                chunk = ns // D
                off = jax.lax.axis_index(axis) * chunk
                take = lambda v: jax.lax.dynamic_slice_in_dim(v, off,
                                                              chunk, 0)
                fp_loc = jax.tree_util.tree_map(take, fp)
                errs_loc = score(
                    fp_loc, take(valid_arg) if valid_arg is not None
                    else None)
                errs_loc = jnp.where(take(excluded)[None, :], jnp.inf,
                                     errs_loc)                  # (nf, chunk)
                if sel.local_argmin:
                    # small reduce: per-device (min, global index) pairs
                    lv, gi = shard_argmin(errs_loc, off)
                    j = merge_sharded_argmin(jax.lax.all_gather(lv, axis),
                                             jax.lax.all_gather(gi, axis),
                                             ns)
                    errs = None
                else:
                    # the policy needs the full error distribution: gather
                    # the chunks back to (nf, ns) and select replicated
                    errs = jax.lax.all_gather(errs_loc, axis, axis=1,
                                              tiled=True)
        else:
            errs = None
        # padded pools always pass bounded=True: the exclusion mask is
        # non-trivial even under last-write-wins, so selection policies must
        # take their masked path (see SelectionPolicy.select_batched)
        if shard is None or not (sel.needs_errors and sel.local_argmin):
            j = sel.select_batched(errs, excluded, key_i, nf=nf, ns=ns, i=i,
                                   bounded=bounded or feat_valid is not None
                                   or admission is not None)
        selected = jax.tree_util.tree_map(lambda p: p[j], fp)      # (nf, ...)
        mine = jax.tree_util.tree_map(lambda h: h[i], heads)
        blended = transfer.apply(mine, selected)
        act = active[i] & any_valid
        if feat_valid is not None:
            mask_i = act & fv[i]                               # (nf,)
            new_mine = jax.tree_util.tree_map(
                lambda b, m: jnp.where(
                    mask_i.reshape((nf,) + (1,) * (m.ndim - 1)), b, m),
                blended, mine)
        else:
            new_mine = jax.tree_util.tree_map(
                lambda b, m: jnp.where(act, b, m), blended, mine)
        # publication: active clients overwrite their pool row (age resets),
        # inactive clients' stale entries persist (the pool policy decides
        # how long they stay *visible*)
        pub = active[i]
        if trust is not None and trust.watermark is not None:
            # signature verify + top-up on the client's OWN head: the
            # topped head persists in its params (so the honest watermark
            # never decays through Eq.-8 blending); a failed verification
            # (a sign-flipped head projects at -strength) blocks the
            # publication and leaves the head untouched as evidence
            sig_i = jax.tree_util.tree_map(lambda s: s[i], trust_sig)
            topped, wm_ok, _ = TR.wm_apply(
                new_mine, sig_i, strength=trust.watermark.strength,
                threshold=trust.watermark.threshold)
            new_mine = jax.tree_util.tree_map(
                lambda t, m: jnp.where(pub, t, m), topped, new_mine)
            wmf_i = pub & ~wm_ok
            pub = pub & wm_ok
        else:
            wmf_i = jnp.zeros((), bool)
        heads = jax.tree_util.tree_map(
            lambda h, m: h.at[i].set(m), heads, new_mine)
        cand = new_mine
        if trust is not None and trust.dp is not None:
            # the DP release: what actually reaches the pool is the
            # clipped+noised candidate; the client's own params keep the
            # raw head.  The noise key forks off the selection key on a
            # dedicated stream, so the selection RNG sequence (and with
            # it the trust=None graph) is untouched.
            cand, clipped = TR.dp_privatize(
                cand, jax.random.fold_in(key_i, 0x7D),
                clip=trust.dp.clip, sigma=trust.dp.sigma)
            if feat_valid is not None:
                # padded rows stay zero in the pool (noise on a row the
                # client does not own is never a release)
                cand = jax.tree_util.tree_map(
                    lambda l: jnp.where(
                        fv[i].reshape((nf,) + (1,) * (l.ndim - 1)), l, 0),
                    cand)
            clip_i = pub & clipped
        else:
            clip_i = jnp.zeros((), bool)
        if admission is not None:
            # pool admission guard: a candidate head must be finite and
            # within the L2 norm bound, or the publication is rejected —
            # the previous (clean) row and its age survive untouched
            sq = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                     for leaf in jax.tree_util.tree_leaves(cand))
            ok = jnp.isfinite(sq) & (sq <= jnp.float32(admission) ** 2)
            rejected_i = pub & ~ok
            pub = pub & ok
        pool = jax.tree_util.tree_map(
            lambda pl, m: pl.at[i].set(jnp.where(pub, m, pl[i])),
            pool, cand)
        age = age.at[i].set(jnp.where(pub, 0, age[i]))
        if feat_valid is not None:
            chosen = jnp.where(act & fv[i], j, -1).astype(jnp.int32)
        else:
            chosen = jnp.where(act, j, -1).astype(jnp.int32)
        if admission is not None and trust is not None:
            ys = (chosen, rejected_i, (clip_i, wmf_i))
        elif admission is not None:
            ys = (chosen, rejected_i)
        elif trust is not None:
            ys = (chosen, (clip_i, wmf_i))
        else:
            ys = chosen
        if telemetry is not None:
            # the metrics carry: client i's Eq.-7 score aggregates over its
            # masked candidate pool.  Excluded entries score inf, so the
            # min is the winning score and the mean runs over the finite
            # (valid) candidates; policies that never score (and secure
            # rounds, which bypass this body) report the inf/0 sentinels.
            if sel.needs_errors and errs is not None:
                fin = jnp.isfinite(errs)
                smin_i = jnp.min(errs)
                smean_i = jnp.sum(jnp.where(fin, errs, 0.0)) \
                    / jnp.maximum(jnp.sum(fin), 1)
            elif sel.needs_errors:
                # sharded local_argmin path: the error matrix stayed
                # device-local — reduce the aggregates collectively so
                # they come back replicated
                axis, _D = shard
                fin = jnp.isfinite(errs_loc)
                smin_i = jax.lax.pmin(jnp.min(errs_loc), axis)
                smean_i = jax.lax.psum(
                    jnp.sum(jnp.where(fin, errs_loc, 0.0)), axis) \
                    / jnp.maximum(jax.lax.psum(jnp.sum(fin), axis), 1)
            else:
                smin_i = jnp.asarray(jnp.inf)
                smean_i = jnp.asarray(0.0)
            tele_i = (smin_i.astype(jnp.float32),
                      smean_i.astype(jnp.float32))
            ys = (ys if isinstance(ys, tuple) else (ys,)) + (tele_i,)
        return (heads, pool, age), ys

    keys = jax.random.split(key, C)
    (heads, pool_heads, pool_age), ys = jax.lax.scan(
        body, (heads, pool_heads, pool_age), (jnp.arange(C), keys))
    if telemetry is not None:
        tele = ys[-1]
        ys = ys[:-1]
        if len(ys) == 1:
            ys = ys[0]
    if admission is not None and trust is not None:
        chosen, rejected, tstats = ys
        out = (heads, pool_heads, pool_age, chosen, rejected, tstats)
    elif admission is not None:
        chosen, rejected = ys
        out = (heads, pool_heads, pool_age, chosen, rejected)
    elif trust is not None:
        chosen, tstats = ys
        out = (heads, pool_heads, pool_age, chosen, tstats)
    else:
        out = (heads, pool_heads, pool_age, ys)
    if telemetry is not None:
        out = out + (tele,)
    return out


@functools.partial(jax.jit, static_argnames=("nf", "policies", "use_kernel"))
def fused_policy_round(heads, pool_heads, pool_age, xd_R, y_R, active, key,
                       *, nf: int, policies: FederationPolicies,
                       use_kernel: bool):
    """Standalone jitted :func:`_policy_round_body` — ONE federated
    opportunity per dispatch.  The fused-epoch engine no longer dispatches
    this per round (it traces the body into its epoch scan); it remains the
    single-round entry point for diagnostics and benchmarks."""
    return _policy_round_body(heads, pool_heads, pool_age, xd_R, y_R,
                              active, key, nf=nf, policies=policies,
                              use_kernel=use_kernel)


def _stack_trees(trees):
    """Stack a list of same-structure pytrees leaf-wise on a new leading
    axis — the batched engine's (C, ...) client stacking."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _tree_bytes(tree) -> int:
    """Total payload bytes of a pytree's leaves (comms accounting)."""
    return int(sum(int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(tree)))


def _exchange_round_bytes(D: int, heads_bytes: int, probe_bytes: int,
                          C: int, nf: int, ns: int, selection) -> int:
    """Analytic per-device bytes one mesh exchange round moves — what
    ``dispatch_stats["pool_bytes_gathered"]`` accumulates: the pool-
    candidate heads + probe-batch all-gathers, plus the per-client score
    reduce (two tiny (D, nf) pairs under ``local_argmin`` selection, the
    full (nf, ns) float32 error matrix otherwise, nothing for policies
    that skip Eq.-7 scoring)."""
    if selection.needs_errors:
        if selection.local_argmin:
            reduce_b = C * D * nf * 8       # f32 minima + int32 indices
        else:
            reduce_b = C * nf * ns * 4      # gathered (nf, ns) errors
    else:
        reduce_b = 0
    return heads_bytes + probe_bytes + reduce_b


def stack_pool(pool: HeadPool, names: Sequence[str], nf: int):
    """A HeadPool's entries as the batched engine's stacked ``(C, nf, ...)``
    tree — the one place that defines the stacked pool layout, shared by
    the executor and by benchmarks profiling its building blocks."""
    return _stack_trees(
        [_stack_trees([pool.entries[(n, f)] for f in range(nf)])
         for n in names])


def _tree_row(tree, i):
    """Client i's slice of a stacked (C, ...) tree."""
    return jax.tree_util.tree_map(lambda p: p[i], tree)


def _selection_lut(names: Sequence[str], nf: int) -> np.ndarray:
    """Map the batched engine's row-major (client, feature) flat pool index
    to the sequential oracle's excluded, sorted-by-(name, feature) index —
    so both engines log identical selections."""
    C = len(names)
    lut = np.full((C, C * nf), -1, np.int64)
    for i in range(C):
        others = sorted((names[j], j) for j in range(C) if j != i)
        for rank, (_, j) in enumerate(others):
            for g in range(nf):
                lut[i, j * nf + g] = rank * nf + g
    return lut


@functools.lru_cache(maxsize=None)
def _make_batched_fns(lr: float):
    """vmap-over-clients versions of the exact same per-client step/eval the
    sequential engine jits (see hfl._train_step / hfl._eval_mse)."""
    opt = adam(lr)
    step = jax.jit(jax.vmap(functools.partial(_train_step, opt)))
    evaluate = jax.jit(jax.vmap(_eval_mse))
    return step, evaluate


def _epoch_body(lr: float, nf: int, policies: FederationPolicies,
                use_kernel: bool, do_federate: bool, do_eval: bool, *,
                exchange_every: int = 1, gather=None, local_rows=None,
                shard=None, admission=None, trust=None, telemetry=None):
    """The fused whole-epoch computation shared by BOTH batched backends:
    a scan over the epoch's sub-rounds (vmapped Adam step on that round's
    R-slice, then the fused policy round), with the per-epoch validation
    eval and save-best ``where``-merge folded in when ``do_eval``.

    ``gather`` / ``local_rows`` are the pool-exchange hooks — the ONLY
    point where the two backends differ.  Identity (the default) on the
    single-device path, where every array already holds all C clients.
    The mesh backend (``repro.core.mesh_federation``) injects an
    all-gather along the `clients` axis (pool candidates + probe batches
    to the global client order) and a dynamic-slice taking the device's
    own client block back out of the blended heads; the probe gathers are
    issued BEFORE the train step, which has no data dependency on them, so
    XLA's scheduler may overlap the collective with the step's compute.
    ``shard`` is forwarded to :func:`_policy_round_body` (client-sharded
    Eq.-7 scoring).

    ``exchange_every`` = k > 1 (with ``do_federate``) restructures the scan
    into SEGMENTS: an outer scan over groups of k sub-rounds whose body
    runs k-1 train-only steps plus one train+exchange step on the group's
    last sub-round (its own R-batch is the probe batch, exactly the
    oracle's ``_recent``), then a train-only scan over the ``n_sub % k``
    leftover rounds.  No ``lax.cond`` around collectives — the cadence is
    static, so the mesh path segments identically on every device.  k=1
    traces the historical flat scan unchanged (the bit-identity pin).

    ``admission`` (a norm bound, or None) forwards to
    :func:`_policy_round_body`'s pool admission guard; when set, the epoch
    function returns ONE extra trailing output — the stacked
    ``(exchange_rounds, C)`` bool per-opportunity rejection mask.

    ``trust`` (a :class:`~repro.core.trust.TrustPlan`, or None) threads the
    trust layer through the scan.  The epoch function then takes ONE extra
    trailing runtime argument ``trust_arrays`` — the watermark signature
    stack (C, nf, ...) under ``trust.watermark``, the host-derived
    ``(net_masks, correction)`` pair (leading axis = this epoch's exchange
    rounds, consumed as an extra scan leg) under ``trust.secure_agg``, an
    ignored dummy under DP-only — and returns one extra trailing output
    AFTER the admission mask: the stacked ``((rounds, C) clip, (rounds, C)
    wm_failed)`` bool pair.  Secure aggregation replaces the per-client
    selection scan with ``trust.secure_round`` (masked mean transfer — the
    pool stores masked payloads, ``chosen`` is all -1).  ``trust=None``
    traces the byte-identical pre-trust graph (the bit-identity pin,
    mirroring ``faults=None``).

    ``telemetry`` (a :class:`~repro.core.telemetry.TelemetryPlan` with
    ``rounds`` on, or None) threads the in-graph metrics carry through the
    scan: the epoch function returns one extra LAST output — the stacked
    per-exchange-round series ``((rounds, C) foreign-pick counts,
    (rounds, C) score_min, (rounds, C) score_mean, (rounds, C) pool_age
    snapshots)`` — still inside the same single dispatch.  Unpack order at
    every call site: telemetry pops FIRST (it is appended last), then
    trust, then admission.  ``telemetry=None`` traces the byte-identical
    pre-instrumentation graph."""
    opt = adam(lr)
    step = jax.vmap(functools.partial(_train_step, opt))
    evaluate = jax.vmap(_eval_mse)
    bounded = policies.pool.bounded
    k_ex = int(exchange_every)
    secure = trust is not None and trust.secure_agg is not None
    # secure masks ride the scan as an extra xs leg only when the scan
    # actually exchanges; a do_federate=False dispatch ignores them
    secure_in_scan = secure and do_federate
    sel_trust = None if secure else trust
    if gather is None:
        gather = lambda t: t
    if local_rows is None:
        local_rows = lambda t: t

    def epoch(params, opt_state, pool_heads, pool_age, key, best_val,
              best_params, xs_r, xd_r, y_r, active, val_xs, val_xd, val_y,
              trust_arrays=None):
        C = active.shape[0]
        n_sub = y_r.shape[0]

        def body(carry, batch):
            params, opt_state, pool_heads, pool_age, key = carry
            if secure_in_scan:
                (xs_b, xd_b, y_b), (mask_e, corr_e) = batch
            else:
                xs_b, xd_b, y_b = batch
            if do_federate and not secure:  # secure needs no probe gathers
                xd_g, y_g = gather(xd_b), gather(y_b)   # overlaps the step
            params, opt_state, _ = step(params, opt_state, xs_b, xd_b, y_b)
            if do_federate:
                if bounded:
                    pool_age = pool_age + 1
                key, sub = jax.random.split(key)
                if secure:
                    (new_heads, pool_heads, pool_age, chosen, rej,
                     clip) = TR.secure_round(
                        gather(params["heads"]), pool_heads, pool_age,
                        active, mask_e, corr_e, sub, sa=trust.secure_agg,
                        dp=trust.dp, nf=nf, admission=admission)
                    tstats = (clip, jnp.zeros((C,), bool))
                else:
                    out = _policy_round_body(
                        gather(params["heads"]), pool_heads, pool_age,
                        xd_g, y_g, active, sub, nf=nf,
                        policies=policies, use_kernel=use_kernel,
                        shard=shard, admission=admission, trust=sel_trust,
                        trust_sig=(trust_arrays if sel_trust is not None
                                   and sel_trust.watermark is not None
                                   else None), telemetry=telemetry)
                    if telemetry is not None:
                        scores = out[-1]
                        out = out[:-1]
                    if trust is not None:
                        tstats = out[-1]
                        out = out[:-1]
                    if admission is not None:
                        new_heads, pool_heads, pool_age, chosen, rej = out
                    else:
                        new_heads, pool_heads, pool_age, chosen = out
                params = {**params, "heads": local_rows(new_heads)}
            else:
                chosen = jnp.full((C, nf), -1, jnp.int32)
                if admission is not None:
                    rej = jnp.zeros((C,), bool)
                if trust is not None:
                    tstats = (jnp.zeros((C,), bool), jnp.zeros((C,), bool))
            if telemetry is not None:
                if not do_federate or secure:
                    # a non-exchanging (or masked secure) round scores
                    # nothing: the series carry the inf/0 sentinels
                    scores = (jnp.full((C,), jnp.inf, jnp.float32),
                              jnp.zeros((C,), jnp.float32))
                tele_r = (jnp.sum(chosen >= 0, axis=-1).astype(jnp.int32),
                          scores[0], scores[1], pool_age)
            ys = (chosen,)
            if admission is not None:
                ys = ys + (rej,)
            if trust is not None:
                ys = ys + (tstats,)
            if telemetry is not None:
                ys = ys + (tele_r,)
            if len(ys) == 1:
                ys = ys[0]
            return (params, opt_state, pool_heads, pool_age, key), ys

        def train_only(carry, batch):
            params, opt_state, pool_heads, pool_age, key = carry
            xs_b, xd_b, y_b = batch
            params, opt_state, _ = step(params, opt_state, xs_b, xd_b, y_b)
            return (params, opt_state, pool_heads, pool_age, key), None

        carry = (params, opt_state, pool_heads, pool_age, key)
        if not do_federate or k_ex == 1:
            # the historical flat scan — one (train, exchange?) step per
            # sub-round; exchange_every=1 must stay bit-identical to it
            xs = (xs_r, xd_r, y_r)
            if secure_in_scan:
                xs = (xs, trust_arrays)
            carry, ys = jax.lax.scan(body, carry, xs)
        else:
            n_grp, rem = divmod(n_sub, k_ex)
            grouped = jax.tree_util.tree_map(
                lambda t: t[:n_grp * k_ex].reshape(
                    (n_grp, k_ex) + t.shape[1:]),
                (xs_r, xd_r, y_r))

            def group(carry, batch_k):
                # k-1 train-only rounds, then train + exchange on the
                # group's LAST round (probes = that round's own R-batch)
                if secure_in_scan:
                    batch_k, masks_e = batch_k
                carry, _ = jax.lax.scan(
                    train_only, carry,
                    jax.tree_util.tree_map(lambda t: t[:k_ex - 1], batch_k))
                last = jax.tree_util.tree_map(lambda t: t[k_ex - 1], batch_k)
                if secure_in_scan:
                    last = (last, masks_e)
                return body(carry, last)

            xs = (grouped, trust_arrays) if secure_in_scan else grouped
            carry, ys = jax.lax.scan(group, carry, xs)
            if rem:                       # leftover rounds never exchange
                carry, _ = jax.lax.scan(
                    train_only, carry,
                    jax.tree_util.tree_map(lambda t: t[n_grp * k_ex:],
                                           (xs_r, xd_r, y_r)))
        if telemetry is not None:
            tele = ys[-1]
            ys = ys[:-1]
            if len(ys) == 1:
                ys = ys[0]
        else:
            tele = None
        if admission is not None and trust is not None:
            chosen, rejected, tstats = ys
        elif admission is not None:
            chosen, rejected = ys
            tstats = None
        elif trust is not None:
            chosen, tstats = ys
            rejected = None
        else:
            chosen, rejected, tstats = ys, None, None
        (params, opt_state, pool_heads, pool_age, key) = carry
        if do_eval:
            v = evaluate(params, val_xs, val_xd, val_y)  # (local clients,)
            improved = v < best_val
            best_val = jnp.where(improved, v, best_val)
            n_loc = v.shape[0]
            best_params = jax.tree_util.tree_map(
                lambda b, p: jnp.where(
                    improved.reshape((n_loc,) + (1,) * (p.ndim - 1)), p, b),
                best_params, params)
        else:
            v = None
        out = (params, opt_state, pool_heads, pool_age, key, best_val,
               best_params, v, chosen)
        if admission is not None:
            out = out + (rejected,)
        if trust is not None:
            out = out + (tstats,)
        if telemetry is not None:
            out = out + (tele,)
        return out

    return epoch


@functools.lru_cache(maxsize=None)
def _make_epoch_fn(lr: float, nf: int, policies: FederationPolicies,
                   use_kernel: bool, do_federate: bool, do_eval: bool,
                   exchange_every: int = 1, admission=None, trust=None,
                   telemetry=None):
    """Compile-cached whole-epoch function: ONE dispatch scans every
    sub-round of an epoch — the vmapped Adam step on that round's R-slice,
    then the fused policy round (selection, blend, publish, aging, RNG
    fold-in) — and, when ``do_eval``, folds the per-epoch validation eval
    and the save-best ``where``-merge into the same compiled function.
    The computation itself is :func:`_epoch_body` with identity exchange
    hooks; the client-sharded twin wraps the same body in ``shard_map``
    (``mesh_federation._make_mesh_epoch_fn``).

    The whole carried state (stacked params, opt state, pool, ages, PRNG
    key, best-val, best-params) is DONATED, so XLA reuses the stacked
    buffers across epochs instead of copying them every dispatch.  The
    per-round ``chosen`` indices come back stacked ``(n_rounds, C, nf)``
    as a scan output: selection traces materialize in one device-to-host
    transfer per epoch, not one per round.

    The cache key is the trace-relevant statics — (lr, nf, policies,
    use_kernel, do_federate, do_eval, exchange_every); jit itself caches
    per shape, so one factory entry serves every (C, n_rounds, R)
    geometry.  The chunked fallback (per-round callbacks) dispatches the
    same function over 1-round slices with ``do_eval`` only on the last
    chunk and the exchange cadence applied through per-round
    ``do_federate`` gating (a non-exchange round IS a ``do_federate=False``
    round)."""
    epoch = _epoch_body(lr, nf, policies, use_kernel, do_federate, do_eval,
                        exchange_every=exchange_every, admission=admission,
                        trust=trust, telemetry=telemetry)
    return jax.jit(epoch, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


def _is_homogeneous(clients: Sequence[FederatedClient]) -> bool:
    """The single-stack fast path's precondition: every client has the same
    feature count nf AND identical train/valid/test array shapes (the
    per-client state is stacked on a leading axis and scanned as one
    geometry).  Mixed populations no longer error — they route through the
    cohort engine (``repro.core.cohorts``), which partitions them into
    homogeneous cohorts and exchanges heads through a padded union pool."""
    nf = clients[0].nf
    shapes = [tuple(np.shape(a) for a in c.train) for c in clients]
    return (all(c.nf == nf for c in clients) and len(set(shapes)) == 1
            and len({tuple(np.shape(a) for a in c.valid)
                     for c in clients}) == 1
            and len({tuple(np.shape(a) for a in c.test)
                     for c in clients}) == 1)


def _fit_batched(fed: "Federation", n_epochs: int, cbs) -> None:
    """The batched executor: stack the population, scan whole epochs inside
    one compiled dispatch (see :func:`_make_epoch_fn`), and — when the
    Federation carries a multi-device mesh — run that same scan client-
    sharded under ``shard_map`` (see ``repro.core.mesh_federation``).
    Heterogeneous populations (mixed nf / ragged split lengths) route
    through the cohort engine (``repro.core.cohorts._fit_cohorted``), which
    reproduces the same oracle semantics via per-cohort stacks and a padded
    union pool.  Writes results back into the clients via :func:`sync` and
    fills ``fed.dispatch_stats``."""
    clients = fed.clients
    if not _is_homogeneous(clients):
        from repro.core import cohorts
        cohorts._fit_cohorted(fed, n_epochs, cbs)
        return
    C = len(clients)
    names = [c.name for c in clients]
    nf = clients[0].nf
    cfg, pol = fed.cfg, fed.policies
    R = fed.schedule.R

    xs = jnp.stack([np.asarray(c.train[0]) for c in clients])
    xd = jnp.stack([np.asarray(c.train[1]) for c in clients])
    y = jnp.stack([np.asarray(c.train[2]) for c in clients])
    val = tuple(jnp.stack([np.asarray(c.valid[k]) for c in clients])
                for k in range(3))
    n = int(y.shape[1])
    n_sub = fed.schedule.sub_rounds(n)

    def rounds_axis(t):
        """(C, n, ...) -> (n_sub, C, R, ...): the schedule's R-slices stacked
        on a leading scan axis (the slices are contiguous from 0, so this is
        a reshape + transpose, done once per fit)."""
        m = n_sub * R
        return jnp.moveaxis(
            t[:, :m].reshape((C, n_sub, R) + t.shape[2:]), 1, 0)

    xs_r, xd_r, y_r = rounds_axis(xs), rounds_axis(xd), rounds_axis(y)

    params = _stack_trees([c.params for c in clients])
    opt_state = _stack_trees([c.opt_state for c in clients])
    # pool state comes from the canonical HeadPool (a fresh fit sees the
    # initial publication; a restored fit sees the checkpointed pool)
    pool_heads = stack_pool(fed.pool, names, nf)
    pool_age = jnp.asarray([fed.pool.age_of(n_) for n_ in names], jnp.int32)
    use_kernel = cfg.use_pool_kernel and pool_kernel_available()
    lut = _selection_lut(names, nf)
    admission = fed._admission()
    smask = fed._straggler_mask
    trust = fed._trust
    secure = trust is not None and trust.secure_agg is not None
    # telemetry layer (core/telemetry.py): `tele` is the enabled plan iff
    # its in-graph per-round series is on (a static jit argument, so
    # tele=None traces the byte-identical pre-instrumentation graph); `rec`
    # is the host-side flight recorder (spans + counters + round events)
    tele = fed._tele_rounds()
    rec = fed._recorder
    # host templates/derivations the trust layer needs (captured before the
    # stacked state is donated away)
    head_tmpl = jax.tree_util.tree_map(
        np.asarray, clients[0].params["heads"]) if secure else None
    sig_stack = None
    if trust is not None and trust.watermark is not None:
        sig_stack = jax.tree_util.tree_map(
            jnp.asarray,
            TR.stack_trees_np([fed._wm_sig(c) for c in clients]))
    clip_total = 0
    wm_fail = np.zeros(C, np.int64)
    dp_pubs = np.zeros(C, np.int64)
    heads_rejected = 0
    k_ex = fed.schedule.exchange_every
    exch_mask = fed.schedule.exchange_mask(n_sub)
    n_exch_epoch = fed.schedule.exchanges(n_sub)
    exchange_rounds = 0
    pool_bytes = 0
    # per-device bytes one mesh exchange round moves (0 on a single device)
    heads_bytes = _tree_bytes(pool_heads)
    probe_bytes = C * R * (nf * cfg.w + 1) * 4
    exch_bytes = _exchange_round_bytes(
        MF.mesh_devices(fed._exec_mesh()), heads_bytes, probe_bytes,
        C, nf, C * nf, pol.selection) if fed._exec_mesh() is not None else 0

    histories = [list(c.val_history) for c in clients]
    best_val = jnp.asarray([c.best_val for c in clients], jnp.float32)
    best_params = _stack_trees([c.best_params for c in clients])
    # device-resident learnable state for this fit (the participation
    # orchestrator's gather/scatter unit and its bounded-working-set meter)
    state_bytes = (_tree_bytes(params) + _tree_bytes(opt_state)
                   + _tree_bytes(best_params))
    n_rounds = np.zeros(C, np.int64)
    base_rounds = dict(fed.n_rounds)
    key = fed._key

    # client-sharded execution: with a multi-device mesh the stacked state
    # is partitioned over the `clients` axis once per fit (subsequent
    # epochs carry the shardings through the donated outputs) and the
    # epoch function is the shard_map twin of _make_epoch_fn
    mesh = fed._exec_mesh()
    if mesh is not None:
        (params, opt_state, pool_heads, pool_age, key, best_val,
         best_params, (xs_r, xd_r, y_r), val) = MF.shard_fit_state(
            mesh, nf, cfg.w, C, params=params, opt_state=opt_state,
            pool_heads=pool_heads, pool_age=pool_age, key=key,
            best_val=best_val, best_params=best_params,
            rounds_data=(xs_r, xd_r, y_r), val_data=val)

    def make_epoch_fn(do_federate: bool, do_eval: bool,
                      exchange_every: int = 1):
        if mesh is not None:
            return MF._make_mesh_epoch_fn(cfg.lr, nf, cfg.w, pol,
                                          use_kernel, do_federate, do_eval,
                                          mesh, C, exchange_every,
                                          admission, trust, tele)
        return _make_epoch_fn(cfg.lr, nf, pol, use_kernel, do_federate,
                              do_eval, exchange_every, admission, trust,
                              tele)

    def trust_args(active, n_exch: int, e_off: int = 0):
        """The epoch function's trailing ``trust_arrays`` argument for one
        dispatch: the replicated signature stack (watermark), the wave's
        ``(net_masks, correction)`` pair covering ``n_exch`` exchange
        rounds starting at within-epoch round ``e_off`` (secure), or a
        scalar dummy (DP-only).  Returns () when the trust layer is off."""
        if trust is None:
            return ()
        if secure:
            wave = fed._trust_wave_base + fed.epoch
            masks = TR.net_masks(trust.secure_agg, wave, n_exch,
                                 fed._trust_ids, head_tmpl,
                                 round_offset=e_off)
            corr = TR.mask_correction(masks, active)
            ta = jax.tree_util.tree_map(jnp.asarray, (masks, corr))
        elif sig_stack is not None:
            ta = sig_stack
        else:
            ta = jnp.zeros((), jnp.float32)
        if mesh is not None:
            ta = MF.replicate(mesh, ta)
        return (ta,)

    # the fused path runs the whole epoch in ONE dispatch; any callback that
    # needs per-round delivery forces the chunked path (one dispatch per
    # sub-round through the SAME compiled function, on_round after each)
    fused = not any(_wants_per_round(cb) for cb in cbs)
    n_dispatch = 0

    def account_trust(tstats, rej, active, federated: bool, n_exch: int):
        """Fold one dispatch's trust outputs into the fit's counters: clip
        events, per-client watermark failures, and the DP release count —
        publications actually made (active exchange opportunities minus
        watermark-blocked minus admission-rejected; the three are disjoint
        by the in-graph publication chain)."""
        nonlocal clip_total
        if trust is None:
            return
        clip_r, wmf_r = (np.asarray(t) for t in tstats)
        clip_total += int(clip_r.sum())
        wmf_pc = wmf_r.reshape(-1, C).sum(axis=0).astype(np.int64)
        wm_fail[:] += wmf_pc
        if trust.dp is not None and federated:
            rej_pc = (np.asarray(rej).reshape(-1, C).sum(axis=0)
                      if rej is not None else np.zeros(C, np.int64))
            dp_pubs[:] += (active.astype(np.int64) * n_exch
                           - wmf_pc - rej_pc)

    def sync():
        """Write the stacked loop state back into the clients / pool / rng —
        run after the loop, and on demand when a callback checkpoints the
        federation mid-fit (Federation.save calls this hook)."""
        ages = np.asarray(pool_age)
        bv = np.asarray(best_val)
        for i, c in enumerate(clients):
            c.params = _tree_row(params, i)
            c.opt_state = _tree_row(opt_state, i)
            c.val_history = histories[i]
            c.best_val = float(bv[i])
            c.best_params = _tree_row(best_params, i)
            fed.pool.publish(c.name, _tree_row(pool_heads, i), nf,
                             age=int(ages[i]))
            fed.n_rounds[c.name] = base_rounds[c.name] + int(n_rounds[i])
        fed._key = key

    fed._sync = sync
    for _ in range(n_epochs):
        epoch = fed.epoch
        active = np.asarray(pol.switch.active_mask(histories,
                                                   fed._switch_rng))
        if smask is not None:   # stragglers train but miss every exchange
            active = active & ~np.asarray(smask, bool)
        active_dev = jnp.asarray(active)
        if mesh is not None:
            active_dev = MF.replicate(mesh, active_dev)
        do_federate = bool(active.any()) and C >= 2
        state = (params, opt_state, pool_heads, pool_age, key, best_val,
                 best_params)
        fed._mid_epoch = True
        if fused:
            epoch_fn = make_epoch_fn(do_federate, True, k_ex)
            with TEL.span(rec, "dispatch", epoch=epoch, path="fused"):
                out = epoch_fn(*state, xs_r, xd_r, y_r, active_dev, *val,
                               *trust_args(active, n_exch_epoch))
            if tele is not None:   # telemetry rides LAST: pop it first
                tele_out, out = out[-1], out[:-1]
            if trust is not None:
                tstats, out = out[-1], out[:-1]
            if admission is not None:
                (*state, v, chosen, rej) = out
                heads_rejected += int(np.asarray(rej).sum())
            else:
                (*state, v, chosen) = out
                rej = None
            account_trust(tstats, rej, active, do_federate,
                          n_exch_epoch) if trust is not None else None
            n_dispatch += 1
        else:
            chunks = []
            tele_chunks = []
            e_done = 0          # exchange rounds executed so far this epoch
                                # (the trust layer's within-epoch mask index)
            for rnd in range(n_sub):
                # cadence on the chunked path: a non-exchange sub-round is
                # exactly a do_federate=False dispatch (train + eval only)
                fed_r = do_federate and bool(exch_mask[rnd])
                epoch_fn = make_epoch_fn(fed_r, rnd == n_sub - 1)
                with TEL.span(rec, "dispatch", epoch=epoch, round=rnd,
                              path="chunked"):
                    out = epoch_fn(
                        *state, xs_r[rnd:rnd + 1], xd_r[rnd:rnd + 1],
                        y_r[rnd:rnd + 1], active_dev, *val,
                        *trust_args(active, 1 if fed_r else 0, e_done))
                if tele is not None:
                    tele_chunks.append(out[-1])
                    out = out[:-1]
                if trust is not None:
                    tstats, out = out[-1], out[:-1]
                if admission is not None:
                    (*state, v, ch, rej) = out
                    heads_rejected += int(np.asarray(rej).sum())
                else:
                    (*state, v, ch) = out
                    rej = None
                account_trust(tstats, rej, active, fed_r,
                              1 if fed_r else 0) if trust is not None \
                    else None
                if fed_r:
                    e_done += 1
                chunks.append(ch)
                n_dispatch += 1
                # sync the carried state (and the live round counters)
                # before handing control to the callback so a mid-epoch
                # reader sees current state, as on the sequential engine
                (params, opt_state, pool_heads, pool_age, key, best_val,
                 best_params) = state
                if active.any() and exch_mask[rnd]:
                    n_rounds += active
                for cb in cbs:
                    cb.on_round(fed, epoch, rnd)
            if n_sub == 0:      # no trainable sub-round: eval-only dispatch
                epoch_fn = make_epoch_fn(do_federate, True)
                with TEL.span(rec, "dispatch", epoch=epoch,
                              path="eval-only"):
                    out = epoch_fn(*state, xs_r, xd_r, y_r, active_dev,
                                   *val, *trust_args(active, 0))
                if tele is not None:
                    out = out[:-1]
                if trust is not None:
                    out = out[:-1]
                if admission is not None:
                    (*state, v, ch, _rej) = out
                else:
                    (*state, v, ch) = out
                chunks.append(ch)
                n_dispatch += 1
            chosen = jnp.concatenate(chunks) if chunks else None
            tele_out = tuple(
                np.concatenate([np.asarray(t[k]) for t in tele_chunks])
                for k in range(4)) if tele is not None and tele_chunks \
                else None
        (params, opt_state, pool_heads, pool_age, key, best_val,
         best_params) = state
        with TEL.span(rec, "exchange", epoch=epoch):
            if do_federate:
                # ONE device->host materialization of the epoch's selections
                for ch in np.asarray(chosen):
                    for i in range(C):
                        if active[i] and ch[i][0] >= 0:
                            fed.selections[names[i]].append(
                                lut[i, ch[i]].tolist())
            if tele is not None and tele_out is not None:
                rec.record_epoch_rounds(epoch, tele_out, active)
        if fused and active.any():   # chunked path counted per round above
            n_rounds += active * n_exch_epoch
        if rec is not None and active.any():
            rec.count("client_rounds", int(active.sum()) * n_exch_epoch)
        # refresh the live counters each epoch (idempotent with sync(), a
        # handful of host ints) so epoch-boundary readers — VerboseLogger's
        # throughput line — see current round counts without a device sync
        for i, nm in enumerate(names):
            fed.n_rounds[nm] = base_rounds[nm] + int(n_rounds[i])
        if do_federate:
            exchange_rounds += n_exch_epoch
            pool_bytes += n_exch_epoch * exch_bytes
        v = np.asarray(v, np.float64)
        for i in range(C):
            histories[i].append(float(v[i]))
        fed.epoch += 1
        fed._mid_epoch = False
        for cb in cbs:
            cb.on_epoch_end(fed, epoch,
                            {names[i]: float(v[i]) for i in range(C)},
                            {names[i]: bool(active[i]) for i in range(C)})

    if trust is not None:
        fed._clip_events += clip_total
        for i, nm in enumerate(names):
            if wm_fail[i]:
                fed._wm_failures[nm] = (fed._wm_failures.get(nm, 0)
                                        + int(wm_fail[i]))
            if dp_pubs[i]:
                fed._dp_counts[nm] = (fed._dp_counts.get(nm, 0)
                                      + int(dp_pubs[i]))
    if rec is not None:
        # fold this fit's in-graph counters into the flight recorder so an
        # exported trace carries them even when dispatch_stats is later
        # overwritten (the participation orchestrator re-aggregates waves)
        if heads_rejected:
            rec.count("heads_rejected", int(heads_rejected))
        if trust is not None:
            if clip_total:
                rec.count("clip_events", int(clip_total))
            if wm_fail.sum():
                rec.count("watermark_failures", int(wm_fail.sum()))
    fed.dispatch_stats = {"engine": "batched",
                          "path": "fused" if fused else "chunked",
                          "devices": MF.mesh_devices(mesh),
                          "cohorts": 1,
                          "epochs": n_epochs, "dispatches": n_dispatch,
                          "dispatches_per_epoch": n_dispatch / n_epochs,
                          "exchange_every": k_ex,
                          "exchange_rounds": exchange_rounds,
                          "pool_bytes_gathered": pool_bytes,
                          "state_bytes": state_bytes,
                          **fed._fault_stats(heads_rejected),
                          **fed._trust_stats()}
    # write the final state back so the clients / pool / rng stay canonical
    sync()
    fed._sync = None


# ---------------------------------------------------------------------------
# Federation
# ---------------------------------------------------------------------------

def _client_data_shapes(c: FederatedClient):
    """JSON-comparable split shapes, checked at restore time so a client
    rebuilt from different pipeline arguments fails fast, not inside jit."""
    return [[list(np.shape(a)) for a in split]
            for split in (c.train, c.valid, c.test)]


class Federation:
    """A resumable federated-training run: clients + policies + schedule +
    callbacks + all mutable state (pool, RNG streams, counters).

    ``fit()`` trains up to ``schedule.epochs``; ``fit(epochs=k)`` trains k
    MORE epochs from wherever the federation currently is.  ``save(dir)`` /
    ``restore(dir, clients)`` round-trip the full state through
    ``repro.checkpoint`` (data is NOT checkpointed — rebuild the clients the
    same way, then restore overlays params/opt/pool/rng/histories).

    ``engine="batched"`` accepts heterogeneous populations transparently:
    mixed feature counts and ragged split lengths are partitioned into
    homogeneous cohorts by ``repro.core.cohorts`` (an internal planning
    step surfaced in ``dispatch_stats["cohorts"]``/``["per_cohort"]``),
    trained per-cohort at native geometry inside one fused dispatch per
    epoch, and federated through a padded union head pool — selections
    identical to the sequential oracle.

    ``mesh`` (batched engine only) opts into client-sharded execution: a
    1-D :class:`jax.sharding.Mesh` with a ``clients`` axis
    (:func:`repro.core.mesh_federation.make_mesh`) partitions the stacked
    population over its devices — device-local Adam steps, explicit
    all-gather pool exchange per sub-round, selections identical to the
    single-device engine.  A one-device mesh falls back to the plain
    single-device fused path automatically.  On a heterogeneous
    population every cohort's size must divide the device count (checked
    at fit time)."""

    def __init__(self, clients: Sequence[FederatedClient],
                 cfg: Optional[HFLConfig] = None, *,
                 policies: Optional[FederationPolicies] = None,
                 schedule: Optional[RoundSchedule] = None,
                 callbacks: Sequence[Callback] = (),
                 engine: str = "sequential",
                 mesh=None, faults=None, trust=None, telemetry=None):
        if engine not in ("sequential", "batched"):
            raise ValueError(f"unknown engine {engine!r}")
        self.clients = list(clients)
        if mesh is not None:
            if engine != "batched":
                raise ValueError(
                    "mesh= requires engine='batched' (the sequential "
                    "oracle is a host-driven reference loop)")
            MF.validate_mesh(mesh, len(self.clients))
        self.mesh = mesh
        names = [c.name for c in self.clients]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate client names: {names}")
        if cfg is None:
            cfg = self.clients[0].cfg if self.clients else HFLConfig()
        self.cfg = cfg
        self.policies = policies if policies is not None \
            else FederationPolicies.from_config(cfg)
        self.schedule = schedule or RoundSchedule(cfg.epochs, cfg.R)
        self.callbacks = list(callbacks)
        self.engine = engine
        self.epoch = 0
        self.n_rounds: Dict[str, int] = {n: 0 for n in names}
        self.selections: Dict[str, list] = {n: [] for n in names}
        # fault-tolerance layer (core/faults.py): an *enabled* FaultPlan
        # arms the pool admission guard; a disabled plan (all rates zero)
        # or None keeps every engine bit-identical to a fault-free build
        self.faults = faults
        # trust layer (core/trust.py): an *enabled* TrustPlan arms masked
        # secure aggregation / DP releases / watermark verification; a
        # disabled plan or None keeps every engine bit-identical to a
        # trust-free build (the same contract as faults=None)
        if trust is not None and not isinstance(trust, TR.TrustPlan):
            raise TypeError(f"trust: expected a TrustPlan, "
                            f"got {type(trust).__name__}")
        self.trust = trust
        self._trust = trust if trust is not None and trust.enabled else None
        # telemetry layer (core/telemetry.py): an *enabled* TelemetryPlan
        # arms the in-graph per-round metrics carry and the host-side
        # flight recorder; a disabled plan or None keeps every engine
        # bit-identical to an uninstrumented build (same contract as
        # faults=None / trust=None)
        if telemetry is not None \
                and not isinstance(telemetry, TEL.TelemetryPlan):
            raise TypeError(f"telemetry: expected a TelemetryPlan, "
                            f"got {type(telemetry).__name__}")
        self.telemetry = telemetry
        self._telemetry = telemetry if telemetry is not None \
            and telemetry.enabled else None
        self._recorder = (TEL.FlightRecorder(self._telemetry)
                          if self._telemetry is not None else None)
        # wave/identity context the participation orchestrator overrides so
        # trust derivations (masks, oracle DP noise) key on GLOBAL client
        # ids and the wave counter, not per-wave positions
        self._trust_wave_base = 0
        self._trust_ids = tuple(range(len(self.clients)))
        self._dp_counts: Dict[str, int] = {}
        self._wm_failures: Dict[str, int] = {n: 0 for n in names}
        self._clip_events = 0
        self._wm_sigs: Dict[str, object] = {}
        # (C,) bool poked by the participation orchestrator before fit():
        # True rows are this wave's stragglers (they train, never exchange)
        self._straggler_mask = None
        self._seed_rejected = 0
        wm = self._trust.watermark if self._trust is not None else None
        if wm is not None:
            # embed/top-up every client's OWN signature before anything is
            # published — the no-heal rule leaves an already-flipped head
            # (projection at -strength) untouched, so corruption that
            # happened upstream stays detectable
            for c in self.clients:
                new_h, _ = TR.wm_embed(c.params["heads"], self._wm_sig(c),
                                       wm)
                c.params = dict(c.params)
                c.params["heads"] = new_h
        self.pool = HeadPool()
        admission = self._admission()
        for c in self.clients:   # asynchronous start: pool is never empty
            if self._trust is not None \
                    and self._trust.secure_agg is not None:
                # under secure aggregation no raw head may ever reach the
                # pool — the seed rows are zeros (the first masked round
                # overwrites them with masked payloads)
                self.pool.publish(c.name,
                                  FT.zero_heads_like(c.params["heads"]),
                                  c.nf)
            elif wm is not None and not TR.wm_verify_host(
                    c.params["heads"], self._wm_sig(c), wm):
                # a seed head that fails its own signature was tampered
                # with before this federation saw it (the sign-flip
                # fingerprint): quarantine the row, count the failure
                self.pool.publish(c.name,
                                  FT.zero_heads_like(c.params["heads"]),
                                  c.nf, age=FT.QUARANTINE_AGE)
                self._wm_failures[c.name] += 1
            elif admission is not None and not FT.heads_admissible(
                    c.params["heads"], admission):
                # quarantine a poisoned seed head: publish a zeroed row at
                # the sentinel age so no selector ever sees it (a clean
                # republication later revives the row at age 0)
                self.pool.publish(c.name,
                                  FT.zero_heads_like(c.params["heads"]),
                                  c.nf, age=FT.QUARANTINE_AGE)
                self._seed_rejected += 1
            else:
                self.pool.publish(c.name, c.params["heads"], c.nf)
        self._sel_rng = np.random.default_rng(cfg.seed)
        self._switch_rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 0x5F]))
        self._key = jax.random.PRNGKey(cfg.seed)
        self._sync = None       # set by the batched executor while it runs
        self._mid_epoch = False  # True inside an epoch: save() would be torn
        # {engine, path, devices, epochs, dispatches, dispatches_per_epoch}
        # for the most recent fit: "fused" = one compiled dispatch per
        # epoch, "chunked" = one per sub-round (per-round callbacks
        # present), "per-round" = the sequential oracle's per-client
        # dispatch pattern; devices = mesh devices actually sharded over
        # (1 on the single-device path)
        self.dispatch_stats: Optional[dict] = None

    def _exec_mesh(self):
        """The mesh the batched executor actually shards over: None when no
        mesh was given OR the mesh has one device — the single-device fused
        path runs then (selection-identical, zero shard_map overhead)."""
        if self.mesh is not None and MF.mesh_devices(self.mesh) > 1:
            return self.mesh
        return None

    def _admission(self) -> Optional[float]:
        """The pool admission guard's norm bound, or None when the guard is
        off (no FaultPlan, or a disabled all-zero plan — the engines then
        trace exactly the fault-free computation)."""
        if self.faults is not None and self.faults.enabled:
            return float(self.faults.norm_bound)
        return None

    def _tele_rounds(self):
        """The TelemetryPlan the epoch factories receive as their static
        telemetry argument — the enabled plan iff its in-graph per-round
        series is on, else None (the factories then trace exactly the
        uninstrumented computation)."""
        if self._telemetry is not None and self._telemetry.rounds:
            return self._telemetry
        return None

    def _fault_stats(self, heads_rejected: int) -> dict:
        """The fault counters every engine folds into ``dispatch_stats``.
        Dropout / wave degradation happen a layer up (the participation
        orchestrator re-rounds wave geometry before this Federation even
        exists), so a plain Federation reports zeros there and the
        orchestrator overwrites them with wave-aggregated counts."""
        smask = self._straggler_mask
        return {"heads_rejected": int(heads_rejected)
                + int(self._seed_rejected),
                "clients_dropped": 0,
                "stragglers": 0 if smask is None else int(np.sum(smask)),
                "waves_degraded": 0}

    def _wm_sig(self, c: FederatedClient):
        """The client's cached watermark signature tree (a pure function of
        the watermark seed and the client NAME, so it is identical across
        engines, waves and restores)."""
        if c.name not in self._wm_sigs:
            self._wm_sigs[c.name] = TR.signature(
                self._trust.watermark, c.name,
                jax.tree_util.tree_map(np.asarray, c.params["heads"]))
        return self._wm_sigs[c.name]

    def _trust_stats(self) -> dict:
        """The trust counters every engine folds into ``dispatch_stats``:
        ``epsilon_spent`` is the worst per-client analytic (eps, delta)
        bound over all DP releases so far (cumulative across fits),
        ``clip_events`` / ``watermark_failures`` the cumulative event
        counts.  All zero when the trust layer is off."""
        t = self._trust
        eps = 0.0
        if t is not None and t.dp is not None:
            eps = max((t.dp.epsilon(v) for v in self._dp_counts.values()),
                      default=0.0)
        return {"epsilon_spent": float(eps),
                "clip_events": int(self._clip_events),
                "watermark_failures": int(sum(self._wm_failures.values()))}

    # -- training ----------------------------------------------------------

    def fit(self, epochs: Optional[int] = None, verbose: bool = False):
        """Train `epochs` more epochs (default: up to ``schedule.epochs``
        total) and return the legacy history dict
        {name: {val, test, rounds, best_val, selections}}."""
        target = self.schedule.epochs if epochs is None \
            else self.epoch + epochs
        n = max(0, target - self.epoch)
        cbs = list(self.callbacks)
        if verbose and not any(isinstance(cb, VerboseLogger) for cb in cbs):
            cbs.append(VerboseLogger())
        for cb in cbs:
            cb.on_fit_start(self)
        if n:
            dropped = {c.name: self.schedule.leftover(len(c.train[2]))
                       for c in self.clients}
            dropped = {k: v for k, v in dropped.items() if v}
            if dropped:
                warnings.warn(
                    f"RoundSchedule(R={self.schedule.R}) drops the trailing "
                    f"partial batch every epoch: {dropped} train events per "
                    f"epoch are never trained on (train lengths are not "
                    f"multiples of R); truncate to a multiple of R or pick "
                    f"a divisor R to silence this", UserWarning,
                    stacklevel=2)
            with TEL.span(self._recorder, "fit", epochs=n,
                          engine=self.engine):
                if self.engine == "batched":
                    _fit_batched(self, n, cbs)
                else:
                    _fit_sequential(self, n, cbs)
        results = self.results()
        for cb in cbs:
            cb.on_fit_end(self, results)
        return results

    def results(self):
        """Per-client history in the legacy run_federated_training format."""
        if self._sync is not None:   # mid-fit (batched executor)
            self._sync()
        test = self._test_mses()
        return {c.name: {"val": list(c.val_history),
                         "test": test[c.name],
                         "rounds": self.n_rounds[c.name],
                         "best_val": float(c.best_val),
                         "selections": [list(s) for s in
                                        self.selections[c.name]]}
                for c in self.clients}

    def _test_mses(self) -> Dict[str, float]:
        """Best-params test MSE per client — ONE vmapped dispatch per cohort
        on the batched engine (matching its training-path batching) instead
        of C per-client jit calls.  A homogeneous population is one cohort;
        singleton cohorts fall back to the client's own jitted eval."""
        if self.engine == "batched" and len(self.clients) > 1:
            from repro.core import cohorts
            plan = cohorts.plan_cohorts(self.clients, self.schedule.R)
            _, eval_fn = _make_batched_fns(self.cfg.lr)
            out: Dict[str, float] = {}
            for co in plan.cohorts:
                cl = [self.clients[i] for i in co.members]
                if len(cl) == 1:
                    out[cl[0].name] = cl[0].test_mse()
                    continue
                tst = tuple(jnp.stack([np.asarray(c.test[k]) for c in cl])
                            for k in range(3))
                bp = _stack_trees([c.best_params for c in cl])
                v = np.asarray(eval_fn(bp, *tst), np.float64)
                out.update({c.name: float(v[i]) for i, c in enumerate(cl)})
            return out
        return {c.name: c.test_mse() for c in self.clients}

    # -- persistence -------------------------------------------------------

    def save(self, directory) -> Path:
        """Checkpoint the complete federation state for mid-training resume:
        per-client params/opt/best, the pool (entries + ages), both host RNG
        streams, the device PRNG key, and every counter/history.

        Durable against interrupts: the state tree goes to an epoch-stamped
        file first and the manifest — the commit point, written atomically
        last — is what references it, so a crash anywhere mid-save leaves
        the previously committed checkpoint fully readable.  Only valid at
        an epoch boundary (on_epoch_end / between fits); a mid-epoch save
        from an on_round callback raises."""
        if self._mid_epoch:
            raise RuntimeError(
                "Federation.save is only valid at an epoch boundary "
                "(on_epoch_end or between fits); mid-epoch state has "
                "unlogged selections and an un-advanced epoch counter")
        if self._sync is not None:  # mid-fit (batched executor): pull the
            self._sync()            # stacked loop state into the clients
        d = Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        state = {
            "epoch": self.epoch,   # cross-checked against the manifest so a
                                   # torn pair is detected (belt+braces)
            "clients": [{"params": c.params, "opt_state": c.opt_state,
                         "best_params": c.best_params}
                        for c in self.clients],
            "pool": {f"{u}|{i}": entry
                     for (u, i), entry in self.pool.entries.items()},
            "key": np.asarray(self._key),
        }
        state_name = f"state_{self.epoch:08d}.msgpack"
        ckpt.save(d / state_name, state)
        manifest = {
            "format": 1,
            "state_file": state_name,
            "epoch": self.epoch,
            "engine": self.engine,
            "cfg": dataclasses.asdict(self.cfg),
            "policies": self.policies.spec(),
            "schedule": {"epochs": self.schedule.epochs,
                         "R": self.schedule.R,
                         "exchange_every": self.schedule.exchange_every},
            # informational: the device count the run sharded over.  The
            # checkpointed state itself is mesh-agnostic (gathered to host
            # trees), so a restore may use any mesh — or none.
            "mesh_devices": MF.mesh_devices(self.mesh),
            "names": [c.name for c in self.clients],
            "nf": [c.nf for c in self.clients],
            "data_shapes": [_client_data_shapes(c) for c in self.clients],
            "val_histories": {c.name: c.val_history for c in self.clients},
            "best_val": {c.name: float(c.best_val) for c in self.clients},
            "n_rounds": self.n_rounds,
            "selections": self.selections,
            "pool_ages": {f"{u}|{i}": a
                          for (u, i), a in self.pool.ages.items()},
            "sel_rng": self._sel_rng.bit_generator.state,
            "switch_rng": self._switch_rng.bit_generator.state,
            "faults": (self.faults.spec()
                       if self.faults is not None else None),
            "trust": (self.trust.spec()
                      if self.trust is not None else None),
            # integer counters only — the accountant's state restores
            # bit-identically by construction (epsilons are recomputed
            # analytically from the counts)
            "trust_state": {"dp_counts": self._dp_counts,
                            "wm_failures": self._wm_failures,
                            "clip_events": self._clip_events,
                            "wave_base": self._trust_wave_base,
                            "ids": list(self._trust_ids)},
            "telemetry": (self.telemetry.spec()
                          if self.telemetry is not None else None),
            # the flight recorder's ring buffer + counters + clock offset:
            # a restored run's spans continue the trace monotonically
            "telemetry_state": (self._recorder.to_json()
                                if self._recorder is not None else None),
        }
        # atomic manifest write = the commit; only then prune state files
        # superseded by it (the previous pair stays intact until here)
        tmp = d / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, d / "manifest.json")
        for p in d.glob("state_*.msgpack"):
            if p.name != state_name:
                p.unlink()
        return d

    @classmethod
    def restore(cls, directory, clients: Sequence[FederatedClient], *,
                engine: Optional[str] = None,
                callbacks: Sequence[Callback] = (),
                mesh=None) -> "Federation":
        """Rebuild a saved federation over freshly-constructed clients (the
        data pipeline is re-run by the caller; everything learned/random is
        overlaid from the checkpoint, bit-identically).  ``mesh`` re-shards
        the resumed run over a device mesh — checkpoints are mesh-agnostic,
        so saving from a 4-device run and restoring onto 1 device (or vice
        versa) is bit-identical either way."""
        d = Path(directory)
        manifest = json.loads((d / "manifest.json").read_text())
        names = [c.name for c in clients]
        if names != manifest["names"]:
            raise ValueError(f"client names {names} do not match "
                             f"checkpoint {manifest['names']}")
        nfs = [c.nf for c in clients]
        if nfs != manifest["nf"]:
            raise ValueError(f"client feature counts {nfs} do not match "
                             f"checkpoint {manifest['nf']}")
        shapes = [_client_data_shapes(c) for c in clients]
        if shapes != manifest.get("data_shapes", shapes):
            raise ValueError(
                "client data shapes do not match the checkpoint — rebuild "
                "the clients with the same data pipeline arguments "
                f"(got {shapes}, checkpoint has {manifest['data_shapes']})")
        ck_cfg = manifest["cfg"]
        for c in clients:
            # lr is baked into the client's jitted train step at
            # construction (and w into its schema) — a mismatch would
            # silently resume on the wrong optimizer/model
            if c.cfg.lr != ck_cfg["lr"] or c.cfg.w != ck_cfg["w"]:
                raise ValueError(
                    f"client {c.name!r} was built with lr={c.cfg.lr}, "
                    f"w={c.cfg.w} but the checkpoint has "
                    f"lr={ck_cfg['lr']}, w={ck_cfg['w']} — rebuild the "
                    f"clients with the checkpointed config")
        cfg = HFLConfig(**manifest["cfg"])
        fspec = manifest.get("faults")
        tspec = manifest.get("trust")
        espec = manifest.get("telemetry")
        fed = cls(clients, cfg,
                  policies=FederationPolicies.from_spec(manifest["policies"]),
                  schedule=RoundSchedule(**manifest["schedule"]),
                  callbacks=callbacks,
                  engine=engine or manifest["engine"],
                  mesh=mesh,
                  faults=policy_from_spec(fspec) if fspec else None,
                  trust=policy_from_spec(tspec) if tspec else None,
                  telemetry=policy_from_spec(espec) if espec else None)
        state = ckpt.load(d / manifest.get("state_file", "state.msgpack"))
        if state.get("epoch") != manifest["epoch"]:
            raise ValueError(
                f"checkpoint is torn: state.msgpack is at epoch "
                f"{state.get('epoch')} but manifest.json at "
                f"{manifest['epoch']} (a save was interrupted between the "
                f"two writes) — re-save or fall back to an older checkpoint")
        for c, cs in zip(fed.clients, state["clients"]):
            c.params = cs["params"]
            c.opt_state = cs["opt_state"]
            c.best_params = cs["best_params"]
            c.val_history = list(manifest["val_histories"][c.name])
            c.best_val = float(manifest["best_val"][c.name])
        fed.pool.entries = {
            (k.rsplit("|", 1)[0], int(k.rsplit("|", 1)[1])): entry
            for k, entry in state["pool"].items()}
        fed.pool.ages = {
            (k.rsplit("|", 1)[0], int(k.rsplit("|", 1)[1])): int(a)
            for k, a in manifest["pool_ages"].items()}
        fed.epoch = int(manifest["epoch"])
        fed.n_rounds = {n: int(v) for n, v in manifest["n_rounds"].items()}
        fed.selections = {n: [list(s) for s in v]
                          for n, v in manifest["selections"].items()}
        fed._key = jnp.asarray(state["key"])
        fed._sel_rng.bit_generator.state = manifest["sel_rng"]
        fed._switch_rng.bit_generator.state = manifest["switch_rng"]
        ts = manifest.get("trust_state")
        if ts is not None:
            # the constructor's init-time embedding/seeding side effects
            # were fully overwritten by the params/pool overlays above;
            # the counters below make the accountant/reputation state
            # replay bit-identically
            fed._dp_counts = {k: int(v)
                              for k, v in ts.get("dp_counts", {}).items()}
            fed._wm_failures = {k: int(v)
                                for k, v in ts.get("wm_failures",
                                                   {}).items()}
            fed._clip_events = int(ts.get("clip_events", 0))
            fed._trust_wave_base = int(ts.get("wave_base", 0))
            fed._trust_ids = tuple(int(i) for i in ts.get(
                "ids", range(len(clients))))
        rs = manifest.get("telemetry_state")
        if rs is not None and fed._telemetry is not None:
            fed._recorder = TEL.FlightRecorder.from_json(fed._telemetry, rs)
        return fed


# ---------------------------------------------------------------------------
# Non-federated loop on the shared schedule (benchmark systems)
# ---------------------------------------------------------------------------

def fit_local(step_fn, eval_fn, params, opt_state, train, valid,
              schedule: RoundSchedule, callbacks: Sequence[Callback] = ()):
    """Single-model training on the shared :class:`RoundSchedule` with
    save-best-on-validation (paper §5.2) and the same callback hooks as
    :meth:`Federation.fit` — the benchmark systems' loop.

    ``step_fn(params, opt_state, xs, xd, y) -> (params, opt_state)``;
    ``eval_fn(params, xs, xd, y) -> scalar``.  Returns
    ``(params, opt_state, best_params, best_val)``."""
    xs, xd, y = train
    best_val, best_params = np.inf, params
    for cb in callbacks:
        cb.on_fit_start(None)
    for epoch in range(schedule.epochs):
        for rnd, sl in enumerate(schedule.slices(len(y))):
            params, opt_state = step_fn(params, opt_state,
                                        xs[sl], xd[sl], y[sl])
            for cb in callbacks:
                cb.on_round(None, epoch, rnd)
        v = float(eval_fn(params, *valid))
        if v < best_val:
            best_val, best_params = v, params
        for cb in callbacks:
            cb.on_epoch_end(None, epoch, {"val": v}, {})
    for cb in callbacks:
        cb.on_fit_end(None, {"best_val": best_val})
    return params, opt_state, best_params, best_val
