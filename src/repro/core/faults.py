"""Deterministic fault injection for federated training.

The failure model (docs/SCALING.md "Failure model") has three client fault
classes, drawn per (wave, client) from a seeded plan so any scenario
replays bit-identically across engines, device counts, and restores:

  * **dropout** — the client vanishes for the wave: it is removed from the
    sampled active set before any state is gathered, and the wave's
    geometry is re-rounded (``reround_wave``) so the fused engine never
    sees a ragged stack.
  * **straggler** — the client trains but misses its federated
    opportunities: its switch is masked off for the wave, so it neither
    selects nor publishes, and its pool entry ages under the existing
    bounded-staleness clock exactly as an inactive client's would.
  * **byzantine** — the client's head parameters are corrupted host-side
    before the wave trains (NaN / Inf / exploding-norm / sign-flip).  The
    engines' pool admission guard (``federation._policy_round_body``
    ``admission=``) rejects non-finite or norm-violating heads at
    publication time, so a poisoned head never enters the shared pool;
    the client itself trains on its own corrupted state (sacrificial).

Faults are drawn independently per (wave, global client index) from
``SeedSequence([plan.seed, 0xFA, wave, index])`` — never from a shared
stream — so the schedule is index-addressable: the same client faults the
same way no matter which engine runs the wave, how the mesh shards it, or
in what order other clients are drawn.  Precedence within one draw is
dropout > straggler > byzantine (the classes are disjoint per wave).

``FaultPlan`` is a frozen registered policy dataclass, so it round-trips
through checkpoint manifests via ``spec()`` / ``policy_from_spec`` like
every other protocol.

Known limitation, by design: the admission guard is a *sanity* gate
(finiteness + norm bound), not a statistical defense — a sign-flipped head
has the same norm as the original and passes.  Robust aggregation belongs
to the ROADMAP trust layer; the quarantine contract here guarantees only
that no non-finite or norm-exploding head is ever served by the pool.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.policies import _Spec, register_policy

# Pool rows seeded from an inadmissible head are published as zeros at this
# sentinel age: far above any real staleness bound, so both the bounded
# (`age > max_age`) and the admission-aware unbounded exclusion hide the row
# from every selector until a clean republication resets its age.
QUARANTINE_AGE = 1 << 30

CORRUPTIONS = ("nan", "inf", "explode", "signflip")


@register_policy
@dataclasses.dataclass(frozen=True)
class FaultPlan(_Spec):
    """A seeded description of the failure scenario to inject.

    ``dropout`` / ``straggler`` / ``byzantine`` are independent per-wave
    per-client probabilities (disjoint classes: dropout wins over
    straggler wins over byzantine).  ``corruption`` picks how a byzantine
    client's heads are mangled; ``norm_bound`` is the admission guard's
    L2 bound on a published head tree (non-finite heads are always
    rejected).  An all-zero plan is exactly "no faults": the engines skip
    the admission guard entirely and trace bit-identically to a run with
    no plan at all."""
    dropout: float = 0.0
    straggler: float = 0.0
    byzantine: float = 0.0
    corruption: str = "nan"
    norm_bound: float = 1e6
    seed: int = 0

    def __post_init__(self):
        for name in ("dropout", "straggler", "byzantine"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be a probability in [0, 1], "
                                 f"got {v}")
        if self.corruption not in CORRUPTIONS:
            raise ValueError(f"unknown corruption {self.corruption!r} "
                             f"(one of {CORRUPTIONS})")
        if not self.norm_bound > 0:
            raise ValueError(f"norm_bound must be > 0, got {self.norm_bound}")

    @property
    def enabled(self) -> bool:
        """Whether any fault class can fire.  Disabled plans are inert:
        engines treat them exactly like ``faults=None``."""
        return (self.dropout > 0 or self.straggler > 0
                or self.byzantine > 0)


@dataclasses.dataclass(frozen=True)
class WaveFaults:
    """The faults that actually hit one wave, AFTER geometry re-rounding
    (a drawn-dropped client revived to keep the wave at one mesh multiple
    is healthy; a trimmed survivor counts as dropped).  Global population
    indices, each tuple sorted."""
    wave: int
    dropped: Tuple[int, ...] = ()
    stragglers: Tuple[int, ...] = ()
    byzantine: Tuple[int, ...] = ()

    @property
    def degraded(self) -> bool:
        """A wave is degraded when it lost clients to dropout."""
        return bool(self.dropped)

    def to_json(self) -> dict:
        return {"wave": self.wave, "dropped": list(self.dropped),
                "stragglers": list(self.stragglers),
                "byzantine": list(self.byzantine)}

    @classmethod
    def from_json(cls, d: dict) -> "WaveFaults":
        return cls(wave=int(d["wave"]),
                   dropped=tuple(int(i) for i in d["dropped"]),
                   stragglers=tuple(int(i) for i in d["stragglers"]),
                   byzantine=tuple(int(i) for i in d["byzantine"]))


def reround_wave(indices: Sequence[int], dropped: Sequence[int],
                 multiple: int = 1):
    """Re-round a wave's geometry after dropout, deterministically.

    ``indices`` is the sampled active set in sample order; ``dropped`` the
    drawn dropouts.  Survivors are kept in sample order.  If fewer than
    ``max(multiple, 1)`` clients survive, drawn dropouts are revived in
    sample order until one multiple is reached (a wave never goes empty);
    if the survivor count is not a multiple of ``multiple``, the
    HIGHEST-index survivors are trimmed (they count as dropped — the mesh
    needs per-device equal blocks, see ``participation_multiple``).
    Returns ``(kept, effective_dropped)`` — both lists of ints, ``kept``
    in sample order, ``effective_dropped`` sorted."""
    indices = [int(i) for i in indices]
    drop = set(int(d) for d in dropped) & set(indices)
    floor = max(int(multiple), 1)
    kept = [i for i in indices if i not in drop]
    for i in indices:               # revive first-drawn until one multiple
        if len(kept) >= floor:
            break
        if i in drop:
            drop.discard(i)
            kept = [j for j in indices if j not in drop]
    if multiple > 1 and len(kept) % multiple:
        excess = len(kept) % multiple
        for i in sorted(kept, reverse=True)[:excess]:
            drop.add(i)
        kept = [j for j in indices if j not in drop]
    return kept, sorted(drop)


class FaultInjector:
    """Draws a :class:`FaultPlan`'s faults.  Stateless between calls —
    every decision is a pure function of ``(plan.seed, wave, index)`` —
    so a restored run replays the identical schedule without any carried
    RNG state."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def _draws(self, wave: int, index: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.plan.seed, 0xFA, int(wave),
                                    int(index)]))
        return rng.random(3)

    def wave_faults(self, wave: int, indices: Sequence[int],
                    multiple: int = 1) -> WaveFaults:
        """The effective faults for one wave over its sampled ``indices``
        (geometry re-rounding applied; see :func:`reround_wave`)."""
        p = self.plan
        drawn_drop: List[int] = []
        strag: List[int] = []
        byz: List[int] = []
        for i in indices:
            u = self._draws(wave, int(i))
            if u[0] < p.dropout:
                drawn_drop.append(int(i))
            elif u[1] < p.straggler:
                strag.append(int(i))
            elif u[2] < p.byzantine:
                byz.append(int(i))
        kept, dropped = reround_wave(indices, drawn_drop, multiple)
        keptset = set(kept)
        return WaveFaults(
            wave=int(wave), dropped=tuple(dropped),
            stragglers=tuple(sorted(i for i in strag if i in keptset)),
            byzantine=tuple(sorted(i for i in byz if i in keptset)))

    def corrupt_heads(self, heads, wave: int, index: int):
        """A corrupted copy of a stacked head tree (host-side numpy) for a
        byzantine client, per ``plan.corruption``.  The 'explode' scale
        draw comes from the client's own (wave, index) stream, so it too
        replays exactly."""
        mode = self.plan.corruption

        def bad(x):
            a = np.array(x, copy=True)
            if mode == "nan":
                a[...] = np.nan
            elif mode == "inf":
                a[...] = np.inf
            elif mode == "explode":
                rng = np.random.default_rng(
                    np.random.SeedSequence([self.plan.seed, 0xFB,
                                            int(wave), int(index)]))
                a = (a + 1.0) * np.asarray(
                    rng.uniform(1e12, 1e15), a.dtype)
            elif mode == "signflip":
                a = -a
            return a.astype(np.asarray(x).dtype)

        return jax.tree_util.tree_map(bad, heads)


def heads_admissible(heads, norm_bound: float) -> bool:
    """The host-side twin of the in-graph admission predicate: True iff
    every leaf of the head tree is finite and the whole tree's L2 norm is
    within ``norm_bound``.  Used by the sequential oracle's publish gate
    and by the pool-seeding sanitizer — MUST agree with the traced form in
    ``federation._policy_round_body`` (sum of float32 squares, compared to
    the squared bound)."""
    sq = 0.0
    for leaf in jax.tree_util.tree_leaves(heads):
        a = np.asarray(leaf, np.float32)
        sq += float(np.sum(np.square(a), dtype=np.float32))
    return bool(np.isfinite(sq) and sq <= float(norm_bound) ** 2)


def zero_heads_like(heads):
    """A zeroed copy of a head tree — what a quarantined pool row serves
    if something scores it anyway (it never should: quarantine age hides
    it from every selector)."""
    return jax.tree_util.tree_map(
        lambda x: np.zeros_like(np.asarray(x)), heads)


def fault_log_json(log: Sequence[WaveFaults]) -> list:
    """JSON form of a fault log for the checkpoint manifest."""
    return [wf.to_json() for wf in log]


def fault_log_from_json(rows: Sequence[dict]) -> List[WaveFaults]:
    return [WaveFaults.from_json(r) for r in rows]
