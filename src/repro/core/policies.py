"""Pluggable federation policies (the composable HFL API).

The paper's mechanisms are four orthogonal decisions, each now a policy
protocol with interchangeable implementations:

  * :class:`SwitchPolicy`  — WHEN a client federates.  The paper's
    validation-plateau rule (:class:`PlateauSwitch`), plus ``always`` /
    ``never`` / Bernoulli-``prob(p)`` variants.
  * :class:`SelectionPolicy` — WHICH pool head a client pulls per feature.
    Eq. 7 argmin (:class:`ArgminSelection`), uniform :class:`RandomSelection`
    (the §5.5 ablation), softmax-weighted sampling and uniform-over-top-k.
  * :class:`TransferRule` — HOW a selected head is merged into the local
    head.  Eq. 8 alpha-blend (:class:`AlphaBlend`) and a per-feature-alpha
    variant.
  * :class:`PoolPolicy` — WHAT the pool serves.  Last-write-wins asynchrony
    (stale entries persist forever, the paper's semantics) or a bounded
    max-staleness variant that hides entries older than ``max_age``
    federated opportunities.

A fifth protocol lives in :mod:`repro.core.participation`:
``ParticipationPolicy`` — WHO is even present.  It samples the per-wave
active subset of a (possibly huge) population before any engine runs, so
it is host-side-only and never enters the jitted bundle below; its
implementations register through the same :func:`register_policy` hook and
round-trip through checkpoints like the four here.

Every policy is a **frozen dataclass**: hashable, so the whole bundle can be
a static argument to the batched engine's fused jitted round — selection /
transfer expose *jittable* ``*_batched`` methods traced straight into the
scan, next to the host-side methods the sequential oracle calls.  Legacy
``HFLConfig.mode`` strings remain factory shorthands via
:meth:`FederationPolicies.from_config`.

Policies serialize to plain dict specs (``spec()`` / :func:`policy_from_spec`)
so a resumable :class:`~repro.core.federation.Federation` checkpoint can
rebuild them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def plateaued(val_history: Sequence[float], patience: int) -> bool:
    """The paper's switching criterion: the validation loss has not improved
    for `patience` consecutive epochs (zero patience: eligible from epoch 1
    on)."""
    h = val_history
    if patience <= 0:
        return len(h) > 0
    if len(h) < patience + 1:
        return False
    best_before = min(h[:-patience])
    return all(v >= best_before for v in h[-patience:])


def plateaued_mask(hist, patience: int):
    """Jittable vectorized :func:`plateaued` over a (C, E) history matrix —
    the whole population's switch mask as in-graph ops, so a fused engine
    can trace the plateau rule instead of looping clients on the host.  E
    is the (common) history length, static under jit.  Elementwise equal to
    ``[plateaued(h, patience) for h in hist]`` at the array's own dtype;
    note ``jnp.asarray`` follows jax's default promotion (float32 unless
    x64 is enabled) — the host-side epoch path uses
    :meth:`PlateauSwitch.active_mask`, which compares in exact float64."""
    hist = jnp.asarray(hist)
    C, E = hist.shape
    if patience <= 0:
        return jnp.full((C,), E > 0)
    if E < patience + 1:
        return jnp.zeros((C,), bool)
    best_before = jnp.min(hist[:, :E - patience], axis=1)
    return jnp.all(hist[:, E - patience:] >= best_before[:, None], axis=1)


class _Spec:
    """spec()/from-spec plumbing shared by every policy dataclass."""

    def spec(self) -> dict:
        d = dataclasses.asdict(self)
        d["kind"] = type(self).__name__
        return d


# ---------------------------------------------------------------------------
# SwitchPolicy — when does a client federate?
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SwitchPolicy(_Spec):
    """Decides, at the start of each epoch, whether a client participates in
    federated transfer this epoch.  Host-side only (the activity mask is
    computed once per epoch on the host by both engines, in client order, so
    stochastic policies stay engine-deterministic)."""

    def active(self, val_history: Sequence[float],
               rng: np.random.Generator) -> bool:
        """One client's switch decision for the coming epoch, given its
        validation-MSE history (may be empty) and the shared host rng
        stream (consumed ONLY by stochastic policies, in client order)."""
        raise NotImplementedError

    def active_mask(self, histories: Sequence[Sequence[float]],
                    rng: np.random.Generator) -> np.ndarray:
        """The whole population's activity for one epoch as a (C,) bool
        array.  The default walks clients in list order calling
        :meth:`active`, so stochastic policies consume the shared host rng
        stream exactly as the sequential oracle does; deterministic policies
        override with a vectorized form."""
        return np.array([self.active(h, rng) for h in histories], bool)


@dataclasses.dataclass(frozen=True)
class PlateauSwitch(SwitchPolicy):
    """Federate only when validation has plateaued (paper §4.2)."""
    patience: int = 3

    def active(self, val_history, rng):
        return plateaued(val_history, self.patience)

    def active_mask(self, histories, rng):
        """Vectorized over the population in exact float64 on the host —
        bitwise the same comparisons as the scalar :func:`plateaued` (the
        jittable in-graph form is :func:`plateaued_mask`)."""
        C = len(histories)
        E = min((len(h) for h in histories), default=0)
        if E != max((len(h) for h in histories), default=0):
            return super().active_mask(histories, rng)   # ragged: loop
        if self.patience <= 0:
            return np.full(C, E > 0)
        if E < self.patience + 1:
            return np.zeros(C, bool)
        hist = np.asarray([list(h) for h in histories],
                          np.float64).reshape(C, E)
        best_before = hist[:, :E - self.patience].min(axis=1)
        return (hist[:, E - self.patience:] >=
                best_before[:, None]).all(axis=1)


@dataclasses.dataclass(frozen=True)
class AlwaysSwitch(SwitchPolicy):
    """Every epoch federates (§5.5 `always`, also the `random` ablation)."""

    def active(self, val_history, rng):
        return True

    def active_mask(self, histories, rng):
        return np.ones(len(histories), bool)


@dataclasses.dataclass(frozen=True)
class NeverSwitch(SwitchPolicy):
    """Transfer disabled (§5.5 `no`)."""

    def active(self, val_history, rng):
        return False

    def active_mask(self, histories, rng):
        return np.zeros(len(histories), bool)


@dataclasses.dataclass(frozen=True)
class ProbSwitch(SwitchPolicy):
    """Bernoulli(p) participation — partial-participation scenarios."""
    p: float = 0.5

    def active(self, val_history, rng):
        return bool(rng.random() < self.p)


# ---------------------------------------------------------------------------
# SelectionPolicy — which pool head per feature?
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SelectionPolicy(_Spec):
    """Picks one pool entry per target feature.

    Host path (sequential oracle): :meth:`select_host` gets the Eq.-7 error
    vector (np, ``inf`` at excluded entries; ``None`` when
    ``needs_errors`` is False), the validity mask, and the shared host rng —
    returns an int index.

    Batched path: :meth:`select_batched` is traced inside the fused round
    scan; gets errors ``(nf, ns)`` (already ``inf``-masked) or ``None``, the
    per-entry exclusion mask ``(ns,)``, a per-client PRNG key, and static
    geometry — returns ``(nf,)`` int32 flat pool indices.

    ``local_argmin`` declares that the policy's selection is a pure argmin
    over the error row, so a client-sharded engine may score pool CHUNKS
    per device and merge per-chunk ``(min, index)`` pairs instead of
    all-gathering the full ``(nf, ns)`` error matrix (see
    ``federation.merge_sharded_argmin`` — the merge reproduces
    ``jnp.argmin``'s lowest-flat-index tie-break exactly).  Policies that
    need the full error distribution (softmax, top-k) leave it False and
    get the gathered matrix."""

    needs_errors = True
    local_argmin = False

    def select_host(self, errs: Optional[np.ndarray], valid: np.ndarray,
                    rng: np.random.Generator) -> int:
        """Sequential-oracle selection of ONE pool index for one feature
        (see the class docstring for the argument contract)."""
        raise NotImplementedError

    def select_batched(self, errs, excluded, key, *, nf: int, ns: int, i,
                       bounded: bool):
        """Jittable all-features selection for client ``i`` — traced into
        the batched engine's fused round scan (see the class docstring)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ArgminSelection(SelectionPolicy):
    """Eq. 7: the pool head with the smallest preliminary-prediction squared
    error on the client's last-R probe batch.  Ties resolve to the LOWEST
    flat pool index (``argmin``'s first occurrence) on every engine — the
    pinned tie-break rule the sharded reduce preserves."""

    local_argmin = True

    def select_host(self, errs, valid, rng):
        return int(np.argmin(errs))

    def select_batched(self, errs, excluded, key, *, nf, ns, i, bounded):
        return jnp.argmin(errs, axis=1)


@dataclasses.dataclass(frozen=True)
class RandomSelection(SelectionPolicy):
    """Uniform over the (valid) foreign pool — the §5.5 `random` ablation.
    Skips Eq.-7 scoring entirely."""

    needs_errors = False

    def select_host(self, errs, valid, rng):
        if valid.all():              # legacy stream: one draw over all keys
            return int(rng.integers(len(valid)))
        idx = np.flatnonzero(valid)
        return int(idx[rng.integers(len(idx))])

    def select_batched(self, errs, excluded, key, *, nf, ns, i, bounded):
        if not bounded:
            # uniform over the ns - nf foreign entries, mapped to full index
            e = jax.random.randint(key, (nf,), 0, ns - nf)
            return jnp.where(e >= i * nf, e + nf, e)
        logits = jnp.where(excluded, -jnp.inf, 0.0)
        return jax.random.categorical(
            key, jnp.broadcast_to(logits, (nf, ns)), axis=-1)


@dataclasses.dataclass(frozen=True)
class SoftmaxSelection(SelectionPolicy):
    """Sample proportionally to softmax(-err / temperature) — softer than
    argmin, explores near-optimal sources."""
    temperature: float = 1.0

    def __post_init__(self):
        if self.temperature <= 0:
            raise ValueError(f"temperature must be > 0, "
                             f"got {self.temperature} (use ArgminSelection "
                             f"for the deterministic limit)")

    def select_host(self, errs, valid, rng):
        logits = -errs / self.temperature
        logits = logits - logits[np.isfinite(logits)].max()
        p = np.where(np.isfinite(logits), np.exp(logits), 0.0)
        return int(rng.choice(len(errs), p=p / p.sum()))

    def select_batched(self, errs, excluded, key, *, nf, ns, i, bounded):
        return jax.random.categorical(key, -errs / self.temperature, axis=-1)


@dataclasses.dataclass(frozen=True)
class TopKSelection(SelectionPolicy):
    """Uniform over the k lowest-error valid heads (k=1 == argmin)."""
    k: int = 3

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")

    def select_host(self, errs, valid, rng):
        order = np.argsort(errs, kind="stable")       # inf (excluded) last
        kk = max(1, min(self.k, int(np.isfinite(errs).sum())))
        return int(order[rng.integers(kk)])

    def select_batched(self, errs, excluded, key, *, nf, ns, i, bounded):
        k = min(self.k, ns)
        neg, idx = jax.lax.top_k(-errs, k)            # (nf, k), best first
        kk = jnp.clip(jnp.sum(neg > -jnp.inf, axis=1), 1, k)
        u = jax.random.uniform(key, (nf,))
        r = jnp.minimum((u * kk).astype(jnp.int32), kk - 1)
        return idx[jnp.arange(nf), r]


# ---------------------------------------------------------------------------
# TransferRule — how is a selected head merged in?
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransferRule(_Spec):
    """Merges the selected pool heads into the client's own heads.  `apply`
    operates on the stacked ``(nf, ...)`` head trees and must be jittable
    (it is traced inside the batched engine's fused scan)."""

    def apply(self, target_heads_stacked, selected_stacked):
        """Merge the selected ``(nf, ...)`` pool heads into the client's own
        ``(nf, ...)`` heads; returns the new head tree (jittable, pure)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AlphaBlend(TransferRule):
    """Eq. 8: H_i <- alpha * H_hat + (1 - alpha) * H_i for all nf heads."""
    alpha: float = 0.2

    def apply(self, target, selected):
        a = self.alpha
        return jax.tree_util.tree_map(
            lambda t, s: a * s + (1 - a) * t, target, selected)


@dataclasses.dataclass(frozen=True)
class PerFeatureAlpha(TransferRule):
    """Eq. 8 with a distinct alpha per target feature (e.g. trust foreign
    knowledge more on sparsely-observed channels)."""
    alphas: Tuple[float, ...] = (0.2,)

    def apply(self, target, selected):
        a = jnp.asarray(self.alphas, jnp.float32)

        def blend_leaf(t, s):
            af = a.reshape((-1,) + (1,) * (t.ndim - 1))
            return af * s + (1 - af) * t

        return jax.tree_util.tree_map(blend_leaf, target, selected)


# ---------------------------------------------------------------------------
# PoolPolicy — what does the pool serve?
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolPolicy(_Spec):
    """Asynchrony semantics of the head pool.  ``max_age`` is None for the
    paper's last-write-wins rule (stale entries persist forever); an int
    bounds how many federated opportunities an entry may go unrefreshed
    before it stops being served to selectors (it is hidden, not deleted —
    a republish revives the row)."""
    max_age: Optional[int] = None

    @property
    def bounded(self) -> bool:
        return self.max_age is not None


@dataclasses.dataclass(frozen=True)
class LastWriteWins(PoolPolicy):
    """Entries persist until overwritten — the paper's asynchrony."""
    max_age: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class MaxStaleness(PoolPolicy):
    """Hide entries older than `max_age` federated opportunities."""
    max_age: Optional[int] = 3


# ---------------------------------------------------------------------------
# Bundle + legacy-mode factory + spec round-trip
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FederationPolicies:
    """One complete policy description consumed by BOTH engines.  Hashable,
    so the bundle itself is a static argument of the fused batched round."""
    switch: SwitchPolicy
    selection: SelectionPolicy
    transfer: TransferRule
    pool: PoolPolicy

    @classmethod
    def from_config(cls, cfg) -> "FederationPolicies":
        """Legacy ``HFLConfig.mode`` shorthand -> explicit policy bundle."""
        mode = cfg.mode
        if mode == "no":
            switch: SwitchPolicy = NeverSwitch()
        elif mode in ("always", "random"):
            switch = AlwaysSwitch()
        elif mode == "hfl":
            switch = PlateauSwitch(patience=cfg.patience)
        else:
            raise ValueError(f"unknown HFL mode {mode!r}")
        selection = (RandomSelection() if mode == "random"
                     else ArgminSelection())
        return cls(switch=switch, selection=selection,
                   transfer=AlphaBlend(alpha=cfg.alpha),
                   pool=LastWriteWins())

    def spec(self) -> dict:
        """JSON-serializable description of the whole bundle — what a
        Federation checkpoint manifest stores."""
        return {"switch": self.switch.spec(),
                "selection": self.selection.spec(),
                "transfer": self.transfer.spec(),
                "pool": self.pool.spec()}

    @classmethod
    def from_spec(cls, spec: dict) -> "FederationPolicies":
        """Inverse of :meth:`spec` — rebuilds every policy through the
        registry (third-party policies must have been re-registered via
        :func:`register_policy` before restoring)."""
        return cls(**{slot: policy_from_spec(spec[slot])
                      for slot in ("switch", "selection", "transfer", "pool")})


_REGISTRY = {cls.__name__: cls for cls in (
    PlateauSwitch, AlwaysSwitch, NeverSwitch, ProbSwitch,
    ArgminSelection, RandomSelection, SoftmaxSelection, TopKSelection,
    AlphaBlend, PerFeatureAlpha,
    LastWriteWins, MaxStaleness, PoolPolicy,
)}


def register_policy(cls):
    """Third-party policy plugin hook: registered classes round-trip through
    Federation checkpoints.  Usable as a decorator."""
    _REGISTRY[cls.__name__] = cls
    return cls


def policy_from_spec(spec: dict):
    """One policy object back from its ``spec()`` dict: the ``kind`` key
    names the registered class, every other key is a constructor field
    (JSON-decoded lists are coerced back to tuples so frozen dataclasses
    stay hashable)."""
    d = dict(spec)
    kind = d.pop("kind")
    if kind not in _REGISTRY:
        raise ValueError(f"unknown policy kind {kind!r} "
                         f"(register it with policies.register_policy)")
    for k, v in d.items():          # JSON round-trip turns tuples into lists
        if isinstance(v, list):
            d[k] = tuple(v)
        elif isinstance(v, dict) and "kind" in v:
            d[k] = policy_from_spec(v)   # nested sub-policy (TrustPlan etc.)
    return _REGISTRY[kind](**d)
