"""Dense & sparse feature tensors (paper §3, Figs. 3-4).

A patient record is an *event stream*: at each tick exactly ONE channel (one
of `nf` features or the label) carries a value — the paper's sparsity model.
For every tick where the LABEL is observed we pack:

  sparse tensor  X^S ∈ R^{nf x w}:  X^S[i, l] = x_i at tick (t-1-l) if that
      tick carried feature i, else 0   (raw last-w window per feature);
  dense tensor   X^D ∈ R^{nf x w}:  X^D[i, l] = the (l+1)-th most recent
      *available* value of feature i before tick t (0 while unseen).

Both are returned most-recent-first along the window axis, matching Eq. (1):
X^S_{i,t} = [x_{i,t-1}, x_{i,t-2}, ..., x_{i,t-w}].
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class EventStream:
    """One patient's sparse record.  channel: 0..nf-1 = features, nf = label."""
    channels: np.ndarray   # (T,) int32
    values: np.ndarray     # (T,) float32
    times: np.ndarray      # (T,) float32, strictly increasing (irregular gaps)
    nf: int

    def __post_init__(self):
        assert self.channels.shape == self.values.shape == self.times.shape


def pack_feature_tensors(stream: EventStream, w: int
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (X_sparse, X_dense, y) with shapes (N, nf, w), (N, nf, w), (N,)
    where N = number of label events (label events with no history still
    count; unseen entries are 0, as in the paper's zero-padded tensors)."""
    nf = stream.nf
    T = len(stream.channels)
    label_ticks = np.nonzero(stream.channels == nf)[0]
    N = len(label_ticks)
    xs = np.zeros((N, nf, w), np.float32)
    xd = np.zeros((N, nf, w), np.float32)
    y = stream.values[label_ticks].astype(np.float32)

    # rolling per-feature history of available values (most-recent-first)
    hist = np.zeros((nf, w), np.float32)
    hist_len = np.zeros(nf, np.int64)
    li = 0
    for t in range(T):
        ch = stream.channels[t]
        if ch == nf:
            if li < N and label_ticks[li] == t:
                # sparse: raw window of the last w ticks
                lo = max(0, t - w)
                for l, tick in enumerate(range(t - 1, lo - 1, -1)):
                    c = stream.channels[tick]
                    if c < nf:
                        xs[li, c, l] = stream.values[tick]
                xd[li] = hist
                li += 1
        else:
            hist[ch, 1:] = hist[ch, :-1]
            hist[ch, 0] = stream.values[t]
            hist_len[ch] = min(w, hist_len[ch] + 1)
    return xs, xd, y


def pack_feature_tensors_ref(stream: EventStream, w: int):
    """O(T*w) oracle used by the hypothesis property tests (independent,
    maximally-dumb implementation)."""
    nf = stream.nf
    out_s, out_d, out_y = [], [], []
    for t in range(len(stream.channels)):
        if stream.channels[t] != nf:
            continue
        xs = np.zeros((nf, w), np.float32)
        for l in range(w):
            tick = t - 1 - l
            if tick >= 0 and stream.channels[tick] < nf:
                xs[stream.channels[tick], l] = stream.values[tick]
        xd = np.zeros((nf, w), np.float32)
        for i in range(nf):
            past = [stream.values[u] for u in range(t)
                    if stream.channels[u] == i]
            for l, v in enumerate(reversed(past[-w:])):
                xd[i, l] = v
        out_s.append(xs)
        out_d.append(xd)
        out_y.append(stream.values[t])
    if not out_y:
        return (np.zeros((0, nf, w), np.float32),) * 2 + (np.zeros(0, np.float32),)
    return (np.stack(out_s), np.stack(out_d),
            np.asarray(out_y, np.float32))
