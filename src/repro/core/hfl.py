"""Heterogeneous Federated Learning mechanism (paper §4.2).

Implements, faithfully:
  * the asynchronous **head pool** (decentralized: every user publishes its
    nf global-head weight sets; stale versions remain usable),
  * **heterogeneous domain selection** (Eq. 7): for each target head H_i pick
    the pool model with the smallest preliminary-prediction *squared* error
    on the target's own last R samples (Eq. 7 as printed omits the square;
    Eqs. 3/6 define the error as squared — we use squared, noted in DESIGN),
  * **alpha-blending** (Eq. 8): H_i <- alpha * H_hat + (1-alpha) * H_i,
  * the **switching mechanism**: selection+blend only in epochs where the
    validation loss has not improved for `patience` consecutive epochs,
  * the ablation modes of §5.5: no / random / always / hfl.

Training protocol per the paper §4.2/§5.2: one gradient-descent update per R
consecutive periods (batch = R samples), Adam lr 0.01, 50 epochs, save-best
on validation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as N
from repro.optim import adam, apply_updates
from repro.sharding import spec as S


@dataclasses.dataclass
class HFLConfig:
    w: int = 3
    R: int = 50
    alpha: float = 0.2
    lr: float = 0.01
    epochs: int = 50
    patience: int = 3
    mode: str = "hfl"            # hfl | no | random | always
    use_pool_kernel: bool = False  # Pallas pool-scoring kernel (TPU path)
    seed: int = 0


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

class FederatedClient:
    """One hospital: local data, local model, recent-R scoring buffer."""

    def __init__(self, name: str, nf: int, cfg: HFLConfig,
                 train, valid, test, rng):
        self.name, self.nf, self.cfg = name, nf, cfg
        self.train, self.valid, self.test = train, valid, test  # (xs, xd, y)
        schema = N.hfl_schema(nf, cfg.w)
        self.params = S.materialize(schema, rng)
        self.opt = adam(cfg.lr)
        self.opt_state = self.opt.init(self.params)
        self.val_history: List[float] = []
        self.best_val = np.inf
        self.best_params = self.params
        self._recent: Optional[Tuple[np.ndarray, np.ndarray]] = None  # xd, y

        @jax.jit
        def _train_step(params, opt_state, xs, xd, y):
            (loss, parts), grads = jax.value_and_grad(
                N.hfl_loss, has_aux=True)(params, xs, xd, y)
            updates, opt_state = self.opt.update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss

        @jax.jit
        def _eval_mse(params, xs, xd, y):
            y_hat, _ = N.hfl_forward(params, xs, xd)
            return jnp.mean((y - y_hat) ** 2)

        self._train_step = _train_step
        self._eval_mse = _eval_mse

    def train_epoch(self) -> None:
        xs, xd, y = self.train
        R = self.cfg.R
        n = len(y)
        for start in range(0, n - R + 1, R):
            sl = slice(start, start + R)
            self.params, self.opt_state, _ = self._train_step(
                self.params, self.opt_state, xs[sl], xd[sl], y[sl])
            self._recent = (xd[sl], y[sl])
            yield_round = True  # one federated opportunity per R periods
            if yield_round:
                yield

    def val_mse(self) -> float:
        return float(self._eval_mse(self.params, *self.valid))

    def test_mse(self, params=None) -> float:
        return float(self._eval_mse(params if params is not None
                                    else self.best_params, *self.test))

    def end_epoch(self) -> None:
        v = self.val_mse()
        self.val_history.append(v)
        if v < self.best_val:
            self.best_val = v
            self.best_params = self.params

    def fl_active(self) -> bool:
        """Switching mechanism: FL only when validation has plateaued for
        `patience` epochs (always/random modes bypass; no disables)."""
        mode = self.cfg.mode
        if mode == "no":
            return False
        if mode in ("always", "random"):
            return True
        h = self.val_history
        p = self.cfg.patience
        if len(h) < p + 1:
            return False
        best_before = min(h[:-p])
        return all(v >= best_before for v in h[-p:])


# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------

class HeadPool:
    """Decentralized asynchronous pool of shared head-layer weights.

    Entries persist until overwritten ("the last version stored in the
    pool"), so a user that skips publication rounds still contributes its
    stale heads — the paper's asynchrony semantics."""

    def __init__(self):
        self.entries: Dict[Tuple[str, int], dict] = {}

    def publish(self, user: str, head_params_stacked, nf: int) -> None:
        for i in range(nf):
            entry = jax.tree_util.tree_map(lambda p: p[i], head_params_stacked)
            self.entries[(user, i)] = entry

    def stacked_for(self, exclude_user: str):
        """All pool heads from OTHER users, stacked to (ns, ...)."""
        keys = [k for k in sorted(self.entries) if k[0] != exclude_user]
        if not keys:
            return None, []
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[self.entries[k] for k in keys])
        return stacked, keys


# ---------------------------------------------------------------------------
# Selection (Eq. 7) + blending (Eq. 8)
# ---------------------------------------------------------------------------

@jax.jit
def pool_errors(pool_stacked, xd_i, y):
    """Mean squared preliminary-prediction error of every pool head on the
    client's last-R dense vectors of feature i.  xd_i: (R, w); y: (R,).
    Returns (ns,)."""
    def one(head):
        return jnp.mean((y - N.head_apply(head, xd_i)) ** 2)

    return jax.vmap(one)(pool_stacked)


def pool_errors_kernel(pool_stacked, xd_i, y):
    """TPU Pallas fused pool sweep (see src/repro/kernels/pool_mlp)."""
    from repro.kernels.pool_mlp.ops import pool_mlp_errors
    return pool_mlp_errors(pool_stacked, xd_i, y)


@jax.jit
def blend(target_heads_stacked, selected_stacked, alpha: float):
    """Eq. 8 applied to all nf heads at once."""
    return jax.tree_util.tree_map(
        lambda t, s: alpha * s + (1 - alpha) * t,
        target_heads_stacked, selected_stacked)


def federated_round(client: FederatedClient, pool: HeadPool,
                    rng: np.random.Generator) -> Optional[List[int]]:
    """One heterogeneous-transfer round for `client` (paper Fig. 6).
    Returns the selected pool indices per feature (for logging), or None."""
    if client._recent is None:
        return None
    stacked, keys = pool.stacked_for(client.name)
    if stacked is None:
        return None
    xd_R, y_R = client._recent
    nf = client.nf
    chosen = []
    sel_entries = []
    for i in range(nf):
        if client.cfg.mode == "random":
            j = int(rng.integers(len(keys)))
        else:
            score_fn = (pool_errors_kernel if client.cfg.use_pool_kernel
                        else pool_errors)
            errs = score_fn(stacked, jnp.asarray(xd_R[:, i]), jnp.asarray(y_R))
            j = int(jnp.argmin(errs))
        chosen.append(j)
        sel_entries.append(jax.tree_util.tree_map(lambda p: p[j], stacked))
    selected = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sel_entries)
    client.params = dict(client.params)
    client.params["heads"] = blend(client.params["heads"], selected,
                                   client.cfg.alpha)
    return chosen


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

def run_federated_training(clients: Sequence[FederatedClient],
                           cfg: HFLConfig, verbose: bool = False):
    """Decentralized HFL over a set of clients.  Returns per-client history:
    {name: {"val": [...], "test": float, "rounds": int}}."""
    rng = np.random.default_rng(cfg.seed)
    pool = HeadPool()
    # initial publication so the pool is never empty (asynchronous start)
    for c in clients:
        pool.publish(c.name, c.params["heads"], c.nf)

    n_rounds = {c.name: 0 for c in clients}
    for epoch in range(cfg.epochs):
        active = {c.name: c.fl_active() for c in clients}
        iters = {c.name: c.train_epoch() for c in clients}
        live = set(iters)
        while live:
            for c in clients:
                if c.name not in live:
                    continue
                try:
                    next(iters[c.name])
                except StopIteration:
                    live.discard(c.name)
                    continue
                if active[c.name] and cfg.mode != "no":
                    federated_round(c, pool, rng)
                    n_rounds[c.name] += 1
                    pool.publish(c.name, c.params["heads"], c.nf)
        for c in clients:
            c.end_epoch()
        if verbose:
            msg = " ".join(f"{c.name}={c.val_history[-1]:.4f}"
                           f"{'*' if active[c.name] else ''}" for c in clients)
            print(f"[hfl] epoch {epoch:3d} val: {msg}", flush=True)
    return {c.name: {"val": c.val_history, "test": c.test_mse(),
                     "rounds": n_rounds[c.name], "best_val": c.best_val}
            for c in clients}
