"""Heterogeneous Federated Learning mechanism (paper §4.2).

Implements, faithfully:
  * the asynchronous **head pool** (decentralized: every user publishes its
    nf global-head weight sets; stale versions remain usable),
  * **heterogeneous domain selection** (Eq. 7): for each target head H_i pick
    the pool model with the smallest preliminary-prediction *squared* error
    on the target's own last R samples (Eq. 7 as printed omits the square;
    Eqs. 3/6 define the error as squared — we use squared, noted in DESIGN),
  * **alpha-blending** (Eq. 8): H_i <- alpha * H_hat + (1-alpha) * H_i,
  * the **switching mechanism**: selection+blend only in epochs where the
    validation loss has not improved for `patience` consecutive epochs,
  * the ablation modes of §5.5: no / random / always / hfl.

Training protocol per the paper §4.2/§5.2: one gradient-descent update per R
consecutive periods (batch = R samples), Adam lr 0.01, 50 epochs, save-best
on validation.

Two execution engines (see docs/ARCHITECTURE.md):
  * ``engine="sequential"`` — the reference oracle: a Python loop over
    clients with an explicit :class:`HeadPool` object, per-feature scoring
    and host-side argmin.  Handles heterogeneous feature counts and
    ragged per-client data lengths.
  * ``engine="batched"`` — client parameters stacked along a leading axis,
    the Adam step ``vmap``-ed across clients, and selection+blend for all
    nf features fused into ONE jitted scan over clients (no per-feature
    Python loop, no host sync inside a round).  Requires homogeneous
    clients (same nf, same data shapes).  Matches the sequential oracle's
    selections exactly and its head params to float tolerance.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as N
from repro.optim import adam, apply_updates
from repro.sharding import spec as S


@dataclasses.dataclass
class HFLConfig:
    w: int = 3
    R: int = 50
    alpha: float = 0.2
    lr: float = 0.01
    epochs: int = 50
    patience: int = 3
    mode: str = "hfl"            # hfl | no | random | always
    use_pool_kernel: bool = False  # Pallas pool-scoring kernel (TPU path)
    seed: int = 0


def switch_active(val_history: Sequence[float], cfg: HFLConfig) -> bool:
    """Switching mechanism: FL only when validation has plateaued for
    `patience` epochs (always/random modes bypass; no disables)."""
    mode = cfg.mode
    if mode == "no":
        return False
    if mode in ("always", "random"):
        return True
    h = val_history
    p = cfg.patience
    if p <= 0:                   # zero-patience: eligible from epoch 1 on
        return len(h) > 0
    if len(h) < p + 1:
        return False
    best_before = min(h[:-p])
    return all(v >= best_before for v in h[-p:])


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

def _train_step(opt, params, opt_state, xs, xd, y):
    """One Adam update on one client's R-batch.  The SINGLE definition both
    engines build on — sequential jits it directly, batched vmaps it — so
    they cannot drift apart."""
    (loss, parts), grads = jax.value_and_grad(
        N.hfl_loss, has_aux=True)(params, xs, xd, y)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


def _eval_mse(params, xs, xd, y):
    y_hat, _ = N.hfl_forward(params, xs, xd)
    return jnp.mean((y - y_hat) ** 2)


@functools.lru_cache(maxsize=None)
def _client_fns(lr: float):
    """Per-lr shared (optimizer, jitted train step, jitted eval) so N clients
    compile once, not N times."""
    opt = adam(lr)
    return (opt, jax.jit(functools.partial(_train_step, opt)),
            jax.jit(_eval_mse))


class FederatedClient:
    """One hospital: local data, local model, recent-R scoring buffer."""

    def __init__(self, name: str, nf: int, cfg: HFLConfig,
                 train, valid, test, rng):
        self.name, self.nf, self.cfg = name, nf, cfg
        self.train, self.valid, self.test = train, valid, test  # (xs, xd, y)
        schema = N.hfl_schema(nf, cfg.w)
        self.params = S.materialize(schema, rng)
        self.opt, self._train_step, self._eval_mse = _client_fns(cfg.lr)
        self.opt_state = self.opt.init(self.params)
        self.val_history: List[float] = []
        self.best_val = np.inf
        self.best_params = self.params
        self._recent: Optional[Tuple[np.ndarray, np.ndarray]] = None  # xd, y

    def train_epoch(self) -> None:
        xs, xd, y = self.train
        R = self.cfg.R
        n = len(y)
        for start in range(0, n - R + 1, R):
            sl = slice(start, start + R)
            self.params, self.opt_state, _ = self._train_step(
                self.params, self.opt_state, xs[sl], xd[sl], y[sl])
            self._recent = (xd[sl], y[sl])
            yield_round = True  # one federated opportunity per R periods
            if yield_round:
                yield

    def val_mse(self) -> float:
        return float(self._eval_mse(self.params, *self.valid))

    def test_mse(self, params=None) -> float:
        return float(self._eval_mse(params if params is not None
                                    else self.best_params, *self.test))

    def end_epoch(self) -> None:
        v = self.val_mse()
        self.val_history.append(v)
        if v < self.best_val:
            self.best_val = v
            self.best_params = self.params

    def fl_active(self) -> bool:
        return switch_active(self.val_history, self.cfg)


# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------

class HeadPool:
    """Decentralized asynchronous pool of shared head-layer weights.

    Entries persist until overwritten ("the last version stored in the
    pool"), so a user that skips publication rounds still contributes its
    stale heads — the paper's asynchrony semantics."""

    def __init__(self):
        self.entries: Dict[Tuple[str, int], dict] = {}

    def publish(self, user: str, head_params_stacked, nf: int) -> None:
        for i in range(nf):
            entry = jax.tree_util.tree_map(lambda p: p[i], head_params_stacked)
            self.entries[(user, i)] = entry

    def stacked_for(self, exclude_user: str):
        """All pool heads from OTHER users, stacked to (ns, ...)."""
        keys = [k for k in sorted(self.entries) if k[0] != exclude_user]
        if not keys:
            return None, []
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[self.entries[k] for k in keys])
        return stacked, keys


# ---------------------------------------------------------------------------
# Selection (Eq. 7) + blending (Eq. 8)
# ---------------------------------------------------------------------------

@jax.jit
def pool_errors(pool_stacked, xd_i, y):
    """Mean squared preliminary-prediction error of every pool head on the
    client's last-R dense vectors of feature i.  xd_i: (R, w); y: (R,).
    Returns (ns,)."""
    preds = N.head_pool_apply(pool_stacked, xd_i)      # (ns, R)
    return jnp.mean((y[None, :] - preds) ** 2, axis=1)


def pool_errors_kernel(pool_stacked, xd_i, y):
    """TPU Pallas fused pool sweep (see src/repro/kernels/pool_mlp)."""
    from repro.kernels.pool_mlp.ops import pool_mlp_errors
    return pool_mlp_errors(pool_stacked, xd_i, y)


def pool_kernel_available() -> bool:
    """ImportError only — a genuinely broken kernel module must surface, not
    silently fall back to the vmap path."""
    try:
        from repro.kernels.pool_mlp.ops import pool_mlp_errors  # noqa: F401
        return True
    except ImportError:
        return False


@jax.jit
def blend(target_heads_stacked, selected_stacked, alpha: float):
    """Eq. 8 applied to all nf heads at once."""
    return jax.tree_util.tree_map(
        lambda t, s: alpha * s + (1 - alpha) * t,
        target_heads_stacked, selected_stacked)


def federated_round(client: FederatedClient, pool: HeadPool,
                    rng: np.random.Generator) -> Optional[List[int]]:
    """One heterogeneous-transfer round for `client` (paper Fig. 6).
    Returns the selected pool indices per feature (for logging), or None."""
    if client._recent is None:
        return None
    stacked, keys = pool.stacked_for(client.name)
    if stacked is None:
        return None
    xd_R, y_R = client._recent
    nf = client.nf
    chosen = []
    sel_entries = []
    for i in range(nf):
        if client.cfg.mode == "random":
            j = int(rng.integers(len(keys)))
        else:
            score_fn = (pool_errors_kernel if client.cfg.use_pool_kernel
                        else pool_errors)
            errs = score_fn(stacked, jnp.asarray(xd_R[:, i]), jnp.asarray(y_R))
            j = int(jnp.argmin(errs))
        chosen.append(j)
        sel_entries.append(jax.tree_util.tree_map(lambda p: p[j], stacked))
    selected = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sel_entries)
    client.params = dict(client.params)
    client.params["heads"] = blend(client.params["heads"], selected,
                                   client.cfg.alpha)
    return chosen


# ---------------------------------------------------------------------------
# Fused multi-client selection + blend (batched engine)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("nf", "mode", "use_kernel"))
def fused_selection_round(heads, pool_heads, xd_R, y_R, active, alpha, key,
                          *, nf: int, mode: str, use_kernel: bool):
    """One federated opportunity for ALL clients, fused into a single jitted
    scan — replaces C x nf Python-level `pool_errors` calls and C x nf
    host-side argmin syncs with one device program.

    The scan walks clients in their processing order, carrying the pool so
    that client i scores the heads already republished by clients < i in the
    same sub-round — exactly the sequential oracle's interleaving.

    heads, pool_heads: head params stacked to (C, nf, ...);
    xd_R: (C, R, nf, w); y_R: (C, R); active: (C,) bool; key: PRNG key
    (random mode only).  Returns (new_heads, new_pool, chosen) where chosen
    is (C, nf) int32 flat indices into the row-major (client, feature) pool
    (-1 where the client was inactive)."""
    C = y_R.shape[0]
    ns = C * nf

    def flat(pool):
        return jax.tree_util.tree_map(
            lambda p: p.reshape((ns,) + p.shape[2:]), pool)

    def body(carry, inp):
        heads, pool = carry
        i, key_i = inp
        fp = flat(pool)
        xd_i = jnp.moveaxis(xd_R[i], 1, 0)           # (nf, R, w)
        if mode == "random":
            # uniform over the ns - nf foreign entries, mapped to full index
            e = jax.random.randint(key_i, (nf,), 0, ns - nf)
            j = jnp.where(e >= i * nf, e + nf, e)
        else:
            if use_kernel:
                from repro.kernels.pool_mlp.ops import pool_mlp_errors_features
                errs = pool_mlp_errors_features(fp, xd_i, y_R[i])
            else:
                errs = jax.vmap(
                    lambda xf: pool_errors(fp, xf, y_R[i]))(xd_i)  # (nf, ns)
            own = (jnp.arange(ns) // nf) == i
            errs = jnp.where(own[None, :], jnp.inf, errs)
            j = jnp.argmin(errs, axis=1)             # (nf,)
        selected = jax.tree_util.tree_map(lambda p: p[j], fp)   # (nf, ...)
        mine = jax.tree_util.tree_map(lambda h: h[i], heads)
        blended = blend(mine, selected, alpha)
        act = active[i]
        new_mine = jax.tree_util.tree_map(
            lambda b, m: jnp.where(act, b, m), blended, mine)
        heads = jax.tree_util.tree_map(
            lambda h, m: h.at[i].set(m), heads, new_mine)
        # publication: active clients overwrite their pool row, inactive
        # clients' stale entries persist (paper's asynchrony semantics)
        pool = jax.tree_util.tree_map(
            lambda pl, m: pl.at[i].set(jnp.where(act, m, pl[i])),
            pool, new_mine)
        chosen = jnp.where(act, j, -1).astype(jnp.int32)
        return (heads, pool), chosen

    keys = jax.random.split(key, C)
    (heads, pool_heads), chosen = jax.lax.scan(
        body, (heads, pool_heads), (jnp.arange(C), keys))
    return heads, pool_heads, chosen


def _stack_trees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _tree_row(tree, i):
    return jax.tree_util.tree_map(lambda p: p[i], tree)


def _selection_lut(names: Sequence[str], nf: int) -> np.ndarray:
    """Map the batched engine's row-major (client, feature) flat pool index
    to the sequential oracle's excluded, sorted-by-(name, feature) index —
    so both engines log identical selections."""
    C = len(names)
    lut = np.full((C, C * nf), -1, np.int64)
    for i in range(C):
        others = sorted((names[j], j) for j in range(C) if j != i)
        for rank, (_, j) in enumerate(others):
            for g in range(nf):
                lut[i, j * nf + g] = rank * nf + g
    return lut


@functools.lru_cache(maxsize=None)
def _make_batched_fns(lr: float):
    """vmap-over-clients versions of the exact same per-client step/eval the
    sequential engine jits (see _train_step / _eval_mse)."""
    opt = adam(lr)
    step = jax.jit(jax.vmap(functools.partial(_train_step, opt)))
    evaluate = jax.jit(jax.vmap(_eval_mse))
    return step, evaluate


def _run_batched(clients: Sequence[FederatedClient], cfg: HFLConfig,
                 verbose: bool = False):
    """Batched engine: one vmapped Adam step for all clients per sub-round,
    one fused selection+blend scan per federated opportunity."""
    C = len(clients)
    names = [c.name for c in clients]
    if len(set(names)) != C:
        raise ValueError(f"duplicate client names: {names}")
    nf = clients[0].nf
    shapes = [tuple(np.shape(a) for a in c.train) for c in clients]
    if any(c.nf != nf for c in clients) or len(set(shapes)) != 1 or \
            len({tuple(np.shape(a) for a in c.valid) for c in clients}) != 1 or \
            len({tuple(np.shape(a) for a in c.test) for c in clients}) != 1:
        raise ValueError(
            "engine='batched' requires homogeneous clients (same nf and "
            "identical train/valid/test shapes); truncate to a common length "
            "(see experiment.population_task_data) or use "
            "engine='sequential'")

    xs = jnp.stack([np.asarray(c.train[0]) for c in clients])
    xd = jnp.stack([np.asarray(c.train[1]) for c in clients])
    y = jnp.stack([np.asarray(c.train[2]) for c in clients])
    val = tuple(jnp.stack([np.asarray(c.valid[k]) for c in clients])
                for k in range(3))
    tst = tuple(jnp.stack([np.asarray(c.test[k]) for c in clients])
                for k in range(3))

    params = _stack_trees([c.params for c in clients])
    opt_state = _stack_trees([c.opt_state for c in clients])
    pool_heads = params["heads"]                  # initial publication
    step_fn, eval_fn = _make_batched_fns(cfg.lr)
    use_kernel = cfg.use_pool_kernel and pool_kernel_available()
    lut = _selection_lut(names, nf)

    histories = [list(c.val_history) for c in clients]
    best_val = np.array([c.best_val for c in clients], np.float64)
    best_params = params
    n_rounds = np.zeros(C, np.int64)
    selections: Dict[str, list] = {n: [] for n in names}
    key = jax.random.PRNGKey(cfg.seed)
    n, R = int(y.shape[1]), cfg.R

    for epoch in range(cfg.epochs):
        active = np.array([switch_active(histories[i], cfg)
                           for i in range(C)])
        active_dev = jnp.asarray(active)
        epoch_chosen = []          # device arrays; materialized once/epoch
        for start in range(0, n - R + 1, R):
            sl = slice(start, start + R)
            params, opt_state, _ = step_fn(
                params, opt_state, xs[:, sl], xd[:, sl], y[:, sl])
            if cfg.mode != "no" and active.any():
                if C >= 2:
                    key, sub = jax.random.split(key)
                    new_heads, pool_heads, chosen = fused_selection_round(
                        params["heads"], pool_heads, xd[:, sl], y[:, sl],
                        active_dev, cfg.alpha, sub,
                        nf=nf, mode=cfg.mode, use_kernel=use_kernel)
                    params = {**params, "heads": new_heads}
                    epoch_chosen.append(chosen)
                n_rounds += active
        for chosen in map(np.asarray, epoch_chosen):
            for i in range(C):
                if active[i]:
                    selections[names[i]].append(lut[i, chosen[i]].tolist())
        v = np.asarray(eval_fn(params, *val), np.float64)
        improved = v < best_val
        best_val = np.where(improved, v, best_val)
        mask = jnp.asarray(improved)
        best_params = jax.tree_util.tree_map(
            lambda b, p: jnp.where(
                mask.reshape((C,) + (1,) * (p.ndim - 1)), p, b),
            best_params, params)
        for i in range(C):
            histories[i].append(float(v[i]))
        if verbose:
            msg = " ".join(f"{names[i]}={v[i]:.4f}"
                           f"{'*' if active[i] else ''}" for i in range(C))
            print(f"[hfl/batched] epoch {epoch:3d} val: {msg}", flush=True)

    test = np.asarray(eval_fn(best_params, *tst), np.float64)
    # write the final state back so the client objects stay usable
    for i, c in enumerate(clients):
        c.params = _tree_row(params, i)
        c.opt_state = _tree_row(opt_state, i)
        c.val_history = histories[i]
        c.best_val = float(best_val[i])
        c.best_params = _tree_row(best_params, i)
    return {names[i]: {"val": histories[i], "test": float(test[i]),
                       "rounds": int(n_rounds[i]),
                       "best_val": float(best_val[i]),
                       "selections": selections[names[i]]}
            for i in range(C)}


# ---------------------------------------------------------------------------
# Orchestration
# ---------------------------------------------------------------------------

def _run_sequential(clients: Sequence[FederatedClient], cfg: HFLConfig,
                    verbose: bool = False):
    rng = np.random.default_rng(cfg.seed)
    pool = HeadPool()
    # initial publication so the pool is never empty (asynchronous start)
    for c in clients:
        pool.publish(c.name, c.params["heads"], c.nf)

    n_rounds = {c.name: 0 for c in clients}
    selections: Dict[str, list] = {c.name: [] for c in clients}
    for epoch in range(cfg.epochs):
        active = {c.name: c.fl_active() for c in clients}
        iters = {c.name: c.train_epoch() for c in clients}
        live = set(iters)
        while live:
            for c in clients:
                if c.name not in live:
                    continue
                try:
                    next(iters[c.name])
                except StopIteration:
                    live.discard(c.name)
                    continue
                if active[c.name] and cfg.mode != "no":
                    sel = federated_round(c, pool, rng)
                    if sel is not None:
                        selections[c.name].append(sel)
                    n_rounds[c.name] += 1
                    pool.publish(c.name, c.params["heads"], c.nf)
        for c in clients:
            c.end_epoch()
        if verbose:
            msg = " ".join(f"{c.name}={c.val_history[-1]:.4f}"
                           f"{'*' if active[c.name] else ''}" for c in clients)
            print(f"[hfl] epoch {epoch:3d} val: {msg}", flush=True)
    return {c.name: {"val": c.val_history, "test": c.test_mse(),
                     "rounds": n_rounds[c.name], "best_val": c.best_val,
                     "selections": selections[c.name]}
            for c in clients}


def run_federated_training(clients: Sequence[FederatedClient],
                           cfg: HFLConfig, verbose: bool = False,
                           engine: str = "sequential"):
    """Decentralized HFL over a set of clients.

    engine="sequential": the reference oracle (Python loop, HeadPool object,
    host-side per-feature argmin); handles heterogeneous nf / ragged data.
    engine="batched": vmapped train steps + one fused selection scan per
    round; requires homogeneous clients.  Both record the same history:
    {name: {"val": [...], "test": float, "rounds": int, "best_val": float,
    "selections": [[...], ...]}} — selections are indices into the pool
    sorted by (user, feature) excluding the client itself, identical across
    engines for modes hfl/always/no (random draws from different rng
    streams)."""
    if engine == "batched":
        return _run_batched(clients, cfg, verbose=verbose)
    if engine != "sequential":
        raise ValueError(f"unknown engine {engine!r}")
    return _run_sequential(clients, cfg, verbose=verbose)
