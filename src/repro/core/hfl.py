"""Heterogeneous Federated Learning primitives (paper §4.2).

Implements, faithfully:
  * the asynchronous **head pool** (decentralized: every user publishes its
    nf global-head weight sets; stale versions remain usable),
  * **heterogeneous domain selection** (Eq. 7): for each target head H_i pick
    the pool model with the smallest preliminary-prediction *squared* error
    on the target's own last R samples (Eq. 7 as printed omits the square;
    Eqs. 3/6 define the error as squared — we use squared, noted in DESIGN),
  * **alpha-blending** (Eq. 8): H_i <- alpha * H_hat + (1-alpha) * H_i,
  * the **switching mechanism**: selection+blend only in epochs where the
    validation loss has not improved for `patience` consecutive epochs,
  * the ablation modes of §5.5: no / random / always / hfl.

Training protocol per the paper §4.2/§5.2: one gradient-descent update per R
consecutive periods (batch = R samples), Adam lr 0.01, 50 epochs, save-best
on validation.

Orchestration lives in `core/federation.py` (the composable Federation API:
pluggable policies, callbacks, resumable state, the sequential and batched
executors); the pluggable policy implementations live in `core/policies.py`.
This module keeps the paper primitives — the client, the pool, Eq.-7
scoring, Eq.-8 blending — plus :func:`run_federated_training`, the thin
legacy entry point that maps ``HFLConfig.mode`` strings onto the policy API.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as N
from repro.core.policies import plateaued
from repro.optim import adam, apply_updates
from repro.sharding import spec as S


@dataclasses.dataclass
class HFLConfig:
    w: int = 3
    R: int = 50
    alpha: float = 0.2
    lr: float = 0.01
    epochs: int = 50
    patience: int = 3
    mode: str = "hfl"            # hfl | no | random | always
    use_pool_kernel: bool = False  # Pallas pool-scoring kernel (compiled on
                                   # TPU, experimentally on GPU; interpret-
                                   # mode elsewhere)
    seed: int = 0


def switch_active(val_history: Sequence[float], cfg: HFLConfig) -> bool:
    """Switching mechanism: FL only when validation has plateaued for
    `patience` epochs (always/random modes bypass; no disables).  The core
    plateau rule is :func:`repro.core.policies.plateaued`; explicit policy
    objects (policies.PlateauSwitch etc.) are the composable form."""
    mode = cfg.mode
    if mode == "no":
        return False
    if mode in ("always", "random"):
        return True
    return plateaued(val_history, cfg.patience)


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

def _train_step(opt, params, opt_state, xs, xd, y):
    """One Adam update on one client's R-batch.  The SINGLE definition both
    engines build on — sequential jits it directly, batched vmaps it — so
    they cannot drift apart."""
    (loss, parts), grads = jax.value_and_grad(
        N.hfl_loss, has_aux=True)(params, xs, xd, y)
    updates, opt_state = opt.update(grads, opt_state, params)
    return apply_updates(params, updates), opt_state, loss


def _eval_mse(params, xs, xd, y):
    y_hat, _ = N.hfl_forward(params, xs, xd)
    return jnp.mean((y - y_hat) ** 2)


@functools.lru_cache(maxsize=None)
def _client_fns(lr: float):
    """Per-lr shared (optimizer, jitted train step, jitted eval) so N clients
    compile once, not N times."""
    opt = adam(lr)
    return (opt, jax.jit(functools.partial(_train_step, opt)),
            jax.jit(_eval_mse))


class FederatedClient:
    """One hospital: local data, local model, recent-R scoring buffer."""

    def __init__(self, name: str, nf: int, cfg: HFLConfig,
                 train, valid, test, rng):
        self.name, self.nf, self.cfg = name, nf, cfg
        self.train, self.valid, self.test = train, valid, test  # (xs, xd, y)
        schema = N.hfl_schema(nf, cfg.w)
        self.params = S.materialize(schema, rng)
        self.opt, self._train_step, self._eval_mse = _client_fns(cfg.lr)
        self.opt_state = self.opt.init(self.params)
        self.val_history: List[float] = []
        self.best_val = np.inf
        self.best_params = self.params
        self._recent: Optional[Tuple[np.ndarray, np.ndarray]] = None  # xd, y

    def train_epoch(self, R: Optional[int] = None) -> Iterator[None]:
        """Generator over the epoch's R-batches: one Adam update per batch,
        yielding after each — a yield is one federated opportunity.  `R`
        defaults to the client's config (a Federation passes its schedule's
        R so both executors slice identically)."""
        xs, xd, y = self.train
        R = self.cfg.R if R is None else R
        for start in range(0, len(y) - R + 1, R):
            sl = slice(start, start + R)
            self.params, self.opt_state, _ = self._train_step(
                self.params, self.opt_state, xs[sl], xd[sl], y[sl])
            self._recent = (xd[sl], y[sl])
            yield

    def val_mse(self) -> float:
        return float(self._eval_mse(self.params, *self.valid))

    def test_mse(self, params=None) -> float:
        return float(self._eval_mse(params if params is not None
                                    else self.best_params, *self.test))

    def end_epoch(self) -> None:
        v = self.val_mse()
        self.val_history.append(v)
        if v < self.best_val:
            self.best_val = v
            self.best_params = self.params

    def fl_active(self) -> bool:
        return switch_active(self.val_history, self.cfg)


# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------

class HeadPool:
    """Decentralized asynchronous pool of shared head-layer weights.

    Entries persist until overwritten ("the last version stored in the
    pool"), so a user that skips publication rounds still contributes its
    stale heads — the paper's asynchrony semantics.  Each entry carries an
    age (federated opportunities since publication, advanced by
    :meth:`tick`) so a bounded :class:`~repro.core.policies.PoolPolicy` can
    hide — not delete — entries that have gone unrefreshed too long."""

    def __init__(self):
        self.entries: Dict[Tuple[str, int], dict] = {}
        self.ages: Dict[Tuple[str, int], int] = {}

    def publish(self, user: str, head_params_stacked, nf: int,
                age: int = 0) -> None:
        for i in range(nf):
            entry = jax.tree_util.tree_map(lambda p: p[i], head_params_stacked)
            self.entries[(user, i)] = entry
            self.ages[(user, i)] = age

    def tick(self) -> None:
        """Advance every entry's age by one federated opportunity."""
        for k in self.ages:
            self.ages[k] += 1

    def age_of(self, user: str) -> int:
        """A user's publication age (its entries are published together)."""
        return self.ages.get((user, 0), 0)

    def stacked_for(self, exclude_user: str):
        """All pool heads from OTHER users, stacked to (ns, ...)."""
        keys = [k for k in sorted(self.entries) if k[0] != exclude_user]
        if not keys:
            return None, []
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[self.entries[k] for k in keys])
        return stacked, keys

    def fresh_mask(self, exclude_user: str, max_age: Optional[int] = None,
                   keys: Optional[List[Tuple[str, int]]] = None) -> np.ndarray:
        """Validity mask aligned with :meth:`stacked_for`'s sorted keys:
        True where the entry is young enough to be served (always, when
        `max_age` is None — last-write-wins).  Pass the `keys` that
        stacked_for returned to guarantee alignment with its rows."""
        from repro.core import faults as FT
        if keys is None:
            keys = [k for k in sorted(self.entries) if k[0] != exclude_user]
        if max_age is None:
            # Unbounded pools still hide quarantined rows (entries seeded
            # from an inadmissible head at FT.QUARANTINE_AGE) — a clean
            # republication resets the age and revives the row.
            return np.array([self.ages.get(k, 0) < FT.QUARANTINE_AGE
                             for k in keys], bool)
        return np.array([self.ages.get(k, 0) <= max_age for k in keys],
                        bool)


# ---------------------------------------------------------------------------
# Selection scoring (Eq. 7) + blending (Eq. 8)
# ---------------------------------------------------------------------------

@jax.jit
def pool_errors(pool_stacked, xd_i, y):
    """Mean squared preliminary-prediction error of every pool head on the
    client's last-R dense vectors of feature i.  xd_i: (R, w); y: (R,).
    Returns (ns,).  Non-finite errors (a NaN/Inf pool head or probe) are
    pinned to +inf so ``argmin`` never selects a poisoned candidate —
    finite scores pass through bit-exactly."""
    preds = N.head_pool_apply(pool_stacked, xd_i)      # (ns, R)
    errs = jnp.mean((y[None, :] - preds) ** 2, axis=1)
    return jnp.where(jnp.isfinite(errs), errs, jnp.inf)


@functools.lru_cache(maxsize=None)
def _pool_kernel_ops():
    """Cached resolver for the Pallas pool-scoring module: one import at
    first dispatch, not one per round (failed imports are NOT cached —
    lru_cache only memoizes successful returns)."""
    return importlib.import_module("repro.kernels.pool_mlp.ops")


def pool_errors_kernel(pool_stacked, xd_i, y):
    """Pallas fused pool sweep — compiled on TPU/GPU, interpreted elsewhere
    (see src/repro/kernels/pool_mlp)."""
    return _pool_kernel_ops().pool_mlp_errors(pool_stacked, xd_i, y)


def pool_kernel_available() -> bool:
    """ImportError only — a genuinely broken kernel module must surface, not
    silently fall back to the vmap path."""
    try:
        _pool_kernel_ops()
        return True
    except ImportError:
        return False


@jax.jit
def blend(target_heads_stacked, selected_stacked, alpha: float):
    """Eq. 8 applied to all nf heads at once."""
    return jax.tree_util.tree_map(
        lambda t, s: alpha * s + (1 - alpha) * t,
        target_heads_stacked, selected_stacked)


def federated_round(client: FederatedClient, pool: HeadPool,
                    rng: np.random.Generator) -> Optional[List[int]]:
    """One heterogeneous-transfer round for `client` (paper Fig. 6) under the
    client's legacy ``cfg.mode`` — a shim over
    :func:`repro.core.federation.policy_round` with the mode's policy bundle.
    Returns the selected pool indices per feature (for logging), or None."""
    from repro.core.federation import policy_round
    from repro.core.policies import FederationPolicies
    return policy_round(client, pool, rng,
                        FederationPolicies.from_config(client.cfg),
                        use_kernel=client.cfg.use_pool_kernel)


# ---------------------------------------------------------------------------
# Orchestration (legacy entry point over the Federation API)
# ---------------------------------------------------------------------------

def run_federated_training(clients: Sequence[FederatedClient],
                           cfg: HFLConfig, verbose: bool = False,
                           engine: str = "sequential"):
    """Decentralized HFL over a set of clients — compat shim over
    :class:`repro.core.federation.Federation` with the ``cfg.mode`` legacy
    shorthand expanded to an explicit policy bundle.

    engine="sequential": the reference oracle (Python loop, HeadPool object,
    host-side per-feature argmin); handles heterogeneous nf / ragged data.
    engine="batched": vmapped train steps + one fused selection scan per
    round; heterogeneous populations are cohort-planned automatically
    (see ``repro.core.cohorts``).  Both record the same history:
    {name: {"val": [...], "test": float, "rounds": int, "best_val": float,
    "selections": [[...], ...]}} — selections are indices into the pool
    sorted by (user, feature) excluding the client itself, identical across
    engines for modes hfl/always/no (random draws from different rng
    streams)."""
    from repro.core.federation import Federation
    return Federation(clients, cfg, engine=engine).fit(verbose=verbose)
