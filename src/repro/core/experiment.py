"""Experiment driver for the paper's evaluation protocol (§5).

Reproduces, on the simulated MIMIC-III (see repro.data.synthetic):
  * Table 5 — prediction evaluation, target = metavision (the smaller domain),
  * Table 6 — robustness, target = carevue,
  * Table 7 — ablation (no / random / always / hfl),
for each of the five label tasks per hospital (predict channel k from the
other four).

Systems: DNN, BIBE, BIBEP (benchmarks, trained on the target domain only) and
HFL (federated across both hospitals).  Protocol per §5.2: Adam lr 0.01,
50 epochs, batch = R periods, save-best on validation, MSE loss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as N
from repro.core.feature_tensors import pack_feature_tensors
from repro.core.federation import (Callback, Federation, RoundSchedule,
                                   fit_local)
from repro.core.hfl import FederatedClient, HFLConfig
from repro.core.policies import FederationPolicies
from repro.data import synthetic as syn
from repro.optim import adam, apply_updates
from repro.sharding import spec as S


# ---------------------------------------------------------------------------
# Data preparation
# ---------------------------------------------------------------------------

def _normalize_streams(data: syn.HospitalData):
    """Per-channel z-score using TRAIN-split statistics.  ALL channels
    (label included) are normalized for optimization; reported MSEs are
    rescaled back to raw units by sigma_label^2 (paper reports raw units)."""
    nf = data.streams[0].nf
    n_chan = nf + 1
    vals = {c: [] for c in range(n_chan)}
    for i in data.splits["train"]:
        s = data.streams[i]
        for c in range(n_chan):
            v = s.values[s.channels == c]
            if len(v):
                vals[c].append(v)
    mu = np.zeros(n_chan, np.float32)
    sd = np.ones(n_chan, np.float32)
    for c in range(n_chan):
        if vals[c]:
            allv = np.concatenate(vals[c])
            mu[c], sd[c] = allv.mean(), max(1e-6, allv.std())
    out = []
    for s in data.streams:
        v = s.values.copy()
        for c in range(n_chan):
            m = s.channels == c
            v[m] = (v[m] - mu[c]) / sd[c]
        out.append(dataclasses.replace(s, values=v))
    return out, float(mu[nf]), float(sd[nf])


def _scaled_patients(hospital: str, n_patients: Optional[int]):
    """Preserve the paper's domain-size asymmetry (Table 3: metavision is
    the smaller source) when a reduced budget is requested: `n_patients`
    sets the carevue count; metavision scales by the natural 58/120 ratio."""
    if n_patients is None:
        return None
    if hospital == "metavision":
        return max(6, int(round(n_patients * 58 / 120)))
    return n_patients


def task_data(hospital: str, label_idx: int, w: int, seed: int = 0,
              n_patients: Optional[int] = None, n_events: int = 400):
    """Packed (train, valid, test) tensors for predicting channel
    `label_idx` of `hospital` from its other channels."""
    data = syn.make_hospital(hospital, seed=seed,
                             n_patients=_scaled_patients(hospital, n_patients),
                             n_events=n_events)
    # relabel so channel `label_idx` plays the label role
    relabeled = syn.HospitalData(
        data.name, data.feature_names,
        [syn.relabel(s, label_idx) for s in data.streams], data.splits)
    relabeled.streams, mu_y, sd_y = _normalize_streams(relabeled)
    packed = {}
    for split in ("train", "valid", "test"):
        packed[split] = syn.packed_split(relabeled, split, w)
    packed["label_var"] = sd_y * sd_y    # raw-unit rescale for reported MSEs
    return packed


# ---------------------------------------------------------------------------
# Benchmark-system training (non-federated)
# ---------------------------------------------------------------------------

_SYSTEMS = {
    "dnn": (N.dnn_schema, N.dnn_loss, N.dnn_apply),
    "bibe": (N.bibe_schema, N.bibe_loss, N.bibe_apply),
    "bibep": (N.bibe_schema, N.bibe_loss, N.bibe_apply),
}


def train_benchmark(system: str, packed, nf: int, cfg: HFLConfig,
                    rng_seed: int = 0,
                    callbacks: Sequence[Callback] = ()) -> Dict[str, float]:
    """Train one non-federated benchmark system on the shared
    :class:`~repro.core.federation.RoundSchedule` (same epoch / R-batch /
    save-best protocol as the federated engines, via
    :func:`~repro.core.federation.fit_local`)."""
    schema_fn, loss_fn, apply_fn = _SYSTEMS[system]
    schema = schema_fn(nf, cfg.w)
    params = S.materialize(schema, jax.random.PRNGKey(rng_seed))
    opt = adam(cfg.lr)
    opt_state = opt.init(params)
    schedule = RoundSchedule(cfg.epochs, cfg.R)

    @jax.jit
    def step(params, opt_state, xs, xd, y):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, xs, xd, y)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state

    @jax.jit
    def mse(params, xs, xd, y):
        return jnp.mean((y - apply_fn(params, xs, xd)) ** 2)

    if system == "bibep":           # self-supervised pretraining phase
        @jax.jit
        def pstep(params, opt_state, xs, xd, key):
            loss, grads = jax.value_and_grad(N.bibe_pretrain_loss)(
                params, xs, xd, key)
            upd, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state

        key = jax.random.PRNGKey(rng_seed + 1)
        xs, xd, y = packed["train"]
        for e in range(5):
            for sl in schedule.slices(len(y)):
                key, sub = jax.random.split(key)
                params, opt_state = pstep(params, opt_state, xs[sl], xd[sl],
                                          sub)
        opt_state = opt.init(params)   # fresh optimizer for finetuning

    params, opt_state, best_params, best_val = fit_local(
        step, mse, params, opt_state, packed["train"], packed["valid"],
        schedule, callbacks=callbacks)
    scale = packed["label_var"]
    return {"valid": best_val * scale,
            "test": float(mse(best_params, *packed["test"])) * scale}


# ---------------------------------------------------------------------------
# HFL training (federated over both hospitals)
# ---------------------------------------------------------------------------

def train_hfl(target: str, label_idx: int, cfg: HFLConfig, seed: int = 0,
              n_patients=None, n_events: int = 400,
              verbose: bool = False,
              policies: Optional[FederationPolicies] = None,
              callbacks: Sequence[Callback] = ()) -> Dict[str, float]:
    source = "carevue" if target == "metavision" else "metavision"
    t_pack = task_data(target, label_idx, cfg.w, seed, n_patients, n_events)
    s_pack = task_data(source, label_idx, cfg.w, seed, n_patients, n_events)
    nf = t_pack["train"][0].shape[1]
    clients = [
        FederatedClient(target, nf, cfg, t_pack["train"], t_pack["valid"],
                        t_pack["test"], jax.random.PRNGKey(seed)),
        FederatedClient(source, nf, cfg, s_pack["train"], s_pack["valid"],
                        s_pack["test"], jax.random.PRNGKey(seed + 17)),
    ]
    fed = Federation(clients, cfg, policies=policies, callbacks=callbacks)
    hist = fed.fit(verbose=verbose)
    t_scale, s_scale = t_pack["label_var"], s_pack["label_var"]
    return {"valid": hist[target]["best_val"] * t_scale,
            "test": hist[target]["test"] * t_scale,
            "rounds": hist[target]["rounds"],
            "source_test": hist[source]["test"] * s_scale}


# ---------------------------------------------------------------------------
# N-hospital populations (batched-engine scale-out)
# ---------------------------------------------------------------------------

def _truncate_common(packs: List[dict]) -> List[dict]:
    """Truncate every client's split tensors to the population-wide minimum
    length so they stack along a leading client axis (batched engine)."""
    out = []
    mins = {s: min(len(p[s][2]) for p in packs)
            for s in ("train", "valid", "test")}
    for p in packs:
        q = dict(p)
        for s in ("train", "valid", "test"):
            q[s] = tuple(a[:mins[s]] for a in p[s])
        out.append(q)
    return out


def population_task_data(n_clients: int, w: int, seed: int = 0,
                         n_patients: int = 10, n_events: int = 300,
                         nf: int = 4) -> List[dict]:
    """Packed per-hospital tensors for an N-hospital generated population,
    truncated to common split lengths (stackable for the batched engine)."""
    pop = syn.make_population(n_clients, seed=seed, nf=nf,
                              n_patients=n_patients, n_events=n_events)
    return _truncate_common([_pack_hospital(data, w) for data in pop])


def population_clients(n_clients: int, cfg: HFLConfig, seed: int = 0,
                       n_patients: int = 10, n_events: int = 300
                       ) -> Tuple[List[FederatedClient], List[dict]]:
    """Freshly-constructed clients (plus their packed data dicts) for an
    N-hospital generated population — the building block for
    :func:`train_population` and for `Federation.restore` (which overlays a
    checkpoint onto clients built exactly like the originals)."""
    packs = population_task_data(n_clients, cfg.w, seed, n_patients, n_events)
    nf = packs[0]["train"][0].shape[1]
    clients = [
        FederatedClient(p["name"], nf, cfg, p["train"], p["valid"], p["test"],
                        jax.random.PRNGKey(seed + 31 * i))
        for i, p in enumerate(packs)]
    return clients, packs


def train_population(n_clients: int, cfg: HFLConfig, engine: str = "batched",
                     seed: int = 0, n_patients: int = 10,
                     n_events: int = 300, verbose: bool = False,
                     policies: Optional[FederationPolicies] = None,
                     callbacks: Sequence[Callback] = ()
                     ) -> Dict[str, Dict[str, float]]:
    """Federated training over an N-hospital generated population.  Returns
    the per-client history with test/best_val rescaled to raw units."""
    clients, packs = population_clients(n_clients, cfg, seed, n_patients,
                                        n_events)
    fed = Federation(clients, cfg, engine=engine, policies=policies,
                     callbacks=callbacks)
    hist = fed.fit(verbose=verbose)
    for p in packs:
        h = hist[p["name"]]
        h["test"] *= p["label_var"]
        h["best_val"] *= p["label_var"]
    return hist


def _pack_hospital(data: syn.HospitalData, w: int) -> dict:
    """Normalize + pack one hospital's splits (shared by the homogeneous
    and heterogeneous population pipelines)."""
    streams, mu_y, sd_y = _normalize_streams(data)
    data = syn.HospitalData(data.name, data.feature_names, streams,
                           data.splits)
    packed = {"name": data.name,
              "nf": len(data.feature_names)}
    for split in ("train", "valid", "test"):
        packed[split] = syn.packed_split(data, split, w)
    packed["label_var"] = sd_y * sd_y
    return packed


def hetero_population_task_data(n_clients: int, w: int, seed: int = 0,
                                n_patients: int = 10, n_events: int = 300,
                                nf_choices: Sequence[int] = (3, 4, 5),
                                group_truncate: bool = True) -> List[dict]:
    """Packed per-hospital tensors for a MIXED-nf generated population — the
    cohort engine's workload.  With ``group_truncate`` (default) split
    lengths are truncated to the minimum *within each nf group*, so each
    group stacks into one cohort (lengths still differ ACROSS groups —
    mixed-nf AND ragged).  ``group_truncate=False`` keeps every hospital's
    natural lengths: fully ragged, the cohort planner degrades gracefully
    to singleton cohorts."""
    pop = syn.make_hetero_population(n_clients, seed=seed,
                                     nf_choices=nf_choices,
                                     n_patients=n_patients,
                                     n_events=n_events)
    packs = [_pack_hospital(data, w) for data in pop]
    if not group_truncate:
        return packs
    groups: Dict[int, List[dict]] = {}
    for p in packs:
        groups.setdefault(p["nf"], []).append(p)
    out_by_name = {}
    for nf, ps in groups.items():
        for q in _truncate_common(ps):
            out_by_name[q["name"]] = q
    return [out_by_name[p["name"]] for p in packs]


def hetero_population_clients(n_clients: int, cfg: HFLConfig, seed: int = 0,
                              n_patients: int = 10, n_events: int = 300,
                              nf_choices: Sequence[int] = (3, 4, 5),
                              group_truncate: bool = True
                              ) -> Tuple[List[FederatedClient], List[dict]]:
    """Freshly-constructed mixed-nf clients (plus their packed data dicts)
    — the heterogeneous twin of :func:`population_clients`.  Feed them to
    ``Federation(engine="batched")`` and the cohort engine plans/stacks
    them automatically (see ``repro.core.cohorts``)."""
    packs = hetero_population_task_data(n_clients, cfg.w, seed, n_patients,
                                        n_events, nf_choices, group_truncate)
    clients = [
        FederatedClient(p["name"], p["nf"], cfg, p["train"], p["valid"],
                        p["test"], jax.random.PRNGKey(seed + 31 * i))
        for i, p in enumerate(packs)]
    return clients, packs


def lazy_hetero_population(n_clients: int, cfg: HFLConfig, seed: int = 0,
                           n_patients: int = 8, n_events: int = 400,
                           nf_choices: Sequence[int] = (3, 4, 5),
                           split_caps: Tuple[int, int, int] = (160, 40, 40),
                           weighted_sizes: bool = False):
    """A (possibly huge) mixed-nf population as a lazy
    :class:`repro.core.participation.ClientPopulation` — nothing is
    generated up front except the O(N) nf layout; each participation wave
    materializes exactly its sampled hospitals through
    :func:`repro.data.synthetic.make_hospital_at` (index-addressable, so
    hospital 73 041 never requires hospitals 0..73 040).

    Feature counts cycle ``nf_choices`` (hospital i gets
    ``nf_choices[i % len(nf_choices)]``), giving deterministic equal-size
    nf strata.  Splits are truncated to ``split_caps`` events so every
    same-nf client in a wave shares one geometry (one cohort per stratum,
    and a compile-cache hit when per-stratum sample counts repeat — use
    ``StratifiedParticipation``); a hospital whose natural split is
    shorter than its cap keeps its own length and degrades to a singleton
    cohort, still correct.  Rebuilding an index in a later wave yields the
    same data and the same fresh init key (``PRNGKey(seed + 31*i)``), the
    :class:`~repro.core.participation.ClientStore` contract.

    ``weighted_sizes`` declares per-hospital ``n_patients`` draws as
    sampling weights for ``WeightedParticipation`` — an O(N) spec sweep at
    declaration time, so leave it off for 10⁵-client uniform/stratified
    runs."""
    from repro.core.participation import ClientPopulation
    nf_choices = tuple(int(x) for x in nf_choices)
    nfs = np.array([nf_choices[i % len(nf_choices)]
                    for i in range(n_clients)], np.int64)
    caps = tuple(int(c) for c in split_caps)

    def build(indices):
        out = []
        for i in indices:
            data = syn.make_hospital_at(seed, int(i), int(nfs[i]),
                                        n_patients=n_patients,
                                        n_events=n_events)
            p = _pack_hospital(data, cfg.w)
            splits = tuple(tuple(a[:c] for a in p[s])
                           for s, c in zip(("train", "valid", "test"), caps))
            out.append(FederatedClient(p["name"], p["nf"], cfg, *splits,
                                       jax.random.PRNGKey(seed + 31 * i)))
        return out

    sizes = syn.population_sizes_at(seed, range(n_clients), nfs) \
        if weighted_sizes else None
    return ClientPopulation(size=n_clients, nfs=nfs, build=build,
                            sizes=sizes,
                            name_of=lambda i: f"h{i:06d}")


def tensor_population(n_clients: int, cfg: HFLConfig, seed: int = 0,
                      nf_choices: Sequence[int] = (4,),
                      n_train: int = 120, n_eval: int = 40,
                      weighted_sizes: bool = False):
    """A lazy population of deterministic random-tensor clients — the
    synthetic-physiology-free twin of :func:`lazy_hetero_population` for
    benchmarks and mesh runs.

    Every client of one nf shares EXACTLY one geometry (no ragged splits,
    unlike packed event streams whose lengths follow each hospital's label
    frequency), so any stratified sample shards over a mesh whose device
    count divides the per-stratum counts, and wave cohort plans are
    geometry-stable.  Client i's tensors and init key depend only on
    ``(seed, i)`` (``default_rng(seed*1000003 + i)`` /
    ``PRNGKey(seed + 31*i)``) — the same lazy-rebuild contract as the
    synthetic builder.  ``weighted_sizes`` declares deterministic per-client
    weights (for ``WeightedParticipation``) without building anything."""
    from repro.core.participation import ClientPopulation
    nf_choices = tuple(int(x) for x in nf_choices)
    nfs = np.array([nf_choices[i % len(nf_choices)]
                    for i in range(n_clients)], np.int64)

    def build(indices):
        out = []
        for i in indices:
            nf = int(nfs[i])
            rng = np.random.default_rng(seed * 1000003 + int(i))
            mk = lambda m: (rng.normal(size=(m, nf, cfg.w))
                            .astype(np.float32),
                            rng.normal(size=(m, nf, cfg.w))
                            .astype(np.float32),
                            rng.normal(size=m).astype(np.float32))
            out.append(FederatedClient(f"h{int(i):06d}", nf, cfg,
                                       mk(n_train), mk(n_eval), mk(n_eval),
                                       jax.random.PRNGKey(seed + 31 * i)))
        return out

    sizes = 1.0 + (np.arange(n_clients) * 2654435761 % 97) \
        if weighted_sizes else None
    return ClientPopulation(size=n_clients, nfs=nfs, build=build,
                            sizes=sizes,
                            name_of=lambda i: f"h{i:06d}")


def run_task(target: str, label_idx: int, systems: Sequence[str],
             cfg: HFLConfig, seed: int = 0, n_patients=None,
             n_events: int = 400) -> Dict[str, Dict[str, float]]:
    """One row of Table 5/6: every system on one (hospital, label) task."""
    packed = task_data(target, label_idx, cfg.w, seed, n_patients, n_events)
    nf = packed["train"][0].shape[1]
    out = {}
    for sys_name in systems:
        if sys_name == "hfl":
            out[sys_name] = train_hfl(target, label_idx, cfg, seed,
                                      n_patients, n_events)
        elif sys_name.startswith("hfl-"):
            mode = sys_name.split("-", 1)[1]
            out[sys_name] = train_hfl(target, label_idx,
                                      dataclasses.replace(cfg, mode=mode),
                                      seed, n_patients, n_events)
        else:
            out[sys_name] = train_benchmark(sys_name, packed, nf, cfg, seed)
    return out
