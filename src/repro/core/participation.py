"""Sampled partial participation over a host-resident client store.

Production federation is 10⁴–10⁶ clients with a *fraction* participating
per round — the classic FedAvg ``client_fraction`` regime — while every
engine in :mod:`repro.core.federation` assumes the whole population's
params/opt-states are stacked device-resident.  This module inverts that
memory model:

  * The **head pool (+ ages)** is the only always-resident structure
    (host numpy copies between waves); it CARRIES across waves, so
    knowledge transfer spans the whole population transitively — a head
    blended from wave-1 partners is what wave-5 partners select against.
  * Client params / opt-states / best-params live in a host-side
    :class:`ClientStore` (numpy arrays keyed by client name, bit-exact
    round-trip), populated lazily: only clients that have ever been
    sampled occupy store memory.
  * The population itself is a :class:`ClientPopulation` — O(N) cheap
    metadata (feature counts, optional sizes) plus a ``build(indices)``
    factory that materializes exactly the sampled subset, so a 100k-client
    population never exists in memory at once.

Each **wave** (one federated epoch over a sampled subset) a seeded
:class:`ParticipationPolicy` — the fifth pluggable policy protocol
alongside switch/selection/transfer/pool, registered through the same
:func:`repro.core.policies.register_policy` hook — samples the active set;
:class:`ParticipatingFederation` gathers the sampled clients' stored state
to device, runs the existing fused epoch on the gathered view (batched,
cohorted, and mesh engines all unchanged — an inner
:class:`~repro.core.federation.Federation` over the subset), and scatters
the updated state back.  The device working set is bounded by the sample
size, never the population (``dispatch_stats["resident_state_bytes"]``).

Semantics are the subset-federation semantics: a wave's Eq.-7 selection
sees the sampled clients' pool entries (with values carried from their
previous waves), and selections for the sampled subset are IDENTICAL to a
sequential oracle run on that same subset — the inner federation with
``engine="sequential"`` *is* that oracle, so parity is inherited from the
engine-parity invariant rather than re-proven.  Entry ages tick per
exchange opportunity while their owner is resident and stand still
otherwise (age = staleness among the exchanges the owner could have
refreshed at).

All three RNG streams (participation sampler, selection, switching) and
the device PRNG key persist across waves and checkpoint with the store,
so a sampled run is replayable: same seed ⇒ identical participation
schedule, bit-identical histories, including across ``save``/``restore``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import faults as FT
from repro.core import mesh_federation as MF
from repro.core import telemetry as TEL
from repro.core import trust as TR
from repro.core.federation import (Federation, RoundSchedule, _tree_bytes)
from repro.core.hfl import FederatedClient, HFLConfig
from repro.core.policies import (FederationPolicies, _Spec, policy_from_spec,
                                 register_policy)


def host_tree(tree):
    """A bit-exact host copy of a pytree: every leaf as a numpy array.
    ``np.asarray`` on a device array is a dtype-preserving byte copy, so a
    store round-trip (device → store → device) is exact."""
    return jax.tree_util.tree_map(np.asarray, tree)


# ---------------------------------------------------------------------------
# ClientStore — host-resident learnable state
# ---------------------------------------------------------------------------

class StoreCorruption(RuntimeError):
    """A stored entry failed its checksum after the bounded reread budget.
    The orchestrator's recovery is to discard the entry and rebuild the
    client from its deterministic per-index builder (see
    :meth:`ParticipatingFederation.fit`)."""


def entry_checksum(entry: dict) -> int:
    """crc32 over every byte a store entry round-trips: the three numpy
    trees' leaf buffers plus the float64 encodings of best_val and the
    val history.  Bit-exact round-trip ⇒ checksum match; any single-byte
    corruption flips it."""
    crc = 0
    for tree in (entry["params"], entry["opt_state"], entry["best_params"]):
        for leaf in jax.tree_util.tree_leaves(tree):
            crc = zlib.crc32(np.ascontiguousarray(leaf).tobytes(), crc)
    crc = zlib.crc32(np.float64(entry["best_val"]).tobytes(), crc)
    crc = zlib.crc32(np.asarray(entry["val_history"],
                                np.float64).tobytes(), crc)
    return crc


class ClientStore:
    """Host-side store of per-client learnable state (params / opt_state /
    best_params as numpy trees, plus best_val + val_history scalars).

    Grows only with clients that have actually been sampled — a population
    index never drawn costs nothing here; its first wave starts from the
    deterministic fresh init its :class:`ClientPopulation` builds.  Values
    are bit-exact round-trips of whatever was scattered in.

    Every entry carries a crc32 over its leaf bytes, written at
    :meth:`put` and verified at :meth:`get` with a bounded reread budget
    (``GET_RETRIES``).  A persistent mismatch raises
    :class:`StoreCorruption` — the store never silently serves corrupted
    state."""

    GET_RETRIES = 3

    def __init__(self):
        self._states: Dict[str, dict] = {}
        self._crcs: Dict[str, int] = {}

    def put(self, name: str, *, params, opt_state, best_params,
            best_val: float, val_history: Sequence[float]) -> None:
        entry = {
            "params": host_tree(params),
            "opt_state": host_tree(opt_state),
            "best_params": host_tree(best_params),
            "best_val": float(best_val),
            "val_history": [float(v) for v in val_history],
        }
        self._states[name] = entry
        self._crcs[name] = entry_checksum(entry)

    def get(self, name: str) -> dict:
        entry = self._states[name]
        for _ in range(self.GET_RETRIES):
            if entry_checksum(entry) == self._crcs[name]:
                return entry
        raise StoreCorruption(
            f"store entry {name!r} failed checksum verification "
            f"{self.GET_RETRIES} times (host memory corruption); rebuild "
            f"it from the population's deterministic builder")

    def discard(self, name: str) -> None:
        """Drop an entry (the corruption-recovery path: the client's next
        wave starts from its deterministic fresh init again)."""
        self._states.pop(name, None)
        self._crcs.pop(name, None)

    def __contains__(self, name: str) -> bool:
        return name in self._states

    def __len__(self) -> int:
        return len(self._states)

    def names(self) -> List[str]:
        return sorted(self._states)

    def nbytes(self) -> int:
        """Host bytes held by the stored trees (the resident-store meter)."""
        return sum(_tree_bytes((s["params"], s["opt_state"],
                                s["best_params"]))
                   for s in self._states.values())


# ---------------------------------------------------------------------------
# ClientPopulation — lazy description of a (possibly huge) population
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClientPopulation:
    """A federated population as metadata + a lazy factory.

    ``size`` clients exist in principle; ``nfs[i]`` is client i's feature
    count (the stratified sampler's key — cheap to declare without building
    anything); ``sizes[i]``, when given, is its declared local dataset
    weight (the weighted sampler's probabilities); ``build(indices)``
    materializes exactly those clients, deterministically — calling it
    twice for the same index must produce the same name, data, and fresh
    parameter init, so a client rebuilt in a later wave is the same client.
    ``name_of(i)`` must match ``build``'s names (the store key)."""

    size: int
    nfs: np.ndarray
    build: Callable[[Sequence[int]], List[FederatedClient]]
    sizes: Optional[np.ndarray] = None
    name_of: Callable[[int], str] = lambda i: f"h{i:06d}"

    def __post_init__(self):
        self.nfs = np.asarray(self.nfs, np.int64)
        if self.nfs.shape != (self.size,):
            raise ValueError(f"nfs must have shape ({self.size},), "
                             f"got {self.nfs.shape}")
        if self.sizes is not None:
            self.sizes = np.asarray(self.sizes, np.float64)
            if self.sizes.shape != (self.size,):
                raise ValueError(f"sizes must have shape ({self.size},), "
                                 f"got {self.sizes.shape}")
            if not (self.sizes > 0).all():
                raise ValueError("sizes must be positive")

    def fingerprint(self) -> int:
        """Cheap identity check for checkpoints: size + feature layout."""
        return zlib.crc32(self.nfs.tobytes()) ^ self.size


# ---------------------------------------------------------------------------
# ParticipationPolicy — the fifth policy protocol (who is even present)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParticipationPolicy(_Spec):
    """Samples each wave's active subset of the population — host-side only
    (it runs before any engine is built, so unlike the four jitted-bundle
    protocols it never becomes a static jit argument).  Implementations
    must be deterministic functions of ``(population, rng state)`` so a
    seeded run is replayable, and must return SORTED global indices so the
    wave's client order — and with it cohort planning and the selection
    log — is engine-independent.

    ``fraction`` of the population participates per wave (at least
    ``min_clients``, at most all); ``multiple_of`` (the mesh device count,
    see :func:`repro.core.mesh_federation.participation_multiple`) rounds
    counts so the sampled set shards evenly."""

    fraction: float = 0.1
    min_clients: int = 2

    def __post_init__(self):
        if not 0 < self.fraction <= 1:
            raise ValueError(f"fraction must be in (0, 1], "
                             f"got {self.fraction}")
        if self.min_clients < 1:
            raise ValueError(f"min_clients must be >= 1, "
                             f"got {self.min_clients}")

    def n_active(self, N: int, multiple_of: int = 1) -> int:
        """The wave's sample size: fraction·N clamped to
        [min_clients, N], then rounded UP to ``multiple_of`` (capped at the
        largest multiple ≤ N)."""
        if N < 1:
            raise ValueError("empty population")
        n = min(N, max(self.min_clients, int(round(self.fraction * N))))
        if multiple_of > 1:
            if N < multiple_of:
                raise ValueError(
                    f"population of {N} cannot shard over {multiple_of} "
                    f"devices (need at least one client per device)")
            n = min(N - N % multiple_of,
                    -(-n // multiple_of) * multiple_of)
        return n

    def sample(self, population: ClientPopulation,
               rng: np.random.Generator, *,
               multiple_of: int = 1) -> np.ndarray:
        raise NotImplementedError


@register_policy
@dataclasses.dataclass(frozen=True)
class UniformParticipation(ParticipationPolicy):
    """Classic FedAvg client sampling: every client equally likely, without
    replacement."""

    def sample(self, population, rng, *, multiple_of=1):
        n = self.n_active(population.size, multiple_of)
        return np.sort(rng.choice(population.size, size=n, replace=False))


@register_policy
@dataclasses.dataclass(frozen=True)
class WeightedParticipation(ParticipationPolicy):
    """Size-weighted sampling: probability ∝ ``population.sizes`` (local
    dataset size), without replacement — large hospitals participate more
    often, mirroring FedAvg's size-weighted aggregation."""

    def sample(self, population, rng, *, multiple_of=1):
        if population.sizes is None:
            raise ValueError(
                "WeightedParticipation requires population.sizes "
                "(per-client dataset sizes); declare them on the "
                "ClientPopulation or use UniformParticipation")
        n = self.n_active(population.size, multiple_of)
        p = population.sizes / population.sizes.sum()
        return np.sort(rng.choice(population.size, size=n,
                                  replace=False, p=p))


@register_policy
@dataclasses.dataclass(frozen=True)
class StratifiedParticipation(ParticipationPolicy):
    """Stratified-by-cohort sampling: the wave quota is apportioned across
    nf strata (largest-remainder method, ascending-nf order) and drawn
    uniformly within each stratum.

    Two properties make this THE policy for heterogeneous populations:
    per-stratum counts are deterministic in the population alone, so every
    wave's :class:`~repro.core.cohorts.CohortPlan` has the same geometry
    (compile-cache hits instead of a recompile per wave); and with
    ``multiple_of=D`` each stratum count is rounded to the device count,
    which is exactly the mesh cohort engine's every-cohort-divides-D
    requirement (strata too small for one multiple are skipped)."""

    def sample(self, population, rng, *, multiple_of=1):
        from repro.core.cohorts import nf_strata
        strata = nf_strata(population.nfs)
        n = self.n_active(population.size, 1)
        # largest-remainder apportionment of n over strata
        quotas = {k: n * len(ix) / population.size
                  for k, ix in strata.items()}
        counts = {k: int(q) for k, q in quotas.items()}
        rem = n - sum(counts.values())
        for k in sorted(quotas, key=lambda k: (-(quotas[k] - counts[k]), k)):
            if rem <= 0:
                break
            counts[k] += 1
            rem -= 1
        if multiple_of > 1:
            counts = {k: min(len(strata[k]) - len(strata[k]) % multiple_of,
                             -(-c // multiple_of) * multiple_of)
                      for k, c in counts.items() if c > 0}
            counts = {k: c for k, c in counts.items() if c > 0}
            if not counts:
                sizes = {k: len(v) for k, v in strata.items()}
                raise ValueError(
                    f"no stratum of {sizes} can host a multiple of "
                    f"{multiple_of} sampled clients")
        picks = [rng.choice(ix, size=counts[k], replace=False)
                 for k, ix in strata.items() if counts.get(k, 0) > 0]
        return np.sort(np.concatenate(picks))


# ---------------------------------------------------------------------------
# ParticipatingFederation — the wave orchestrator
# ---------------------------------------------------------------------------

class ParticipatingFederation:
    """Federated training over a sampled fraction of a lazy population.

    Each wave: sample indices → ``population.build`` exactly those clients
    → overlay their stored state (params/opt/best + val history) and pool
    entries (+ ages) from the previous waves they appeared in → run ONE
    federated epoch as an inner :class:`Federation` over the subset
    (``engine``/``mesh`` pass straight through, so the batched, cohorted,
    and mesh engines all run unchanged on the gathered view) → scatter the
    updated state back to the :class:`ClientStore` and the resident pool.

    ``schedule.epochs`` is the total wave budget; ``schedule.R`` and
    ``exchange_every`` apply within each wave.  ``fit(waves=k)`` runs k
    more waves.  ``save``/``restore`` checkpoint the store, the pool, the
    sampler RNG, and both engine RNG streams — resuming mid-schedule
    replays the exact participation schedule and histories an
    uninterrupted run would have produced.

    ``faults=`` takes a :class:`~repro.core.faults.FaultPlan`: each wave
    the seeded injector drops clients (the wave re-rounds its geometry and
    proceeds degraded), marks stragglers (they train but miss every
    exchange, aging their pool entries), and corrupts byzantine clients'
    heads (quarantined by the inner engines' pool admission guard).  The
    plan spec and the accumulated fault log ride the checkpoint manifest,
    so a restored run replays the identical failure scenario.

    ``trust=`` takes a :class:`~repro.core.trust.TrustPlan`: the inner
    engines run their trust hooks each wave (masks/noise keyed by the
    GLOBAL wave number and client ids, so derivations are wave-unique and
    engine-independent), while the orchestrator owns the cross-wave state:
    a per-client :class:`~repro.core.trust.DPAccountant` composing epsilon
    over every wave, and a :class:`~repro.core.trust.ReputationBook` that
    strikes clients failing watermark verification and QUARANTINES repeat
    offenders — dropped from subsequent waves (geometry re-rounded like
    dropout; a wave never goes empty, so if every sampled client is
    quarantined the first-drawn are revived) with their resident pool rows
    zeroed at ``faults.QUARANTINE_AGE``.  Both books ride the checkpoint
    manifest bit-identically."""

    def __init__(self, population: ClientPopulation,
                 cfg: Optional[HFLConfig] = None, *,
                 policies: Optional[FederationPolicies] = None,
                 participation: Optional[ParticipationPolicy] = None,
                 schedule: Optional[RoundSchedule] = None,
                 engine: str = "batched",
                 mesh=None,
                 sample_multiple: Optional[int] = None,
                 faults: Optional[FT.FaultPlan] = None,
                 trust: Optional[TR.TrustPlan] = None,
                 telemetry: Optional[TEL.TelemetryPlan] = None):
        self.population = population
        self.cfg = cfg or HFLConfig()
        self.policies = policies if policies is not None \
            else FederationPolicies.from_config(self.cfg)
        self.participation = participation or UniformParticipation()
        self.schedule = schedule or RoundSchedule(self.cfg.epochs,
                                                  self.cfg.R)
        if engine not in ("sequential", "batched"):
            raise ValueError(f"unknown engine {engine!r}")
        if mesh is not None and engine != "batched":
            raise ValueError("mesh= requires engine='batched'")
        self.engine = engine
        self.mesh = mesh
        # deterministic fault injection (core/faults.py): a disabled or
        # absent plan is exactly "no faults" — the wave loop and the inner
        # engines run their historical bit-identical paths
        self.faults = faults
        self._injector = FT.FaultInjector(faults) \
            if faults is not None and faults.enabled else None
        self.fault_log: List[FT.WaveFaults] = []
        # trust layer (core/trust.py): the inner engines privatize/verify
        # per wave; the orchestrator composes the cross-wave books
        if trust is not None and not isinstance(trust, TR.TrustPlan):
            raise TypeError(f"trust: expected a TrustPlan, "
                            f"got {type(trust).__name__}")
        self.trust = trust
        self._trust = trust if trust is not None and trust.enabled else None
        self.accountant = (TR.DPAccountant(trust.dp)
                           if self._trust is not None
                           and trust.dp is not None else None)
        self.reputation = (TR.ReputationBook(trust.watermark)
                           if self._trust is not None
                           and trust.watermark is not None else None)
        self.clip_events = 0
        self.wm_failures: Dict[str, int] = {}
        # telemetry: ONE flight recorder spans all waves — each wave's inner
        # Federation is handed this recorder (its spans and in-graph round
        # series land in the shared ring buffer), so the exported trace
        # shows the whole sampled run: sample → gather → exchange(fit(
        # dispatch…)) → scatter per wave
        if telemetry is not None \
                and not isinstance(telemetry, TEL.TelemetryPlan):
            raise TypeError(f"telemetry: expected a TelemetryPlan, "
                            f"got {type(telemetry).__name__}")
        self.telemetry = telemetry
        self._telemetry = telemetry if telemetry is not None \
            and telemetry.enabled else None
        self._recorder = (TEL.FlightRecorder(self._telemetry)
                          if self._telemetry is not None else None)
        # the granularity sampled counts are rounded to — defaults to the
        # mesh device count; pass it explicitly to reproduce a D-device
        # run's exact participation schedule on another engine/mesh (the
        # oracle-parity tests' lever: the sequential oracle with
        # sample_multiple=D sees the same subsets a D-device mesh run does)
        self.sample_multiple = sample_multiple
        self.store = ClientStore()
        # the always-resident structure: head-pool entries + ages, host-side
        self.pool_entries: Dict[tuple, dict] = {}
        self.pool_ages: Dict[tuple, int] = {}
        self.wave = 0
        self.n_rounds: Dict[str, int] = {}
        self.selections: Dict[str, list] = {}
        self.last_test: Dict[str, float] = {}
        self.wave_log: List[dict] = []
        seed = self.cfg.seed
        # sampler stream distinct from both engine streams (which keep the
        # inner Federation's seeds so a full-participation wave IS a plain
        # Federation epoch)
        self._part_rng = np.random.default_rng(
            np.random.SeedSequence([seed, 0x9A]))
        self._sel_rng = np.random.default_rng(seed)
        self._switch_rng = np.random.default_rng(
            np.random.SeedSequence([seed, 0x5F]))
        self._key = jax.random.PRNGKey(seed)
        self.dispatch_stats: Optional[dict] = None

    # -- training ----------------------------------------------------------

    def _wave_multiple(self) -> int:
        if self.sample_multiple is not None:
            return self.sample_multiple
        return MF.participation_multiple(
            self.mesh if self.mesh is not None
            and MF.mesh_devices(self.mesh) > 1 else None)

    def fit(self, waves: Optional[int] = None, verbose: bool = False):
        """Run ``waves`` more sampling waves (default: up to
        ``schedule.epochs`` total) and return per-touched-client history
        {name: {val, rounds, best_val, selections, test}} — ``test`` is
        the client's test MSE as of its LAST resident wave (test data is
        not resident between waves)."""
        target = self.schedule.epochs if waves is None \
            else self.wave + waves
        mult = self._wave_multiple()
        n_waves = 0
        gather_bytes = scatter_bytes = 0
        resident_clients = resident_bytes = 0
        dispatches = exchange_rounds = pool_bytes = 0
        heads_rejected = clients_dropped = stragglers_n = 0
        waves_degraded = store_rebuilds = 0
        cohorts_max = 1
        path = None
        quarantined_drops = 0
        rec = self._recorder
        while self.wave < target:
            with TEL.span(rec, "sample", wave=self.wave):
                idx = self.participation.sample(self.population,
                                                self._part_rng,
                                                multiple_of=mult)
                active = [int(i) for i in idx]
                if self.reputation is not None:
                    # reputation quarantine: strip quarantined clients from
                    # the wave BEFORE fault injection / building (geometry
                    # re-rounded like dropout; the sampler's RNG sequence
                    # is untouched, so the participation schedule stays
                    # replayable)
                    quar = [i for i in active
                            if self.reputation.is_quarantined(
                                self.population.name_of(i))]
                    if quar:
                        active, _ = FT.reround_wave(active, quar, mult)
                        quarantined_drops += len(quar)
                        if rec is not None:
                            rec.count("quarantined_drops", len(quar))
                wf = None
                if self._injector is not None:
                    # dropout-tolerant wave: drop drawn clients and
                    # re-round the geometry BEFORE anything is built or
                    # gathered — the fused engines never see a ragged
                    # stack.  The draw is a pure function of (plan.seed,
                    # wave, index), so a restored run replays the
                    # identical degraded schedule.
                    wf = self._injector.wave_faults(self.wave, active, mult)
                    dropped = set(wf.dropped)
                    active = [i for i in active if i not in dropped]
                    self.fault_log.append(wf)
                    clients_dropped += len(wf.dropped)
                    stragglers_n += len(wf.stragglers)
                    waves_degraded += int(wf.degraded)
                    if rec is not None:
                        if wf.dropped:
                            rec.count("clients_dropped", len(wf.dropped))
                        if wf.stragglers:
                            rec.count("stragglers", len(wf.stragglers))
                        if wf.degraded:
                            rec.count("waves_degraded", 1)
            with TEL.span(rec, "gather", wave=self.wave,
                          clients=len(active)):
                clients = self.population.build(active)
                names = [self.population.name_of(i) for i in active]
                got = [c.name for c in clients]
                if got != names:
                    raise ValueError(
                        f"population.build returned names {got} for "
                        f"indices {active}, expected {names} (name_of and "
                        f"build must agree — the store is keyed by name)")
                # gather: stored state onto the freshly built clients.  A
                # checksum-corrupt entry is discarded and the client
                # rebuilt from its deterministic fresh init (the
                # self-healing path).
                for c in clients:
                    if c.name in self.store:
                        try:
                            st = self.store.get(c.name)
                        except StoreCorruption:
                            self.store.discard(c.name)
                            store_rebuilds += 1
                            if rec is not None:
                                rec.count("store_rebuilds", 1)
                            continue
                        c.params = st["params"]
                        c.opt_state = st["opt_state"]
                        c.best_params = st["best_params"]
                        c.best_val = st["best_val"]
                        c.val_history = list(st["val_history"])
                if wf is not None and wf.byzantine:
                    # byzantine clients' heads are corrupted host-side
                    # before the wave trains; the inner Federation's
                    # admission guard quarantines the poisoned publication
                    # at pool-seed time and rejects any poisoned
                    # republication in-graph
                    byz = set(wf.byzantine)
                    for c, i in zip(clients, active):
                        if i in byz:
                            c.params = dict(c.params)
                            c.params["heads"] = \
                                self._injector.corrupt_heads(
                                    c.params["heads"], self.wave, i)
            fed = Federation(
                clients, self.cfg, policies=self.policies,
                schedule=RoundSchedule(1, self.schedule.R,
                                       self.schedule.exchange_every),
                engine=self.engine, mesh=self.mesh, faults=self.faults,
                trust=self.trust, telemetry=self.telemetry)
            if self._recorder is not None:
                # ONE ring buffer for the whole sampled run: the inner
                # Federation's spans, round series, and counters land in
                # this orchestrator's recorder instead of a per-wave one
                fed._recorder = self._recorder
            # trust derivations (pairwise masks, oracle DP noise) key on the
            # GLOBAL wave number and GLOBAL client ids: unique per wave,
            # identical across engines/meshes for the same sampled subset
            fed._trust_wave_base = self.wave
            fed._trust_ids = tuple(active)
            if wf is not None and wf.stragglers:
                # stragglers train but miss every exchange this wave: the
                # engines mask their switch off, so their pool entries age
                # under the bounded-staleness clock
                strag = set(wf.stragglers)
                fed._straggler_mask = np.array([i in strag for i in active],
                                               bool)
            # the RNG streams and device key persist ACROSS waves: the
            # generators are shared by reference (mutated in place by the
            # inner fit), the key is threaded through explicitly
            fed._sel_rng = self._sel_rng
            fed._switch_rng = self._switch_rng
            fed._key = self._key
            # pool carry: clients seen before serve their carried entries
            # (+ ages); first-timers keep the fresh publication the inner
            # Federation just made (asynchronous start, age 0)
            for c in clients:
                for f in range(c.nf):
                    k = (c.name, f)
                    if k in self.pool_entries:
                        fed.pool.entries[k] = self.pool_entries[k]
                        fed.pool.ages[k] = self.pool_ages[k]
            with TEL.span(rec, "exchange", wave=self.wave):
                hist = fed.fit()
            self._key = fed._key
            # scatter: updated state back to the store, pool back to the
            # resident pool
            with TEL.span(rec, "scatter", wave=self.wave):
                for c in fed.clients:
                    self.store.put(c.name, params=c.params,
                                   opt_state=c.opt_state,
                                   best_params=c.best_params,
                                   best_val=c.best_val,
                                   val_history=c.val_history)
                    self.n_rounds[c.name] = (self.n_rounds.get(c.name, 0)
                                             + fed.n_rounds[c.name])
                    self.selections.setdefault(c.name, []).extend(
                        fed.selections[c.name])
                    self.last_test[c.name] = hist[c.name]["test"]
                    for f in range(c.nf):
                        k = (c.name, f)
                        self.pool_entries[k] = host_tree(
                            fed.pool.entries[k])
                        self.pool_ages[k] = int(fed.pool.ages[k])
            newly_q: List[str] = []
            if self._trust is not None:
                # fold the wave's trust counters into the cross-wave books
                self.clip_events += fed._clip_events
                if self.accountant is not None:
                    for nm, k in sorted(fed._dp_counts.items()):
                        self.accountant.record(nm, k)
                for nm, k in sorted(fed._wm_failures.items()):
                    if k:
                        self.wm_failures[nm] = (self.wm_failures.get(nm, 0)
                                                + int(k))
                        if self.reputation is not None \
                                and self.reputation.strike(nm):
                            newly_q.append(nm)
                # quarantine action: a newly quarantined client's resident
                # pool rows are zeroed at the QUARANTINE sentinel, so no
                # engine ever serves its poisoned knowledge again
                for nm in newly_q:
                    for k in list(self.pool_entries):
                        if k[0] == nm:
                            self.pool_entries[k] = jax.tree_util.tree_map(
                                np.zeros_like, self.pool_entries[k])
                            self.pool_ages[k] = FT.QUARANTINE_AGE
            st = fed.dispatch_stats or {}
            sb = int(st.get("state_bytes", 0))
            gather_bytes += sb
            scatter_bytes += sb
            resident_clients = max(resident_clients, len(clients))
            resident_bytes = max(resident_bytes, sb)
            dispatches += int(st.get("dispatches", 0))
            exchange_rounds += int(st.get("exchange_rounds", 0))
            pool_bytes += int(st.get("pool_bytes_gathered", 0))
            heads_rejected += int(st.get("heads_rejected", 0))
            cohorts_max = max(cohorts_max, int(st.get("cohorts", 1)))
            path = st.get("path", path)
            # a byzantine client's own validation goes NaN (it trains on
            # its corrupted state, sacrificially) — the wave mean reports
            # over the finite clients so the degradation curve stays real
            finals = [hist[n]["val"][-1] for n in names]
            finite = [v for v in finals if np.isfinite(v)]
            mean_val = float(np.mean(finite)) if finite else float("nan")
            row = {
                "wave": self.wave, "active": active,
                "mean_val": mean_val,
                "state_bytes": sb,
                "rounds": sum(fed.n_rounds.values()),
            }
            if wf is not None:
                row["dropped"] = list(wf.dropped)
                row["stragglers"] = list(wf.stragglers)
                row["byzantine"] = list(wf.byzantine)
            if self._trust is not None:
                if self.accountant is not None:
                    row["epsilon"] = self.accountant.max_epsilon
                if newly_q:
                    row["quarantined"] = newly_q
            self.wave_log.append(row)
            if verbose:
                print(f"[wave {self.wave:3d}] {len(clients)}/"
                      f"{self.population.size} clients  "
                      f"val={mean_val:9.4f}  resident={sb / 1e6:.1f}MB  "
                      f"store={len(self.store)}")
            self.wave += 1
            n_waves += 1
        self.dispatch_stats = {
            "engine": f"participating+{self.engine}",
            "path": path,
            "devices": MF.mesh_devices(self.mesh) if self.mesh is not None
            else 1,
            "cohorts": cohorts_max,
            "population": self.population.size,
            "participation": type(self.participation).__name__,
            "participation_fraction": self.participation.fraction,
            "waves": n_waves,
            "resident_clients": resident_clients,
            "resident_state_bytes": resident_bytes,
            "store_clients": len(self.store),
            "store_bytes": self.store.nbytes(),
            "gather_bytes": gather_bytes,
            "scatter_bytes": scatter_bytes,
            "epochs": n_waves,
            "dispatches": dispatches,
            "dispatches_per_epoch": dispatches / max(n_waves, 1),
            "exchange_every": self.schedule.exchange_every,
            "exchange_rounds": exchange_rounds,
            "pool_bytes_gathered": pool_bytes,
            "heads_rejected": heads_rejected,
            "clients_dropped": clients_dropped,
            "stragglers": stragglers_n,
            "waves_degraded": waves_degraded,
            "store_rebuilds": store_rebuilds,
            "epsilon_spent": (self.accountant.max_epsilon
                              if self.accountant is not None else 0.0),
            "clip_events": self.clip_events,
            "watermark_failures": sum(self.wm_failures.values()),
            "quarantined": (sorted(self.reputation.quarantined)
                            if self.reputation is not None else []),
            "quarantined_drops": quarantined_drops,
        }
        return self.results()

    def results(self):
        """Per-touched-client history in the legacy format (see fit)."""
        return {n: {"val": list(self.store.get(n)["val_history"]),
                    "test": self.last_test[n],
                    "rounds": self.n_rounds[n],
                    "best_val": float(self.store.get(n)["best_val"]),
                    "selections": [list(s) for s in self.selections[n]]}
                for n in self.store.names()}

    # -- persistence -------------------------------------------------------

    def save(self, directory) -> Path:
        """Checkpoint the orchestrator for replayable resume: the client
        store, the resident pool (+ ages), the participation sampler's RNG,
        both engine RNG streams, the device key, and every counter —
        restore + fit reproduces the exact waves and histories an
        uninterrupted run would have.  Same durable two-file layout as
        :meth:`Federation.save` (atomic manifest commit)."""
        d = Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        state = {
            "wave": self.wave,
            "store": {n: self.store.get(n) for n in self.store.names()},
            "pool": {f"{u}|{i}": e
                     for (u, i), e in self.pool_entries.items()},
            "key": np.asarray(self._key),
        }
        state_name = f"state_{self.wave:08d}.msgpack"
        ckpt.save(d / state_name, state)
        manifest = {
            "format": 1,
            "kind": "participating_federation",
            "state_file": state_name,
            "wave": self.wave,
            "engine": self.engine,
            "cfg": dataclasses.asdict(self.cfg),
            "policies": self.policies.spec(),
            "participation": self.participation.spec(),
            "schedule": {"epochs": self.schedule.epochs,
                         "R": self.schedule.R,
                         "exchange_every": self.schedule.exchange_every},
            "population_size": self.population.size,
            "population_fingerprint": self.population.fingerprint(),
            # the EFFECTIVE rounding multiple, so a restore reproduces this
            # run's exact schedule even onto a different mesh (or none)
            "sample_multiple": self._wave_multiple(),
            "n_rounds": self.n_rounds,
            "selections": self.selections,
            "last_test": self.last_test,
            "wave_log": self.wave_log,
            "pool_ages": {f"{u}|{i}": a
                          for (u, i), a in self.pool_ages.items()},
            "part_rng": self._part_rng.bit_generator.state,
            "sel_rng": self._sel_rng.bit_generator.state,
            "switch_rng": self._switch_rng.bit_generator.state,
            # the failure scenario rides the manifest: the plan spec
            # re-seeds the injector (draws are pure functions of
            # (seed, wave, index), so no RNG state to carry) and the log
            # records the faults that already fired, so a restored run
            # replays the exact degraded schedule
            "faults": (self.faults.spec()
                       if self.faults is not None else None),
            "fault_log": FT.fault_log_json(self.fault_log),
            # the trust books are integer counts / name sets — a JSON
            # round-trip is bit-identical by construction
            "trust": (self.trust.spec()
                      if self.trust is not None else None),
            "trust_state": {
                "accountant": (self.accountant.to_json()
                               if self.accountant is not None else None),
                "reputation": (self.reputation.to_json()
                               if self.reputation is not None else None),
                "clip_events": self.clip_events,
                "wm_failures": self.wm_failures,
            },
            # the flight recorder rides the manifest so a restored run
            # CONTINUES its trace: same ring, monotonically later
            # timestamps, counters picking up where they stopped
            "telemetry": (self.telemetry.spec()
                          if self.telemetry is not None else None),
            "telemetry_state": (self._recorder.to_json()
                                if self._recorder is not None else None),
        }
        tmp = d / "manifest.json.tmp"
        tmp.write_text(json.dumps(manifest))
        os.replace(tmp, d / "manifest.json")
        for p in d.glob("state_*.msgpack"):
            if p.name != state_name:
                p.unlink()
        return d

    @classmethod
    def restore(cls, directory, population: ClientPopulation, *,
                engine: Optional[str] = None,
                mesh=None,
                sample_multiple: Optional[int] = None
                ) -> "ParticipatingFederation":
        """Rebuild a saved orchestrator over the same (re-declared) lazy
        population.  The population is identity-checked by size + feature
        layout; its ``build`` is only ever called for newly sampled waves,
        with stored state overlaid as usual."""
        d = Path(directory)
        manifest = json.loads((d / "manifest.json").read_text())
        if manifest.get("kind") != "participating_federation":
            raise ValueError(
                f"{d} is not a ParticipatingFederation checkpoint "
                f"(kind={manifest.get('kind')!r}); Federation checkpoints "
                f"restore via Federation.restore")
        if manifest["population_size"] != population.size \
                or manifest["population_fingerprint"] \
                != population.fingerprint():
            raise ValueError(
                f"population mismatch: checkpoint was taken over "
                f"{manifest['population_size']} clients (fingerprint "
                f"{manifest['population_fingerprint']}), got "
                f"{population.size} ({population.fingerprint()}) — "
                f"re-declare the population with the same arguments")
        cfg = HFLConfig(**manifest["cfg"])
        fspec = manifest.get("faults")
        tspec = manifest.get("trust")
        espec = manifest.get("telemetry")
        fed = cls(population, cfg,
                  policies=FederationPolicies.from_spec(
                      manifest["policies"]),
                  participation=policy_from_spec(manifest["participation"]),
                  schedule=RoundSchedule(**manifest["schedule"]),
                  engine=engine or manifest["engine"],
                  mesh=mesh,
                  sample_multiple=sample_multiple
                  or manifest.get("sample_multiple"),
                  faults=policy_from_spec(fspec) if fspec else None,
                  trust=policy_from_spec(tspec) if tspec else None,
                  telemetry=policy_from_spec(espec) if espec else None)
        state = ckpt.load(d / manifest["state_file"])
        if state.get("wave") != manifest["wave"]:
            raise ValueError(
                f"checkpoint is torn: state file at wave "
                f"{state.get('wave')} but manifest at {manifest['wave']} — "
                f"re-save or fall back to an older checkpoint")
        for n, s in state["store"].items():
            fed.store.put(n, params=s["params"], opt_state=s["opt_state"],
                          best_params=s["best_params"],
                          best_val=s["best_val"],
                          val_history=s["val_history"])
        fed.pool_entries = {
            (k.rsplit("|", 1)[0], int(k.rsplit("|", 1)[1])): e
            for k, e in state["pool"].items()}
        fed.pool_ages = {
            (k.rsplit("|", 1)[0], int(k.rsplit("|", 1)[1])): int(a)
            for k, a in manifest["pool_ages"].items()}
        fed.wave = int(manifest["wave"])
        fed.n_rounds = {n: int(v)
                        for n, v in manifest["n_rounds"].items()}
        fed.selections = {n: [list(s) for s in v]
                          for n, v in manifest["selections"].items()}
        fed.last_test = {n: float(v)
                         for n, v in manifest["last_test"].items()}
        fed.wave_log = list(manifest["wave_log"])
        fed.fault_log = FT.fault_log_from_json(
            manifest.get("fault_log", []))
        fed._key = jnp.asarray(state["key"])
        fed._part_rng.bit_generator.state = manifest["part_rng"]
        fed._sel_rng.bit_generator.state = manifest["sel_rng"]
        fed._switch_rng.bit_generator.state = manifest["switch_rng"]
        ts = manifest.get("trust_state") or {}
        if fed.accountant is not None:
            fed.accountant = TR.DPAccountant.from_json(
                fed.trust.dp, ts.get("accountant"))
        if fed.reputation is not None:
            fed.reputation = TR.ReputationBook.from_json(
                fed.trust.watermark, ts.get("reputation"))
        fed.clip_events = int(ts.get("clip_events", 0))
        fed.wm_failures = {n: int(v)
                           for n, v in (ts.get("wm_failures") or {}).items()}
        rs = manifest.get("telemetry_state")
        if rs is not None and fed._telemetry is not None:
            fed._recorder = TEL.FlightRecorder.from_json(
                fed._telemetry, rs)
        return fed
