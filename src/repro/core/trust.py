"""Trust layer: secure aggregation, differential privacy, watermarked heads.

ROADMAP item 3 as a policy pack over PR 2's ``register_policy`` hook — the
paper claims HFL delivers heterogeneous transfer "with privacy, model
security", and PR 8's admission guard only covers the *numerical* half of
that claim (NaN/Inf/exploding norms).  This module adds the statistical
half as three plugins bundled into a :class:`TrustPlan` the engines thread
exactly like ``faults=`` — ``trust=None`` (or a disabled plan) traces the
byte-identical pre-trust graph on every engine:

* :class:`MaskedSecureAggregation` (a ``TransferRule``): pairwise
  seed-derived masks — a pure function of ``(seed, wave, round, client i,
  client j)``, like ``FaultPlan``'s draws — that cancel in the pool-side
  sum, so no raw head ever leaves a client.  The exchange becomes a masked
  FedAvg mean (per-feature Eq.-7 selection needs raw candidates, which is
  exactly what secure aggregation forbids; the mean transfer is the
  standard secure-aggregation aggregate).  Clients removed AFTER the
  per-wave RNG fold-in (PR 8's stragglers / switch-inactive clients) are
  recovered by mask reconstruction: the server re-derives the missing
  net masks from the seed and adds them back (:func:`mask_correction`),
  so the surviving sum equals the plain sum to float tolerance.

* :class:`DPNoise` (a ``TransferRule``): every published head tree is
  L2-clipped to ``clip`` and perturbed with Gaussian noise of std
  ``sigma * clip`` — the Gaussian mechanism — before it reaches the pool.
  A per-client zCDP accountant (:class:`DPAccountant`) composes the
  releases across rounds and waves (``rho = k / (2 sigma^2)``,
  ``eps(delta) = rho + 2 sqrt(rho ln(1/delta))``), survives save/restore
  bit-identically (its state is integer release counts), and surfaces in
  ``dispatch_stats`` as ``epsilon_spent`` / ``clip_events``.

* :class:`HeadWatermark` (a ``PoolPolicy``): each client's persisted heads
  carry an additive per-client signature — a deterministic unit-norm
  direction derived from ``(seed, crc32(name))``, embedded host-side
  before any corruption can occur and topped back up in-graph at every
  publication.  Publication verifies the signature by projection; a
  sign-flipped head (PR 8's ``corruption="signflip"``, which preserves
  the norm and therefore PASSES the admission guard by design) negates
  the embedded signature, so the projection lands at ``-strength`` and
  verification fails: the publication is blocked (the stale clean row
  persists) and the failure feeds a reputation score
  (:class:`ReputationBook`) that quarantines repeat offenders at wave
  boundaries — dropped from sampling, resident pool rows zeroed at
  ``faults.QUARANTINE_AGE``.

Composition: DP composes with either mechanism (privatize, then mask /
then verify happens first on the raw head); secure aggregation and
watermark verification are mutually exclusive by construction — masked
payloads destroy projections, which is the entire point of masking — and
:class:`TrustPlan` rejects the combination.

Derivations are host-side numpy from ``np.random.SeedSequence`` streams
(the ``FaultPlan`` idiom) so every draw replays bit-identically across
engines, device counts and save/restore; the in-graph pieces
(:func:`wm_apply`, :func:`dp_privatize`, :func:`secure_round`) are pure
jnp functions traced by the fused engines and jit-called by the
sequential oracle, so the two cannot drift apart.  Note the pairwise mask
generation materializes O(C^2) mask trees per exchange round on the host —
fine at wave-sized C; a production deployment would stream a counter-mode
PRG per pair instead.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import (PoolPolicy, TransferRule, _Spec,
                                 register_policy)

# SeedSequence stream tags (disjoint from faults.py's 0xFA/0xFB)
_SIG_STREAM = 0x51       # per-client watermark signature directions
_MASK_STREAM = 0x5A      # pairwise secure-aggregation masks
_DP_STREAM = 0x7D        # host-side (oracle) DP noise; also the in-graph
                         # fold_in tag deriving noise keys off the round key


# ---------------------------------------------------------------------------
# The three plugins + the plan that bundles them
# ---------------------------------------------------------------------------

@register_policy
@dataclasses.dataclass(frozen=True)
class MaskedSecureAggregation(TransferRule):
    """Masked-mean secure aggregation.  ``alpha`` blends each client toward
    the securely aggregated foreign mean (the Eq.-8 role); ``mask_scale``
    is the std of the pairwise mask entries; ``seed`` keys every pairwise
    draw.  Registered as a TransferRule for spec round-trip, but routed by
    the engines through the dedicated mean-transfer round — per-head Eq.-7
    selection on raw candidates is what masking forbids."""
    alpha: float = 0.2
    mask_scale: float = 1e-3
    seed: int = 0

    def __post_init__(self):
        if not 0 < self.alpha <= 1:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        if self.mask_scale < 0:
            raise ValueError(f"mask_scale must be >= 0, "
                             f"got {self.mask_scale}")

    def apply(self, target, selected):
        raise TypeError(
            "MaskedSecureAggregation is not a per-selection blend: the "
            "engines route it through the masked mean-transfer round "
            "(trust.secure_round) — pass it inside a TrustPlan, not as "
            "FederationPolicies.transfer")


@register_policy
@dataclasses.dataclass(frozen=True)
class DPNoise(TransferRule):
    """Gaussian-mechanism release of published heads: L2-clip the head tree
    to ``clip``, add N(0, (sigma*clip)^2) per coordinate.  ``delta`` is the
    accountant's target delta; ``seed`` keys the sequential oracle's host
    noise stream (the fused engines derive theirs from the epoch PRNG key —
    noise streams are engine-specific, like stochastic selection
    policies)."""
    clip: float = 10.0
    sigma: float = 0.5
    delta: float = 1e-5
    seed: int = 0

    def __post_init__(self):
        if self.clip <= 0:
            raise ValueError(f"clip must be > 0, got {self.clip}")
        if self.sigma <= 0:
            raise ValueError(f"sigma must be > 0, got {self.sigma} (omit "
                             f"the DPNoise plugin for the noiseless path)")
        if not 0 < self.delta < 1:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")

    @property
    def rho_per_release(self) -> float:
        """zCDP cost of one Gaussian release at noise multiplier sigma."""
        return 1.0 / (2.0 * self.sigma ** 2)

    def epsilon(self, releases: int) -> float:
        """Analytic (eps, delta)-DP bound after ``releases`` composed
        Gaussian releases: rho-zCDP converts at
        eps = rho + 2 sqrt(rho ln(1/delta))."""
        if releases <= 0:
            return 0.0
        rho = releases * self.rho_per_release
        return rho + 2.0 * math.sqrt(rho * math.log(1.0 / self.delta))


@register_policy
@dataclasses.dataclass(frozen=True)
class HeadWatermark(PoolPolicy):
    """Per-client signature watermarking of published heads.  ``strength``
    is the L2 magnitude of the embedded signature component; verification
    passes when the projection onto the client's signature direction is at
    least ``threshold * strength``; ``tolerance`` is how many waves with a
    failed verification a client survives before the reputation layer
    quarantines it.  Registered as a PoolPolicy (it governs what the pool
    accepts and serves); ``max_age`` is unused here — staleness stays with
    the bundle's pool policy.

    The default ``strength`` is calibrated so HONEST clients essentially
    never fail: between publications R training steps drift the projection
    by an amount independent of ``strength``, so the verification budget
    ``strength * (1 - threshold)`` must dominate that drift (at 0.05 honest
    heads failed ~30% of opportunities on the reference population; at 0.2+
    never), while a sign-flipped head projects at ``-strength`` and fails
    at ANY strength."""
    strength: float = 0.25
    threshold: float = 0.5
    tolerance: int = 2
    seed: int = 0

    def __post_init__(self):
        if self.strength <= 0:
            raise ValueError(f"strength must be > 0, got {self.strength}")
        if not 0 < self.threshold < 1:
            raise ValueError(f"threshold must be in (0, 1), "
                             f"got {self.threshold}")
        if self.tolerance < 1:
            raise ValueError(f"tolerance must be >= 1, "
                             f"got {self.tolerance}")


@register_policy
@dataclasses.dataclass(frozen=True)
class TrustPlan(_Spec):
    """The bundle the engines thread (``Federation(..., trust=plan)``),
    mirroring ``faults=``: a disabled plan (all three None) or ``None``
    traces the byte-identical pre-trust graph.  Hashable, so it joins the
    fused engines' compile-cache keys as a static."""
    secure_agg: Optional[MaskedSecureAggregation] = None
    dp: Optional[DPNoise] = None
    watermark: Optional[HeadWatermark] = None

    def __post_init__(self):
        if self.secure_agg is not None \
                and not isinstance(self.secure_agg, MaskedSecureAggregation):
            raise TypeError(f"secure_agg: expected MaskedSecureAggregation, "
                            f"got {type(self.secure_agg).__name__}")
        if self.dp is not None and not isinstance(self.dp, DPNoise):
            raise TypeError(f"dp: expected DPNoise, "
                            f"got {type(self.dp).__name__}")
        if self.watermark is not None \
                and not isinstance(self.watermark, HeadWatermark):
            raise TypeError(f"watermark: expected HeadWatermark, "
                            f"got {type(self.watermark).__name__}")
        if self.secure_agg is not None and self.watermark is not None:
            raise ValueError(
                "secure_agg and watermark cannot be combined: masked "
                "payloads destroy signature projections by construction "
                "(that is what masking is FOR) — run them in separate "
                "federations or drop one")

    @property
    def enabled(self) -> bool:
        return (self.secure_agg is not None or self.dp is not None
                or self.watermark is not None)

    def spec(self) -> dict:
        """Nested spec: each sub-policy serializes through its own
        ``spec()`` (``policy_from_spec`` recurses on dicts carrying a
        ``kind``), None stays None."""
        return {"kind": type(self).__name__,
                "secure_agg": (self.secure_agg.spec()
                               if self.secure_agg else None),
                "dp": self.dp.spec() if self.dp else None,
                "watermark": (self.watermark.spec()
                              if self.watermark else None)}


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------

def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def tree_dot(a, b):
    """float32 inner product of two same-structure trees (the admission
    guard's reduction style — float32 accumulate regardless of leaf
    dtype)."""
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(_leaves(a), _leaves(b)))


def _tree_sq_norm(tree):
    return sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
               for leaf in _leaves(tree))


def _rng_tree(ss: np.random.SeedSequence, template, scale: float = 1.0):
    """A tree of float32 normal draws shaped like ``template``, one child
    SeedSequence per leaf in canonical tree order (dict leaves flatten in
    sorted-key order — deterministic everywhere)."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    children = ss.spawn(len(leaves))
    out = [np.random.default_rng(c).standard_normal(
        np.shape(leaf), dtype=np.float32) * np.float32(scale)
        for c, leaf in zip(children, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def pad_rows(tree, max_nf: int):
    """Zero-pad every leaf's leading (feature) axis to ``max_nf`` — aligns
    a true-nf signature/head tree with the cohort engine's padded
    geometry."""
    def pad(leaf):
        leaf = np.asarray(leaf)
        if leaf.shape[0] == max_nf:
            return leaf
        width = [(0, max_nf - leaf.shape[0])] + [(0, 0)] * (leaf.ndim - 1)
        return np.pad(leaf, width)
    return jax.tree_util.tree_map(pad, tree)


def stack_trees_np(trees):
    """np.stack a list of same-structure trees on a new leading axis."""
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *trees)


# ---------------------------------------------------------------------------
# Watermark: signatures, embedding, verification
# ---------------------------------------------------------------------------

def signature(wm: HeadWatermark, name: str, heads):
    """The client's deterministic unit-L2 signature tree, shaped like its
    (nf, ...) head tree — a pure function of ``(wm.seed, crc32(name))``,
    so it is identical across engines, waves and restores."""
    ss = np.random.SeedSequence(
        [wm.seed & 0xFFFFFFFF, _SIG_STREAM, zlib.crc32(name.encode())])
    raw = _rng_tree(ss, heads)
    nrm = math.sqrt(sum(float(np.sum(np.square(leaf), dtype=np.float64))
                        for leaf in _leaves(raw)))
    return jax.tree_util.tree_map(
        lambda leaf: (leaf / np.float32(nrm)).astype(np.float32), raw)


def wm_apply(heads, sig, *, strength: float, threshold: float):
    """Verify-and-maintain at a publication opportunity (pure jnp; traced
    by the fused engines, jit-called per client by the sequential oracle —
    the single definition keeps them bit-identical).

    Returns ``(new_heads, ok, proj)``: ``ok`` is the verification verdict
    (projection onto the signature >= threshold * strength); when it
    passes, the signature component is topped back up to exactly
    ``strength`` (Eq.-8 blending attenuates it by (1 - alpha) per
    exchange, so without maintenance an honest client would eventually
    fail its own watermark); when it fails the heads are returned
    untouched — a tampered head is evidence, never healed."""
    proj = tree_dot(heads, sig)
    ok = proj >= jnp.float32(threshold * strength)
    t = jnp.float32(strength) - proj
    new = jax.tree_util.tree_map(
        lambda h, s: jnp.where(ok, h + t * s.astype(h.dtype), h), heads, sig)
    return new, ok, proj


@jax.jit
def _wm_embed_jit(heads, sig, strength, threshold):
    proj = tree_dot(heads, sig)
    # no-heal rule: a strongly NEGATIVE projection is a tamper signature
    # (sign-flip of a marked head) — embedding must not launder it back
    # above the verification threshold
    heal = proj > -jnp.float32(1.0) * threshold * strength
    t = jnp.where(heal, strength - proj, 0.0)
    return jax.tree_util.tree_map(
        lambda h, s: h + t * s.astype(h.dtype), heads, sig), heal


def wm_embed(heads, sig, wm: HeadWatermark):
    """Host-side embedding/top-up of a client's OWN persisted heads (run
    before any fault corruption can touch them): sets the signature
    projection to exactly ``strength`` — unless the head already carries a
    strongly negative projection, the sign-flip fingerprint, which is left
    as evidence for verification to catch.  Returns (new_heads,
    healed: bool)."""
    sig = jax.tree_util.tree_map(jnp.asarray, sig)
    new, heal = _wm_embed_jit(heads, sig, jnp.float32(wm.strength),
                              jnp.float32(wm.threshold))
    return new, bool(heal)


def wm_verify_host(heads, sig, wm: HeadWatermark) -> bool:
    """Host twin of the in-graph verification verdict (same float32
    reduction; used at pool seeding, which runs once on the host for both
    engines)."""
    proj = float(tree_dot(jax.tree_util.tree_map(jnp.asarray, heads),
                          jax.tree_util.tree_map(jnp.asarray, sig)))
    return proj >= wm.threshold * wm.strength


# ---------------------------------------------------------------------------
# Differential privacy: clipped-noise release + accountant
# ---------------------------------------------------------------------------

def dp_privatize(heads, key, *, clip: float, sigma: float):
    """One Gaussian-mechanism release of a head tree: scale to L2 norm <=
    ``clip``, add N(0, (sigma*clip)^2) per coordinate.  Pure jnp.  Returns
    ``(noisy_heads, clipped)`` where ``clipped`` flags a norm actually
    exceeding the bound (the ``clip_events`` counter)."""
    leaves, treedef = jax.tree_util.tree_flatten(heads)
    nrm = jnp.sqrt(_tree_sq_norm(heads))
    scale = jnp.minimum(jnp.float32(1.0),
                        jnp.float32(clip) / jnp.maximum(nrm, 1e-12))
    std = jnp.float32(sigma * clip)
    keys = jax.random.split(key, len(leaves))
    noisy = [leaf * scale.astype(leaf.dtype)
             + std.astype(leaf.dtype)
             * jax.random.normal(k, leaf.shape, leaf.dtype)
             for leaf, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, noisy), nrm > clip


def dp_privatize_host(heads, dp: DPNoise, wave: int, rnd: int, cid: int):
    """The sequential oracle's release: same clip, host numpy noise from
    ``SeedSequence([dp.seed, 0x7D, wave, round, client id])`` — replays
    bit-identically across oracle runs/restores; it is NOT the fused
    engines' noise stream (noise is engine-specific, like stochastic
    selection)."""
    nrm = math.sqrt(max(float(_tree_sq_norm(
        jax.tree_util.tree_map(jnp.asarray, heads))), 0.0))
    scale = min(1.0, dp.clip / max(nrm, 1e-12))
    ss = np.random.SeedSequence([dp.seed & 0xFFFFFFFF, _DP_STREAM,
                                 wave, rnd, cid])
    noise = _rng_tree(ss, heads, scale=dp.sigma * dp.clip)
    noisy = jax.tree_util.tree_map(
        lambda h, z: (jnp.asarray(h) * np.float32(scale)
                      + jnp.asarray(z)), heads, noise)
    return noisy, nrm > dp.clip


class DPAccountant:
    """Per-client zCDP composition over Gaussian releases.  State is a dict
    of integer release counts — trivially bit-identical through JSON
    save/restore; epsilons are recomputed analytically on demand."""

    def __init__(self, dp: DPNoise, counts: Optional[Dict[str, int]] = None):
        self.dp = dp
        self.counts: Dict[str, int] = {k: int(v)
                                       for k, v in (counts or {}).items()}

    def record(self, name: str, releases: int = 1) -> None:
        if releases:
            self.counts[name] = self.counts.get(name, 0) + int(releases)

    def epsilon(self, name: str) -> float:
        return self.dp.epsilon(self.counts.get(name, 0))

    @property
    def max_epsilon(self) -> float:
        """The headline ``dispatch_stats["epsilon_spent"]`` figure: the
        worst per-client epsilon (DP guarantees are per-client)."""
        return max((self.dp.epsilon(k) for k in self.counts.values()),
                   default=0.0)

    def to_json(self) -> Dict[str, int]:
        return dict(self.counts)

    @classmethod
    def from_json(cls, dp: DPNoise, obj) -> "DPAccountant":
        return cls(dp, dict(obj or {}))


# ---------------------------------------------------------------------------
# Reputation
# ---------------------------------------------------------------------------

class ReputationBook:
    """Watermark-failure reputation: one strike per wave in which a client
    failed >= 1 signature verification; ``wm.tolerance`` strikes
    quarantines it (the participation layer then drops it from sampling
    and pins its resident pool rows at ``faults.QUARANTINE_AGE``).  JSON
    state round-trips bit-identically."""

    def __init__(self, wm: HeadWatermark,
                 strikes: Optional[Dict[str, int]] = None,
                 quarantined: Sequence[str] = ()):
        self.wm = wm
        self.strikes: Dict[str, int] = {k: int(v)
                                        for k, v in (strikes or {}).items()}
        self.quarantined = set(quarantined)

    def strike(self, name: str) -> bool:
        """Record one failed wave; returns True when this strike NEWLY
        quarantines the client."""
        self.strikes[name] = self.strikes.get(name, 0) + 1
        if name not in self.quarantined \
                and self.strikes[name] >= self.wm.tolerance:
            self.quarantined.add(name)
            return True
        return False

    def is_quarantined(self, name: str) -> bool:
        return name in self.quarantined

    def to_json(self) -> dict:
        return {"strikes": dict(self.strikes),
                "quarantined": sorted(self.quarantined)}

    @classmethod
    def from_json(cls, wm: HeadWatermark, obj) -> "ReputationBook":
        obj = obj or {}
        return cls(wm, obj.get("strikes"), obj.get("quarantined", ()))


# ---------------------------------------------------------------------------
# Secure aggregation: pairwise masks, reconstruction, the mean round
# ---------------------------------------------------------------------------

def pair_mask(sa: MaskedSecureAggregation, wave: int, rnd: int,
              i: int, j: int, template):
    """The pairwise mask between GLOBAL client ids i < j for one exchange
    round — a pure function of ``(seed, wave, round, i, j)``, so any party
    (or the server, for dropout recovery) can re-derive it."""
    if not i < j:
        raise ValueError(f"pair_mask wants i < j, got ({i}, {j})")
    ss = np.random.SeedSequence([sa.seed & 0xFFFFFFFF, _MASK_STREAM,
                                 wave, rnd, i, j])
    return _rng_tree(ss, template, scale=sa.mask_scale)


def net_masks(sa: MaskedSecureAggregation, wave: int, n_rounds: int,
              ids: Sequence[int], template, round_offset: int = 0):
    """Per-round net masks for the wave's client set: a tree of
    ``(n_rounds, C, ...)`` float32 arrays where row c is client ``ids[c]``'s
    net mask ``sum_{j>i} m_ij - sum_{j<i} m_ji`` — the rows of every round
    sum to EXACTLY zero over the client axis (pairwise cancellation), which
    is the whole secure-aggregation invariant.  O(C^2) host work; the
    position order follows ``ids``, the mask derivation their global
    values.  ``round_offset`` shifts the within-wave round key (the
    sequential oracle derives one round at a time)."""
    C = len(ids)
    zero = jax.tree_util.tree_map(
        lambda leaf: np.zeros((n_rounds, C) + np.shape(leaf), np.float32),
        template)
    if sa.mask_scale == 0:
        return zero
    for r in range(n_rounds):
        for a in range(C):
            for b in range(a + 1, C):
                i, j = ids[a], ids[b]
                lo, hi = (a, b) if i < j else (b, a)
                m = pair_mask(sa, wave, round_offset + r,
                              min(i, j), max(i, j), template)
                jax.tree_util.tree_map(
                    lambda z, ml: (z[r, lo].__iadd__(ml),
                                   z[r, hi].__isub__(ml)), zero, m)
    return zero


def mask_correction(masks, active):
    """Dropout recovery: the sum of the net masks of clients that were in
    the wave's mask derivation but did NOT publish (removed after the RNG
    fold-in — stragglers, switch-inactive).  Adding this to the masked sum
    of the survivors cancels every mask exactly.  ``masks``:
    ``(n_rounds, C, ...)`` tree; ``active``: (C,) bool; returns an
    ``(n_rounds, ...)`` tree."""
    gone = ~np.asarray(active, bool)
    return jax.tree_util.tree_map(
        lambda m: np.ascontiguousarray(m[:, gone].sum(axis=1)
                                       if gone.any()
                                       else np.zeros(
                                           (m.shape[0],) + m.shape[2:],
                                           m.dtype)), masks)


def secure_round(heads, pool_heads, pool_age, active, net_mask, correction,
                 noise_key, priv=None, feat_valid=None, *,
                 sa: MaskedSecureAggregation, dp: Optional[DPNoise],
                 nf: int, admission=None):
    """One masked mean-transfer exchange for ALL C clients (pure jnp; the
    fused engines trace it in place of the per-client selection scan, the
    sequential oracle jit-calls it on stacked host trees — one definition,
    no drift).

    Client-side: each active client releases ``y_i = priv(h_i) + m_i``
    (``priv`` is the optional DP clip+noise; ``m_i`` its net pairwise
    mask).  Pool-side: the masked sum over surviving publishers plus the
    host-reconstructed ``correction`` equals the plain sum of the
    privatized heads to float tolerance — no raw head was ever visible.
    Each active client then blends toward its foreign mean
    ``(S - h'_i) / (publishers - 1)`` with ``sa.alpha`` (per feature row
    under a padded ``feat_valid`` geometry), and the POOL stores the
    masked payload ``y_i``, so even at rest the pool never holds a raw
    head.  ``chosen`` is all -1 (there is no per-head selection to log).

    ``priv`` overrides the in-graph privatization with caller-supplied
    releases (the sequential oracle's host-noise path; clip events are
    then the caller's to count).  Returns ``(heads, pool, age, chosen,
    rejected_or_None, clip_events)``; ``rejected`` (admission guard,
    checked on the pre-mask release) is None when ``admission`` is."""
    C = active.shape[0]
    f32 = jnp.float32
    fv = (jnp.ones((C, nf), bool) if feat_valid is None
          else jnp.asarray(feat_valid))
    fvf = fv.astype(f32)
    actf = active.astype(f32)

    def rows(mask, leaf):
        """(C,)- or (C, nf)-shaped mask broadcast to a (C, nf, ...) leaf."""
        extra = leaf.ndim - mask.ndim
        return mask.reshape(mask.shape + (1,) * extra)

    if priv is not None:
        clip_ev = jnp.zeros((C,), bool)
        if feat_valid is not None:   # host noise on padded rows: silence it
            priv = jax.tree_util.tree_map(
                lambda p: jnp.where(rows(fv, p), p, 0), priv)
    elif dp is not None:
        keys = jax.random.split(noise_key, C)
        priv, clipped = jax.vmap(
            lambda h, k: dp_privatize(h, k, clip=dp.clip, sigma=dp.sigma)
        )(heads, keys)
        # padded rows must stay silent: noise on a row the client does not
        # own would pollute that row's pool-side sum
        priv = jax.tree_util.tree_map(
            lambda p: jnp.where(rows(fv, p), p, 0), priv)
        clip_ev = active & clipped
    else:
        priv, clip_ev = heads, jnp.zeros((C,), bool)

    y = jax.tree_util.tree_map(lambda p, m: p + m.astype(p.dtype),
                               priv, net_mask)
    # the pool-side aggregate: masked survivors + reconstructed masks of
    # the removed; equals sum_i active_i * priv_i up to float error
    S = jax.tree_util.tree_map(
        lambda yl, cl: jnp.sum(jnp.where(rows(active, yl), yl, 0), axis=0)
        + cl.astype(yl.dtype), y, correction)
    pubf = jnp.sum(actf[:, None] * fvf, axis=0)             # (nf,)
    cnt = pubf[None, :] - actf[:, None] * fvf               # (C, nf) foreign
    denom = jnp.maximum(cnt, 1.0)
    foreign = jax.tree_util.tree_map(
        lambda Sl, pl: (Sl[None] - rows(actf[:, None] * fvf, pl) * pl)
        / rows(denom, pl).astype(pl.dtype), S, priv)
    a = f32(sa.alpha)
    use = active[:, None] & fv & (cnt > 0)                  # (C, nf)
    new_heads = jax.tree_util.tree_map(
        lambda h, fr: jnp.where(rows(use, h),
                                (1 - a).astype(h.dtype) * h
                                + a.astype(h.dtype) * fr, h),
        heads, foreign)
    pub = active
    rejected = None
    if admission is not None:
        # the guard bounds the true release (pre-mask): the mask is
        # server-cancelled bookkeeping, not payload magnitude
        sq = sum(jnp.sum(jnp.square(leaf.astype(f32)),
                         axis=tuple(range(1, leaf.ndim)))
                 for leaf in _leaves(priv))
        ok = jnp.isfinite(sq) & (sq <= f32(admission) ** 2)
        rejected = pub & ~ok
        pub = pub & ok
    pool = jax.tree_util.tree_map(
        lambda pl, yl: jnp.where(rows(pub, yl), yl, pl), pool_heads, y)
    age = jnp.where(pub, 0, pool_age)
    chosen = jnp.full((C, nf), -1, jnp.int32)
    return new_heads, pool, age, chosen, rejected, clip_ev


# the sequential oracle's entry point: the SAME function the fused engines
# trace, jitted once over stacked host trees (policies are hashable
# statics), so oracle and engine cannot drift
secure_round_jit = jax.jit(
    secure_round, static_argnames=("sa", "dp", "nf", "admission"))
