"""Flight-recorder telemetry for the federated stack.

Three pieces, all optional and all zero-cost when absent:

* **TelemetryPlan** — the seventh pluggable, spec-round-trippable,
  ``register_policy``-able plan (the ``FaultPlan`` / ``TrustPlan``
  pattern).  ``telemetry=None`` and a fully disabled plan trace the
  byte-identical pre-instrumentation graph on every engine (pinned by
  ``tests/test_telemetry.py``); an enabled plan threads an extra metrics
  carry through the fused epoch scan, so one epoch still costs one
  dispatch and the per-round series come back as stacked scan outputs:

    - ``foreign_per_client`` — the selection histogram: how many of each
      client's features picked a foreign head this exchange round (0 =
      the client kept its own head / sat the round out),
    - ``score_min`` / ``score_mean`` — the Eq.-7 score distribution over
      the valid candidate pool per client (``inf`` / 0 when the selection
      policy scores nothing, e.g. ``RandomSelection`` or a secure round),
    - ``pool_age`` — the staleness-age snapshot after the round
      (quarantined rows sit at the ``QUARANTINE_AGE`` sentinel and are
      masked out of the recorded aggregates).

* **FlightRecorder** — a bounded ring buffer (``collections.deque``) of
  host-side events: ``span`` timings (``span("gather")`` /
  ``span("dispatch")`` / ``span("exchange")`` / ``span("scatter")`` with
  optional ``jax.profiler`` trace annotations behind ``plan.profile``),
  the decoded per-round metric records, and a counter registry snapshot.
  It serializes to JSONL, round-trips through checkpoint manifests
  (``to_json`` / ``from_json``) so resumed runs continue their trace, and
  ``tools/trace_export.py`` turns the event list into Chrome-trace /
  Perfetto JSON.

* **MetricsRegistry schema** — the typed, documented catalog of every
  ``dispatch_stats`` name the engines emit (counter / gauge / histogram /
  label, units, deprecation aliases), machine-readable via ``schema()``.
  ``benchmarks/fl_scale_bench.validate_payload`` validates result rows
  against this one catalog instead of a hand-rolled column list.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.faults import QUARANTINE_AGE
from repro.core.policies import _Spec, register_policy


@register_policy
@dataclasses.dataclass(frozen=True)
class TelemetryPlan(_Spec):
    """What to record.  ``rounds`` turns on the in-graph metrics carry
    (per-round series stacked as extra scan outputs); ``spans`` turns on
    the host-side span tracer; ``ring_size`` bounds the flight recorder;
    ``profile`` additionally wraps every span in a
    ``jax.profiler.TraceAnnotation`` so the spans show up in a captured
    XLA profile.  A plan with both ``rounds`` and ``spans`` off is inert:
    engines treat it exactly like ``telemetry=None``."""
    rounds: bool = True
    spans: bool = True
    ring_size: int = 4096
    profile: bool = False

    def __post_init__(self):
        if not isinstance(self.ring_size, int) or self.ring_size < 1:
            raise ValueError(f"ring_size must be a positive int, got "
                             f"{self.ring_size!r}")

    @property
    def enabled(self) -> bool:
        """Whether anything records.  Disabled plans are inert: engines
        treat them exactly like ``telemetry=None``."""
        return self.rounds or self.spans


# ---------------------------------------------------------------------------
# MetricsRegistry: the one catalog of dispatch_stats / bench metric names
# ---------------------------------------------------------------------------

#: Metric kinds.  ``counter`` only ever increases within a run; ``gauge``
#: is a point-in-time level; ``histogram`` summarizes a distribution;
#: ``label`` is a categorical/structured annotation, not a number.
KINDS = ("counter", "gauge", "histogram", "label")

_NUM = (int, float)
_OPT_NUM = (int, float, type(None))


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One documented metric name: its kind, accepted python types (the
    JSON-decoded types ``validate_payload`` checks against), unit, and a
    one-line description."""
    name: str
    kind: str
    types: tuple
    unit: str
    description: str

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown metric kind {self.kind!r}")


def _m(name, kind, types, unit, description):
    return MetricSpec(name, kind, tuple(types), unit, description)


#: The catalog.  Every key any engine ever puts in ``dispatch_stats``
#: plus the bench-row columns, under one typed schema.
METRICS: Dict[str, MetricSpec] = {m.name: m for m in [
    # -- engine identity / geometry (labels & gauges) ----------------------
    _m("engine", "label", (str,), "", "engine tag (sequential / batched / "
       "batched+mesh / participating+<policy>)"),
    _m("path", "label", (str, type(None)), "", "dispatch path: fused (one "
       "dispatch per epoch) or chunked/per-round"),
    _m("dispatch_path", "label", (str,), "", "bench-row alias column for "
       "`path`"),
    _m("devices", "gauge", (int,), "devices", "mesh device count the epoch "
       "ran on"),
    _m("clients", "gauge", (int,), "clients", "clients trained in the row"),
    _m("hetero", "label", (bool,), "", "mixed-nf population row"),
    _m("cohorts", "gauge", (int,), "cohorts", "homogeneous cohorts the "
       "population was partitioned into"),
    _m("per_cohort", "label", (list,), "", "per-cohort geometry breakdown "
       "(nf / clients / sub_rounds / dispatches)"),
    # -- work accounting (counters) ----------------------------------------
    _m("epochs", "counter", (int,), "epochs", "epochs executed"),
    _m("dispatches", "counter", (int,), "dispatches", "device dispatches "
       "issued"),
    _m("dispatches_per_epoch", "gauge", _NUM, "dispatches/epoch",
       "dispatch amplification (1.0 = fully fused)"),
    _m("exchange_every", "gauge", (int,), "sub-rounds", "bounded-staleness "
       "cadence k: exchange every k-th sub-round"),
    _m("exchange_rounds", "counter", (int,), "rounds", "federated exchange "
       "rounds executed"),
    _m("round_ms", "gauge", _NUM, "ms", "mean wall-clock per client round"),
    _m("client_rounds_per_s", "gauge", _NUM, "rounds/s", "aggregate client-"
       "round throughput"),
    _m("speedup_vs_sequential", "gauge", _OPT_NUM, "x", "throughput vs the "
       "sequential oracle (null when the oracle was skipped)"),
    # -- comms / memory accounting -----------------------------------------
    _m("pool_bytes_gathered", "counter", (int,), "bytes", "pool + probe "
       "bytes all-gathered per device over the run"),
    _m("state_bytes", "gauge", (int,), "bytes", "resident stacked client "
       "state on device"),
    _m("resident_state_bytes", "gauge", (int,), "bytes", "device working "
       "set of the resident wave"),
    _m("resident_clients", "gauge", (int,), "clients", "clients resident "
       "on device at once"),
    _m("store_clients", "gauge", (int,), "clients", "clients parked in the "
       "host-side ClientStore"),
    _m("store_bytes", "gauge", (int,), "bytes", "host-side ClientStore "
       "footprint"),
    _m("gather_bytes", "counter", (int,), "bytes", "host->device state "
       "gathered across waves"),
    _m("scatter_bytes", "counter", (int,), "bytes", "device->host state "
       "scattered back across waves"),
    # -- participation ------------------------------------------------------
    _m("population", "gauge", (int,), "clients", "declared population size"),
    _m("participation", "label", (str, type(None)), "", "participation "
       "policy kind"),
    _m("participation_fraction", "gauge", _NUM, "", "sampled fraction per "
       "wave"),
    _m("waves", "counter", (int,), "waves", "participation waves executed"),
    # -- fault / trust counters --------------------------------------------
    _m("fault_rate", "gauge", _NUM, "", "injected dropout probability"),
    _m("byzantine_frac", "gauge", _NUM, "", "injected byzantine probability"),
    _m("heads_rejected", "counter", (int,), "heads", "publications the "
       "admission guard quarantined"),
    _m("clients_dropped", "counter", (int,), "clients", "clients dropped "
       "from waves by injected faults"),
    _m("stragglers", "counter", (int,), "clients", "clients masked out of "
       "exchanges as stragglers"),
    _m("waves_degraded", "counter", (int,), "waves", "waves that lost at "
       "least one client"),
    _m("store_rebuilds", "counter", (int,), "entries", "corrupt store "
       "entries rebuilt from the deterministic builder"),
    _m("quarantined", "label", (list,), "", "client names quarantined by "
       "the reputation book"),
    _m("quarantined_drops", "counter", (int,), "clients", "sampled clients "
       "removed by reputation quarantine"),
    _m("epsilon_spent", "gauge", _NUM, "eps", "max per-client analytic DP "
       "epsilon spent"),
    _m("clip_events", "counter", (int,), "heads", "DP L2-clip activations"),
    _m("watermark_failures", "counter", (int,), "heads", "watermark "
       "verification failures"),
    _m("mean_val", "gauge", _OPT_NUM, "", "mean final validation metric "
       "over finite clients (null when not collected)"),
    # -- telemetry's own series (histograms over the round axis) -----------
    _m("foreign_picks", "counter", (int,), "picks", "feature-level foreign "
       "head selections recorded in round events"),
    _m("client_rounds", "counter", (int,), "rounds", "client exchange "
       "rounds executed (throughput numerator)"),
    _m("score_min", "histogram", _NUM, "", "per-round minimum Eq.-7 score "
       "over valid candidates"),
    _m("score_mean", "histogram", _NUM, "", "per-round mean Eq.-7 score "
       "over valid candidates"),
    _m("pool_age", "histogram", (int,), "rounds", "per-round pool "
       "staleness-age distribution (quarantine sentinel masked)"),
]}

#: Deprecated spellings -> canonical catalog names.  ``resolve_aliases``
#: rewrites these (with a DeprecationWarning) so external consumers that
#: grew their own names converge on the one schema.
DEPRECATED_ALIASES: Dict[str, str] = {
    "bytes_gathered": "pool_bytes_gathered",
    "rejected_heads": "heads_rejected",
    "dropped_clients": "clients_dropped",
    "eps_spent": "epsilon_spent",
    "epsilon": "epsilon_spent",
    "wm_failures": "watermark_failures",
    "throughput": "client_rounds_per_s",
}


def canonical_name(name: str) -> str:
    """Resolve a (possibly deprecated) metric name to its catalog name."""
    return DEPRECATED_ALIASES.get(name, name)


def metric_spec(name: str) -> MetricSpec:
    return METRICS[canonical_name(name)]


def resolve_aliases(stats: dict) -> dict:
    """Rewrite deprecated keys in a stats dict to their canonical names
    (DeprecationWarning per hit).  Canonical keys win on collision."""
    import warnings
    out = {}
    for k, v in stats.items():
        c = canonical_name(k)
        if c != k:
            warnings.warn(f"dispatch_stats key {k!r} is deprecated; use "
                          f"{c!r}", DeprecationWarning, stacklevel=2)
            out.setdefault(c, v)
        else:
            out[k] = v
    return out


def schema() -> dict:
    """The machine-readable metrics schema: name -> {kind, types, unit,
    description, aliases}."""
    inv: Dict[str, List[str]] = {}
    for old, new in DEPRECATED_ALIASES.items():
        inv.setdefault(new, []).append(old)
    return {
        name: {
            "kind": m.kind,
            "types": [t.__name__ for t in m.types],
            "unit": m.unit,
            "description": m.description,
            "aliases": sorted(inv.get(name, [])),
        }
        for name, m in sorted(METRICS.items())
    }


def validate_stats(stats: dict, *, where: str = "dispatch_stats") -> None:
    """Every key must be a catalog name (aliases rejected: producers emit
    canonical names) carrying a value of the registered type."""
    for k, v in stats.items():
        if k not in METRICS:
            hint = (f" (deprecated alias of {DEPRECATED_ALIASES[k]!r})"
                    if k in DEPRECATED_ALIASES else "")
            raise ValueError(f"{where}: unknown metric {k!r}{hint}")
        m = METRICS[k]
        if m.types and not (isinstance(v, m.types)
                            and not (isinstance(v, bool)
                                     and bool not in m.types)):
            raise ValueError(f"{where}[{k!r}]: expected {m.types}, got "
                             f"{type(v).__name__}")


# ---------------------------------------------------------------------------
# FlightRecorder: bounded host-side event ring + span tracer
# ---------------------------------------------------------------------------

def _now_us(origin: float) -> int:
    return int(round((time.perf_counter() - origin) * 1e6))


class FlightRecorder:
    """A bounded ring buffer of telemetry events with a span tracer.

    Events are plain JSON-serializable dicts with a ``type`` field:

    * ``{"type": "span", "name", "ts", "dur", "depth", ...}`` — a closed
      host-side span; ``ts``/``dur`` are microseconds on the recorder's
      monotonic clock (which survives checkpoint restore: restored
      recorders keep counting up from their last timestamp).
    * ``{"type": "round", "epoch", "round", "foreign_per_client", ...}``
      — one decoded in-graph exchange round (see ``record_epoch_rounds``).
    * ``{"type": "mark", "name", "ts", ...}`` — an instant annotation.

    The deque drops the OLDEST events at capacity — a flight recorder
    keeps the latest window, like the real thing.
    """

    def __init__(self, plan: Optional[TelemetryPlan]):
        self.plan = plan if plan is not None else TelemetryPlan(
            rounds=False, spans=False)
        self.events: collections.deque = collections.deque(
            maxlen=self.plan.ring_size)
        self.counters: Dict[str, float] = {}
        self._origin = time.perf_counter()
        self._depth = 0
        self.wall_start = time.time()

    # -- spans --------------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Time a host-side phase.  No-op (zero events) unless the plan
        enables spans; with ``plan.profile`` the span also opens a
        ``jax.profiler.TraceAnnotation`` so it lands in XLA profiles."""
        if not self.plan.spans:
            yield
            return
        ann = None
        if self.plan.profile:
            try:
                import jax
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:
                ann = None
        t0 = time.perf_counter()
        ts = _now_us(self._origin)
        depth, self._depth = self._depth, self._depth + 1
        try:
            yield
        finally:
            self._depth -= 1
            if ann is not None:
                ann.__exit__(None, None, None)
            dur = int(round((time.perf_counter() - t0) * 1e6))
            self.events.append({"type": "span", "name": name, "ts": ts,
                                "dur": dur, "depth": depth, **attrs})

    def mark(self, name: str, **attrs) -> None:
        if self.plan.spans:
            self.events.append({"type": "mark", "name": name,
                                "ts": _now_us(self._origin), **attrs})

    # -- counters -----------------------------------------------------------

    def count(self, name: str, inc) -> None:
        """Bump a registry counter (name should be a catalog name)."""
        self.counters[name] = self.counters.get(name, 0) + inc

    def snapshot(self) -> dict:
        """The counter registry, canonical names, JSON-clean values."""
        return {k: (int(v) if float(v).is_integer() else float(v))
                for k, v in sorted(self.counters.items())}

    # -- in-graph series decode ---------------------------------------------

    def record_epoch_rounds(self, epoch: int, tele, active=None) -> None:
        """Decode one epoch's stacked in-graph series (the metrics carry's
        scan outputs) into per-round events.

        ``tele`` is the scan-output tuple ``(foreign, score_min,
        score_mean, pool_age)`` with leading round axis; ``active`` is the
        host-side participation mask for the epoch (distinguishes a
        self-keep — active client, zero foreign picks — from a client that
        sat the round out)."""
        if not self.plan.rounds:
            return
        fpick, smin, smean, age = (np.asarray(t) for t in tele)
        act = (np.asarray([bool(active[k]) for k in active])
               if isinstance(active, dict)
               else np.asarray(active, bool) if active is not None
               else None)
        for r in range(fpick.shape[0]):
            fr = fpick[r].astype(int)
            mn, me = smin[r], smean[r]
            finite_mn = mn[np.isfinite(mn)]
            finite_me = me[np.isfinite(me) & (mn != np.inf)]
            live = age[r][age[r] < QUARANTINE_AGE]
            n_active = int(act.sum()) if act is not None \
                else int((fr > 0).sum())
            ev = {
                "type": "round", "epoch": int(epoch), "round": int(r),
                "ts": _now_us(self._origin),
                "foreign_per_client": fr.tolist(),
                "foreign_picks": int(fr.sum()),
                "self_keeps": max(0, n_active - int((fr > 0).sum())),
                "score_min": (float(finite_mn.min())
                              if finite_mn.size else None),
                "score_mean": (float(finite_me.mean())
                               if finite_me.size else None),
                "age_mean": (float(live.mean()) if live.size else None),
                "age_max": (int(live.max()) if live.size else None),
            }
            self.events.append(ev)
            self.count("foreign_picks", int(fr.sum()))

    def last_round_event(self) -> Optional[dict]:
        for ev in reversed(self.events):
            if ev.get("type") == "round":
                return ev
        return None

    # -- persistence ---------------------------------------------------------

    def dump_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")

    @staticmethod
    def load_jsonl(path) -> List[dict]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]

    def to_json(self) -> dict:
        """Manifest-serializable state: the full event window, counters,
        and the last timestamp so a restored recorder's clock continues
        monotonically past everything already recorded."""
        return {"ring_size": self.plan.ring_size,
                "events": list(self.events),
                "counters": self.snapshot(),
                "last_ts": self._last_ts()}

    def _last_ts(self) -> int:
        last = 0
        for ev in self.events:
            last = max(last, int(ev.get("ts", 0)) + int(ev.get("dur", 0)))
        return last

    @classmethod
    def from_json(cls, plan: Optional[TelemetryPlan], data: dict
                  ) -> "FlightRecorder":
        rec = cls(plan)
        rec.events.extend(data.get("events", []))
        rec.counters.update(data.get("counters", {}))
        # resume the monotonic clock strictly after the restored window
        rec._origin = time.perf_counter() - data.get("last_ts", 0) * 1e-6
        return rec


@contextlib.contextmanager
def span(recorder: Optional[FlightRecorder], name: str, **attrs):
    """``with span(rec, "gather"): ...`` — no-op when ``rec`` is None, so
    call sites need no telemetry-enabled branch."""
    if recorder is None:
        yield
    else:
        with recorder.span(name, **attrs):
            yield
