"""HFL applied to the architecture zoo: partial-network sharing, error-driven
selection (Eq. 7) and alpha-blending (Eq. 8) at transformer-module
granularity, across federated clients mapped onto the `pod` mesh axis.

What is shared (DESIGN.md §4): attention stacks + embedding/head/final-norm
(the "global head layers" analogue).  What stays local: MoE routed experts,
RG-LRU recurrence, sLSTM gates, VLM projector (the "local embedding layers"
analogue).  For the attention-free xLSTM the mLSTM in/out projections are
shared instead — HFL needs no attention, only a shareable subtree.

Selection scores every candidate's shared subtree by the client's OWN
language-model loss on its recent batch — the exact Eq. 7 protocol with
"preliminary prediction error" generalized to task loss.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.sharding import spec as S


def default_shared_predicate(comps: Tuple[str, ...]) -> bool:
    """comps: tuple of dict keys from the params-tree path to one leaf."""
    if "moe" in comps or "rglru" in comps or "slstm" in comps:
        return False
    if "vis_proj" in comps:
        return False
    if "attn" in comps:
        return True
    if comps and comps[0] in ("embed", "lm_head", "final_norm"):
        return True
    if "mlstm" in comps and comps[-1] in ("wu", "wd"):
        return True           # attention-free SSM: share the projections
    return False


def _path_comps(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return tuple(out)


def shared_mask(cfg: ModelConfig,
                predicate: Optional[Callable] = None):
    """Pytree of bools (aligned with model_schema) marking shared leaves."""
    predicate = predicate or default_shared_predicate
    schema = M.model_schema(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=S.is_spec)
    leaves = [bool(predicate(_path_comps(path))) for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def shared_fraction(cfg: ModelConfig, predicate=None) -> float:
    """Fraction of parameters shared — the paper's security argument is that
    only PART of the network leaves the client."""
    predicate = predicate or default_shared_predicate
    schema = M.model_schema(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(schema, is_leaf=S.is_spec)
    tot = sum(sp.size for _, sp in flat)
    sh = sum(sp.size for path, sp in flat if predicate(_path_comps(path)))
    return sh / max(1, tot)


def make_blend_step(cfg: ModelConfig, alpha: float = 0.2,
                    predicate: Optional[Callable] = None,
                    dtype=jnp.bfloat16):
    """Returns blend_step(params_stacked, eval_batch) -> (new_params, losses).

    params_stacked: client-stacked params (C leading dim, sharded over `pod`);
    eval_batch: per-client recent batch (C, B, S) — the "last R periods" probe.
    losses: (C, C) matrix, losses[c, j] = client c's loss under candidate j's
    shared subtree (Eq. 7); argmin over j selects, Eq. 8 blends.

    Communication pattern on the mesh: reading candidate j's subtree from a
    pod-sharded stack is an all-gather of ONLY the shared leaves over `pod` —
    the paper's partial-network-sharing security property, expressed in
    collective form.
    """
    mask = shared_mask(cfg, predicate)

    def merge(own, candidate_shared):
        return jax.tree_util.tree_map(
            lambda m, a, b: b if m else a, mask, own, candidate_shared)

    def blend_step(params_stacked, eval_batch):
        def client_losses(params_c, batch_c):
            def with_candidate(shared_j):
                merged = merge(params_c, shared_j)
                loss, _ = M.lm_loss(merged, cfg, batch_c, dtype=dtype)
                return loss

            return jax.vmap(with_candidate)(params_stacked)  # (C,)

        baxes = {k: (1 if k == "positions" else 0) for k in eval_batch}
        losses = jax.vmap(client_losses, in_axes=(0, baxes))(
            params_stacked, eval_batch)                       # (C, C)
        best = jnp.argmin(losses, axis=1)                     # (C,)

        def blend_leaf(m, own_stack, _):
            if not m:
                return own_stack
            sel = own_stack[best]                             # (C, ...)
            return alpha * sel + (1 - alpha) * own_stack

        new_params = jax.tree_util.tree_map(
            lambda m, p: blend_leaf(m, p, None), mask, params_stacked)
        return new_params, losses

    return blend_step
