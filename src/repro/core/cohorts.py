"""Heterogeneous cohort engine: fast-path federation for mixed populations.

The paper's central claim is *heterogeneous* federated transfer — clients
with different feature sets sharing network parts asynchronously — but the
batched fast path stacks the whole population on one leading axis, which
requires every client to have the same feature count ``nf`` and identical
split shapes.  This module closes that gap: an arbitrary mixed population
(varying nf, ragged train/valid/test lengths) is partitioned into
**homogeneous cohorts** — maximal groups of clients that stack — and the
whole mixed epoch still runs as ONE compiled dispatch:

* **Per-cohort training.**  Each cohort's clients are stacked ``(C_k, ...)``
  and take the same vmapped Adam step the homogeneous engine uses
  (``hfl._train_step``), at the cohort's native geometry — no feature
  padding ever enters the training math, so values stay bit-identical to
  the sequential oracle.  Cohorts with fewer sub-rounds than the epoch's
  maximum run masked no-op steps on zero-padded round slices (the computed
  update is discarded with a ``where``, an exact copy of the old state) —
  that is how ragged lengths ride a single uniform scan.

* **Global padded pool exchange.**  Knowledge crosses cohorts through the
  union head pool, stacked ``(C, max_nf, ...)`` with every client's head
  rows zero-padded to ``max_nf`` and a static ``(C, max_nf)`` feature-
  validity mask.  Each sub-round replays the exact homogeneous policy round
  (``federation._policy_round_body`` with ``feat_valid``) over the padded
  union: the Eq.-7 scoring sweep runs over all ``C * max_nf`` rows (padded
  rows masked to ``inf``, so the ``pool_mlp`` kernel sweeps a dense
  rectangle), selection walks clients in their ORIGINAL list order
  (interleaved across cohorts, exactly the oracle), and Eq.-8 blending is
  projected back to each cohort's native nf by slicing the padded result.
  :func:`hetero_selection_lut` maps padded flat indices back to the
  oracle's sorted-foreign-pool positions so logged selections are
  identical.

* **Cohort-aware mesh sharding.**  With a multi-device ``clients`` mesh,
  each cohort's stack is partitioned over the same client axis (every
  cohort size must divide the device count) and the padded union pool is
  assembled from per-cohort all-gathers — the same replicated-deterministic
  exchange pattern as ``mesh_federation``, now per cohort.

``Federation(engine="batched")`` routes here automatically whenever the
population is heterogeneous (see ``federation._is_homogeneous``); cohorting
is an internal planning step surfaced in ``Federation.dispatch_stats``
(``cohorts``, ``per_cohort``).  Selections and validation histories are
bit-identical to the sequential oracle (pinned by ``tests/test_cohorts.py``
on the single-device and multi-device mesh paths).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh_federation as MF
from repro.core import telemetry as TEL
from repro.core import trust as TR
from repro.core.federation import (_exchange_round_bytes, _policy_round_body,
                                   _stack_trees, _tree_bytes, _tree_row,
                                   _wants_per_round)
from repro.core.hfl import (FederatedClient, _eval_mse, _train_step,
                            pool_kernel_available)
from repro.core.policies import FederationPolicies
from repro.optim import adam


# ---------------------------------------------------------------------------
# Cohort planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """One homogeneous cohort: clients with the same nf and identical
    train/valid/test shapes, stackable on a leading axis.  ``members`` are
    global client indices in their original Federation order (the policy
    round's client order is GLOBAL — cohorts only partition the training
    geometry, never the exchange order)."""
    nf: int
    members: Tuple[int, ...]
    n_train: int
    n_sub: int           # full R-sized sub-rounds per epoch for this cohort

    @property
    def size(self) -> int:
        return len(self.members)


@dataclasses.dataclass(frozen=True)
class CohortPlan:
    """The cohort engine's static execution plan — hashable, so it keys the
    compile cache of the fused heterogeneous epoch."""
    cohorts: Tuple[CohortSpec, ...]
    C: int
    max_nf: int
    R: int
    n_sub_max: int
    nfs: Tuple[int, ...]       # per global client
    n_subs: Tuple[int, ...]    # per global client

    def feat_valid(self) -> np.ndarray:
        """(C, max_nf) bool: which rows of each client's padded head/probe
        stacks are real features."""
        fv = np.zeros((self.C, self.max_nf), bool)
        for i, nf in enumerate(self.nfs):
            fv[i, :nf] = True
        return fv


def plan_cohorts(clients: Sequence[FederatedClient], R: int) -> CohortPlan:
    """Partition a population into homogeneous cohorts.

    The cohort key is (nf, train/valid/test shapes): two clients share a
    cohort iff their stacked state is one geometry.  Fully ragged
    populations degrade to singleton cohorts — still correct, just less
    vmap leverage.  Head geometry (the probe window w) must be uniform
    across the WHOLE population: the union pool stacks every client's head
    params into one tree, exactly like the sequential oracle's
    ``HeadPool.stacked_for`` (which would fail on mixed w too)."""
    w0 = {c.cfg.w for c in clients}
    if len(w0) != 1:
        raise ValueError(
            f"heterogeneous head widths w={sorted(w0)}: the shared head "
            f"pool requires one probe-window width across the population "
            f"(heads all map (w,) -> scalar); split the federation per w")
    groups = {}
    order = []
    for i, c in enumerate(clients):
        key = (c.nf,
               tuple(np.shape(a) for a in c.train),
               tuple(np.shape(a) for a in c.valid),
               tuple(np.shape(a) for a in c.test))
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append(i)
    cohorts = []
    for key in order:
        nf = key[0]
        members = tuple(groups[key])
        n_train = key[1][2][0] if len(key[1]) == 3 else 0
        n_sub = max(0, (n_train - R) // R + 1) if n_train >= R else 0
        cohorts.append(CohortSpec(nf=nf, members=members, n_train=n_train,
                                  n_sub=n_sub))
    nfs = tuple(c.nf for c in clients)
    n_subs = [0] * len(clients)
    for co in cohorts:
        for i in co.members:
            n_subs[i] = co.n_sub
    return CohortPlan(cohorts=tuple(cohorts), C=len(clients),
                      max_nf=max(nfs), R=R,
                      n_sub_max=max((co.n_sub for co in cohorts), default=0),
                      nfs=nfs, n_subs=tuple(n_subs))


def nf_strata(nfs: Sequence[int]) -> "OrderedDict[int, np.ndarray]":
    """Group population indices by feature count, in ascending-nf order —
    the stratification key the participation sampler uses.

    nf is a cheap METADATA proxy for the full cohort key (which also folds
    in split shapes that only exist once clients are materialized): every
    cohort of a sampled wave lies inside one nf stratum, so per-stratum
    sample counts sized to a mesh multiple keep every wave cohort
    mesh-divisible, and fixed per-stratum counts keep the per-wave
    ``CohortPlan`` geometry static across waves (compile-cache hits
    instead of a recompile per wave)."""
    from collections import OrderedDict
    nfs = np.asarray(nfs)
    return OrderedDict((int(nf), np.flatnonzero(nfs == nf))
                       for nf in np.unique(nfs))


# ---------------------------------------------------------------------------
# Padded union pool
# ---------------------------------------------------------------------------

def pad_features(tree, max_nf: int):
    """Zero-pad the leading (feature) axis of every leaf of an ``(nf, ...)``
    head tree to ``max_nf`` — the padded rows are dead weight the validity
    masks hide from every selection."""
    def pad(p):
        p = jnp.asarray(p)
        if p.shape[0] == max_nf:
            return p
        return jnp.concatenate(
            [p, jnp.zeros((max_nf - p.shape[0],) + p.shape[1:], p.dtype)], 0)
    return jax.tree_util.tree_map(pad, tree)


def _pad_axis1(tree, max_nf: int):
    """Zero-pad axis 1 (the feature axis of a client-stacked tree)."""
    def pad(p):
        if p.shape[1] == max_nf:
            return p
        widths = [(0, 0)] * p.ndim
        widths[1] = (0, max_nf - p.shape[1])
        return jnp.pad(p, widths)
    return jax.tree_util.tree_map(pad, tree)


def stack_hetero_pool(pool, names: Sequence[str], nfs: Sequence[int],
                      max_nf: int):
    """A HeadPool's entries as the cohort engine's padded ``(C, max_nf, ...)``
    stacked tree: every client's nf head entries, zero-padded to max_nf —
    the heterogeneous twin of ``federation.stack_pool``."""
    rows = []
    for n, nf in zip(names, nfs):
        stacked = _stack_trees([pool.entries[(n, f)] for f in range(nf)])
        rows.append(pad_features(stacked, max_nf))
    return _stack_trees(rows)


def hetero_selection_lut(names: Sequence[str], nfs: Sequence[int],
                         max_nf: int) -> np.ndarray:
    """Map the padded union pool's row-major (client, padded-feature) flat
    index to the sequential oracle's sorted-by-(name, feature) foreign-pool
    index for each selecting client — the mixed-nf generalization of
    ``federation._selection_lut`` (whose pools are rectangular).  Entries
    for the selector's own rows and for padded feature rows are -1."""
    C = len(names)
    lut = np.full((C, C * max_nf), -1, np.int64)
    for i in range(C):
        others = sorted((names[j], j) for j in range(C) if j != i)
        off = 0
        for _, j in others:
            for g in range(nfs[j]):
                lut[i, j * max_nf + g] = off + g
            off += nfs[j]
    return lut


# ---------------------------------------------------------------------------
# The fused heterogeneous epoch
# ---------------------------------------------------------------------------

def _tree_select(cond, new, old):
    """Elementwise keep-or-discard of a whole pytree update (exact copies —
    the ragged-round mask cannot perturb kept values)."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(cond, a, b), new, old)


def _hetero_epoch_body(lr: float, plan: CohortPlan,
                       policies: FederationPolicies, use_kernel: bool,
                       do_federate: bool, do_eval: bool, *,
                       exchange_every: int = 1, gather=None,
                       local_rows=None, shard=None, admission=None,
                       trust=None, telemetry=None):
    """The fused whole-epoch computation for a cohorted population, shared by
    the single-device and mesh backends: one ``lax.scan`` over the epoch's
    global sub-rounds.  Each step trains every cohort at its native
    geometry (masked where the cohort's rounds have run out), then — when
    federating — assembles the padded union view (heads + probe batches
    scattered into global client order), replays the exact homogeneous
    policy round over it with feature-validity masks, and projects each
    cohort's blended heads back to native nf.  Per-epoch eval + save-best
    run per cohort at the end.

    ``gather(tree)`` / ``local_rows(tree, k)`` are the mesh hooks: identity
    on the single-device path; the mesh backend injects a client-axis
    all-gather (per-cohort full view for the replicated policy round) and a
    dynamic-slice taking cohort k's device-local block back out.  ``shard``
    is forwarded to :func:`~repro.core.federation._policy_round_body`
    (client-sharded Eq.-7 scoring over the padded union pool's ``C *
    max_nf`` rows).  ``exchange_every`` = k > 1 segments the scan exactly
    like ``federation._epoch_body``: groups of k sub-rounds run k-1
    train-only steps plus one train+exchange step on the group's last
    round, leftover ``n_sub % k`` rounds never exchange — static cadence,
    so the mesh path traces the identical collective schedule on every
    device; k=1 is the historical flat scan, bit-identical.

    ``admission`` forwards the pool admission guard's norm bound to
    :func:`~repro.core.federation._policy_round_body`; when set, the epoch
    returns one extra trailing ``(exchange_rounds, C)`` bool rejection
    mask (None traces exactly the fault-free body).

    ``trust`` threads the trust layer at the PADDED geometry, exactly as
    ``federation._epoch_body`` does at the homogeneous one: the epoch
    function takes one extra trailing ``trust_arrays`` argument (padded
    signature stack / ``(net_masks, correction)`` scan leg / DP dummy)
    and returns one extra trailing ``((rounds, C) clip, (rounds, C)
    wm_failed)`` pair after the admission mask.  Secure aggregation
    replaces the padded-union selection with ``trust.secure_round`` over
    the padded stacks (``feat_valid`` silences padded rows in every sum).
    ``trust=None`` traces the byte-identical pre-trust graph.

    ``telemetry`` (a TelemetryPlan with the in-graph series enabled)
    appends one more trailing scan output — the per-round metrics 4-tuple
    ``(foreign_picks (C,) int32, score_min (C,) f32, score_mean (C,) f32,
    pool_age (C,) int32)`` at the padded geometry (padded features select
    -1, so they never count as picks) — appended LAST and therefore popped
    FIRST at every unpack site, before trust, before admission.
    ``telemetry=None`` traces the byte-identical pre-telemetry graph."""
    opt = adam(lr)
    step = jax.vmap(functools.partial(_train_step, opt))
    evaluate = jax.vmap(_eval_mse)
    K = len(plan.cohorts)
    C, max_nf, R = plan.C, plan.max_nf, plan.R
    feat_valid = plan.feat_valid()
    members = [np.asarray(co.members, np.int32) for co in plan.cohorts]
    bounded = policies.pool.bounded
    k_ex = int(exchange_every)
    secure = trust is not None and trust.secure_agg is not None
    secure_in_scan = secure and do_federate
    sel_trust = None if secure else trust
    if gather is None:
        gather = lambda t: t
    if local_rows is None:
        local_rows = lambda t, k: t

    def epoch(params_t, opt_t, pool_heads, pool_age, key, best_val_t,
              best_params_t, xs_t, xd_t, y_t, part, tick, live,
              val_xs_t, val_xd_t, val_y_t, trust_arrays=None):

        def train(params_t, opt_t, bx, bd, by, live_r):
            """Every cohort's masked native-geometry step for one
            sub-round (shared by exchange and train-only rounds)."""
            params_t, opt_t = list(params_t), list(opt_t)
            for k, co in enumerate(plan.cohorts):
                p2, o2, _ = step(params_t[k], opt_t[k], bx[k], bd[k], by[k])
                if co.n_sub == plan.n_sub_max:
                    params_t[k], opt_t[k] = p2, o2     # never a padded round
                else:
                    params_t[k] = _tree_select(live_r[k], p2, params_t[k])
                    opt_t[k] = _tree_select(live_r[k], o2, opt_t[k])
            return params_t, opt_t

        def body(carry, inp):
            params_t, opt_t, pool_heads, pool_age, key = carry
            if secure_in_scan:
                inp, (mask_e, corr_e) = inp
            (bx, bd, by), part_r, tick_r, live_r = inp
            params_t, opt_t = train(params_t, opt_t, bx, bd, by, live_r)
            if do_federate:
                if bounded:
                    pool_age = pool_age + tick_r
                key, sub = jax.random.split(key)
                # padded union view in GLOBAL client order: scatter each
                # cohort's (gathered) heads and probe batches into
                # (C, max_nf, ...) / (C, R, max_nf, w) zero-initialized
                # stacks — exact copies, so oracle bit-parity survives
                heads_g = jax.tree_util.tree_map(jnp.zeros_like, pool_heads)
                w = bd[0].shape[-1]
                xd_g = jnp.zeros((C, R, max_nf, w), bd[0].dtype)
                y_g = jnp.zeros((C, R), by[0].dtype)
                for k in range(K):
                    idx = members[k]
                    hk = _pad_axis1(gather(params_t[k]["heads"]), max_nf)
                    heads_g = jax.tree_util.tree_map(
                        lambda g, h: g.at[idx].set(h), heads_g, hk)
                    if not secure:      # secure needs no probe scatters
                        dk = gather(bd[k])             # (C_k, R, nf_k, w)
                        pad = max_nf - dk.shape[2]
                        if pad:
                            dk = jnp.pad(dk,
                                         ((0, 0), (0, 0), (0, pad), (0, 0)))
                        xd_g = xd_g.at[idx].set(dk)
                        y_g = y_g.at[idx].set(gather(by[k]))
                if secure:
                    (new_heads, pool_heads, pool_age, chosen, rej,
                     clip) = TR.secure_round(
                        heads_g, pool_heads, pool_age, part_r, mask_e,
                        corr_e, sub, feat_valid=feat_valid,
                        sa=trust.secure_agg, dp=trust.dp, nf=max_nf,
                        admission=admission)
                    tstats = (clip, jnp.zeros((C,), bool))
                else:
                    out = _policy_round_body(
                        heads_g, pool_heads, pool_age, xd_g, y_g, part_r,
                        sub, nf=max_nf, policies=policies,
                        use_kernel=use_kernel, feat_valid=feat_valid,
                        shard=shard, admission=admission, trust=sel_trust,
                        trust_sig=(trust_arrays if sel_trust is not None
                                   and sel_trust.watermark is not None
                                   else None), telemetry=telemetry)
                    if telemetry is not None:
                        scores = out[-1]
                        out = out[:-1]
                    if trust is not None:
                        tstats = out[-1]
                        out = out[:-1]
                    if admission is not None:
                        new_heads, pool_heads, pool_age, chosen, rej = out
                    else:
                        new_heads, pool_heads, pool_age, chosen = out
                for k, co in enumerate(plan.cohorts):
                    rows = jax.tree_util.tree_map(
                        lambda g: g[members[k], :co.nf], new_heads)
                    params_t[k] = {**params_t[k],
                                   "heads": local_rows(rows, k)}
            else:
                chosen = jnp.full((C, max_nf), -1, jnp.int32)
                if admission is not None:
                    rej = jnp.zeros((C,), bool)
                if trust is not None:
                    tstats = (jnp.zeros((C,), bool), jnp.zeros((C,), bool))
            if telemetry is not None:
                if not do_federate or secure:
                    # non-exchanging / masked-secure rounds score nothing:
                    # the series carry the inf/0 sentinels
                    scores = (jnp.full((C,), jnp.inf, jnp.float32),
                              jnp.zeros((C,), jnp.float32))
                tele_r = (jnp.sum(chosen >= 0, axis=-1).astype(jnp.int32),
                          scores[0], scores[1], pool_age)
            ys = (chosen,)
            if admission is not None:
                ys = ys + (rej,)
            if trust is not None:
                ys = ys + (tstats,)
            if telemetry is not None:
                ys = ys + (tele_r,)
            if len(ys) == 1:
                ys = ys[0]
            return ((tuple(params_t), tuple(opt_t), pool_heads, pool_age,
                     key), ys)

        def train_only(carry, inp):
            params_t, opt_t, pool_heads, pool_age, key = carry
            (bx, bd, by), part_r, tick_r, live_r = inp
            params_t, opt_t = train(params_t, opt_t, bx, bd, by, live_r)
            return ((tuple(params_t), tuple(opt_t), pool_heads, pool_age,
                     key), None)

        xs_all = ((xs_t, xd_t, y_t), part, tick, live)
        carry = (params_t, opt_t, pool_heads, pool_age, key)
        if not do_federate or k_ex == 1:
            # the historical flat scan; exchange_every=1 stays bit-identical
            xs = (xs_all, trust_arrays) if secure_in_scan else xs_all
            carry, ys = jax.lax.scan(body, carry, xs)
        else:
            n_sub = part.shape[0]
            n_grp, rem = divmod(n_sub, k_ex)
            grouped = jax.tree_util.tree_map(
                lambda t: t[:n_grp * k_ex].reshape(
                    (n_grp, k_ex) + t.shape[1:]), xs_all)

            def group(carry, inp_k):
                # k-1 train-only rounds, then train + exchange on the
                # group's LAST round (probes = that round's own R-batches)
                if secure_in_scan:
                    inp_k, masks_e = inp_k
                carry, _ = jax.lax.scan(
                    train_only, carry,
                    jax.tree_util.tree_map(lambda t: t[:k_ex - 1], inp_k))
                last = jax.tree_util.tree_map(lambda t: t[k_ex - 1], inp_k)
                if secure_in_scan:
                    last = (last, masks_e)
                return body(carry, last)

            xs = (grouped, trust_arrays) if secure_in_scan else grouped
            carry, ys = jax.lax.scan(group, carry, xs)
            if rem:                       # leftover rounds never exchange
                carry, _ = jax.lax.scan(
                    train_only, carry,
                    jax.tree_util.tree_map(lambda t: t[n_grp * k_ex:],
                                           xs_all))
        if telemetry is not None:
            tele = ys[-1]
            ys = ys[:-1]
            if len(ys) == 1:
                ys = ys[0]
        else:
            tele = None
        if admission is not None and trust is not None:
            chosen, rejected, tstats = ys
        elif admission is not None:
            chosen, rejected = ys
            tstats = None
        elif trust is not None:
            chosen, tstats = ys
            rejected = None
        else:
            chosen, rejected, tstats = ys, None, None
        (params_t, opt_t, pool_heads, pool_age, key) = carry
        if do_eval:
            vs, new_bv, new_bp = [], [], []
            for k in range(K):
                v = evaluate(params_t[k], val_xs_t[k], val_xd_t[k],
                             val_y_t[k])                  # (local clients,)
                improved = v < best_val_t[k]
                new_bv.append(jnp.where(improved, v, best_val_t[k]))
                n_loc = v.shape[0]
                new_bp.append(jax.tree_util.tree_map(
                    lambda b, p: jnp.where(
                        improved.reshape((n_loc,) + (1,) * (p.ndim - 1)),
                        p, b),
                    best_params_t[k], params_t[k]))
                vs.append(v)
            best_val_t, best_params_t = tuple(new_bv), tuple(new_bp)
            v_t = tuple(vs)
        else:
            v_t = None
        out = (params_t, opt_t, pool_heads, pool_age, key, best_val_t,
               best_params_t, v_t, chosen)
        if admission is not None:
            out = out + (rejected,)
        if trust is not None:
            out = out + (tstats,)
        if telemetry is not None:
            out = out + (tele,)
        return out

    return epoch


@functools.lru_cache(maxsize=None)
def _make_hetero_epoch_fn(lr: float, plan: CohortPlan,
                          policies: FederationPolicies, use_kernel: bool,
                          do_federate: bool, do_eval: bool,
                          exchange_every: int = 1, admission=None,
                          trust=None, telemetry=None):
    """Compile-cached fused heterogeneous epoch (single-device): one
    dispatch scans every global sub-round of a mixed-cohort epoch, with the
    whole carried state donated — the cohort twin of
    ``federation._make_epoch_fn``.  The cache key adds the (hashable)
    :class:`CohortPlan`, so every distinct population LAYOUT compiles once
    and every cohort inside it shares that single program."""
    epoch = _hetero_epoch_body(lr, plan, policies, use_kernel, do_federate,
                               do_eval, exchange_every=exchange_every,
                               admission=admission, trust=trust,
                               telemetry=telemetry)
    return jax.jit(epoch, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


@functools.lru_cache(maxsize=None)
def _make_mesh_hetero_epoch_fn(lr: float, plan: CohortPlan, w: int,
                               policies: FederationPolicies,
                               use_kernel: bool, do_federate: bool,
                               do_eval: bool, mesh,
                               exchange_every: int = 1, admission=None,
                               trust=None, telemetry=None):
    """The client-sharded twin of :func:`_make_hetero_epoch_fn`: the same
    epoch body under ``shard_map``, with every cohort's stack partitioned
    over the mesh's ``clients`` axis (each cohort size must divide the
    device count — :func:`validate_cohort_mesh`), the padded union pool
    assembled from per-cohort all-gathers, and the Eq.-7 sweep over the
    padded union sharded per device (``shard=(axis, D)`` — each device
    scores its contiguous ``C * max_nf / D`` chunk, argminima merged
    through a tiny (D, max_nf) gather), everything downstream
    replicated-deterministic exactly like
    ``mesh_federation._make_mesh_epoch_fn``."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = MF.client_axis(mesh)
    D = MF.mesh_devices(mesh)
    cl, rep, data = P(axis), P(), P(None, axis)
    K = len(plan.cohorts)
    pspecs_t = tuple(MF.param_pspecs(co.nf, w, co.size, mesh)
                     for co in plan.cohorts)
    c_locs = [co.size // D for co in plan.cohorts]

    def gather(tree):
        return jax.lax.all_gather(tree, axis, tiled=True)

    def local_rows(tree, k):
        i0 = jax.lax.axis_index(axis) * c_locs[k]
        return jax.tree_util.tree_map(
            lambda g: jax.lax.dynamic_slice_in_dim(g, i0, c_locs[k], 0),
            tree)

    epoch = _hetero_epoch_body(lr, plan, policies, use_kernel, do_federate,
                               do_eval, exchange_every=exchange_every,
                               gather=gather, local_rows=local_rows,
                               shard=(axis, D), admission=admission,
                               trust=trust, telemetry=telemetry)
    tup = lambda spec: tuple(spec for _ in range(K))
    out_specs = (pspecs_t, tup(cl), rep, rep, rep, tup(cl), pspecs_t,
                 tup(cl) if do_eval else None, rep)
    if admission is not None:
        out_specs = out_specs + (rep,)   # rejection mask is replicated
    in_specs = (pspecs_t, tup(cl), rep, rep, rep, tup(cl), pspecs_t,
                tup(data), tup(data), tup(data), rep, rep, rep,
                tup(cl), tup(cl), tup(cl))
    if trust is not None:
        # trust inputs (padded signature stack / mask pair / dummy) and
        # the per-round trust stats are replicated like the pool carry
        in_specs = in_specs + (rep,)
        out_specs = out_specs + (rep,)
    if telemetry is not None:
        # the per-round metrics 4-tuple comes back replicated (derived
        # from the replicated pool carry / collectively-reduced scores);
        # a single ``rep`` prefixes the whole tuple, as for trust above
        out_specs = out_specs + (rep,)
    sharded = shard_map(
        epoch, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False)
    return jax.jit(sharded, donate_argnums=(0, 1, 2, 3, 4, 5, 6))


def validate_cohort_mesh(mesh, plan: CohortPlan) -> None:
    """Client-sharded cohort execution needs every cohort's stack to split
    evenly over the mesh: each device owns a contiguous equal block of each
    cohort.  Raise with the offending cohort sizes otherwise."""
    D = MF.mesh_devices(mesh)
    bad = [co.size for co in plan.cohorts if co.size % D]
    if bad:
        raise ValueError(
            f"cohort sizes {bad} cannot shard evenly over {D} devices "
            f"(every cohort size must be a multiple of the device count); "
            f"pad the population per cohort, regroup it, or run without "
            f"a mesh")


def shard_hetero_fit_state(mesh, plan: CohortPlan, w: int, *, params_t,
                           opt_t, pool_heads, pool_age, key, best_val_t,
                           best_params_t, rounds_t, val_t):
    """Place the cohort engine's fit state on the mesh (the heterogeneous
    twin of ``mesh_federation.shard_fit_state``): per-cohort trees get the
    schema-derived client partitioning, the padded union pool / ages / PRNG
    key are replicated, per-cohort round data partitions its client (2nd)
    axis."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    validate_cohort_mesh(mesh, plan)
    axis = MF.client_axis(mesh)
    named = lambda ps: NamedSharding(mesh, ps)
    clients_sh, rep = named(P(axis)), named(P())

    def put_params(trees):
        return tuple(
            jax.device_put(t, jax.tree_util.tree_map(
                named, MF.param_pspecs(co.nf, w, co.size, mesh)))
            for t, co in zip(trees, plan.cohorts))

    params_t = put_params(params_t)
    best_params_t = put_params(best_params_t)
    opt_t = tuple(jax.device_put(t, clients_sh) for t in opt_t)
    best_val_t = tuple(jax.device_put(t, clients_sh) for t in best_val_t)
    pool_heads = jax.device_put(pool_heads, rep)
    pool_age = jax.device_put(pool_age, rep)
    key = jax.device_put(key, rep)
    rounds_t = tuple(
        tuple(jax.device_put(a, named(P(None, axis))) for a in rd)
        for rd in rounds_t)
    val_t = tuple(tuple(jax.device_put(a, clients_sh) for a in vd)
                  for vd in val_t)
    return (params_t, opt_t, pool_heads, pool_age, key, best_val_t,
            best_params_t, rounds_t, val_t)


# ---------------------------------------------------------------------------
# The cohorted fit loop
# ---------------------------------------------------------------------------

def _fit_cohorted(fed, n_epochs: int, cbs) -> None:
    """The batched executor's heterogeneous path: plan cohorts, stack each
    at its native geometry, scan whole mixed epochs inside one compiled
    dispatch (chunked per sub-round when a callback needs per-round
    delivery), exchange heads through the padded union pool, and write
    results back through the same sync contract as the homogeneous
    executor.  Selection- and value-identical to the sequential oracle."""
    clients = fed.clients
    C = len(clients)
    names = [c.name for c in clients]
    cfg, pol = fed.cfg, fed.policies
    R = fed.schedule.R
    plan = plan_cohorts(clients, R)
    K = len(plan.cohorts)
    n_sub_max = plan.n_sub_max
    n_subs = np.asarray(plan.n_subs)

    def rounds_axis(t, n_sub):
        """(C_k, n, ...) -> (n_sub_max, C_k, R, ...): the cohort's R-slices
        on a leading scan axis, zero-padded to the global round count (the
        padded rounds are masked no-ops)."""
        Ck = t.shape[0]
        m = n_sub * R
        r = jnp.moveaxis(t[:, :m].reshape((Ck, n_sub, R) + t.shape[2:]),
                         1, 0)
        if n_sub < n_sub_max:
            r = jnp.concatenate(
                [r, jnp.zeros((n_sub_max - n_sub,) + r.shape[1:],
                              r.dtype)], 0)
        return r

    rounds_t, val_t = [], []
    params_l, opt_l, bv_l, bp_l = [], [], [], []
    for co in plan.cohorts:
        cs = [clients[i] for i in co.members]
        stacked = tuple(jnp.stack([np.asarray(c.train[j]) for c in cs])
                        for j in range(3))
        rounds_t.append(tuple(rounds_axis(t, co.n_sub) for t in stacked))
        val_t.append(tuple(jnp.stack([np.asarray(c.valid[j]) for c in cs])
                           for j in range(3)))
        params_l.append(_stack_trees([c.params for c in cs]))
        opt_l.append(_stack_trees([c.opt_state for c in cs]))
        bv_l.append(jnp.asarray([c.best_val for c in cs], jnp.float32))
        bp_l.append(_stack_trees([c.best_params for c in cs]))
    rounds_t, val_t = tuple(rounds_t), tuple(val_t)
    params_t, opt_t = tuple(params_l), tuple(opt_l)
    best_val_t, best_params_t = tuple(bv_l), tuple(bp_l)

    pool_heads = stack_hetero_pool(fed.pool, names, plan.nfs, plan.max_nf)
    pool_age = jnp.asarray([fed.pool.age_of(n_) for n_ in names], jnp.int32)
    use_kernel = cfg.use_pool_kernel and pool_kernel_available()
    lut = hetero_selection_lut(names, plan.nfs, plan.max_nf)
    admission = fed._admission()
    smask = fed._straggler_mask
    trust = fed._trust
    secure = trust is not None and trust.secure_agg is not None
    # telemetry layer: `tele` = the enabled plan iff the in-graph series is
    # on (static jit arg; None traces the uninstrumented graph), `rec` =
    # the host-side flight recorder
    tele = fed._tele_rounds()
    rec = fed._recorder
    # host templates/derivations the trust layer needs, at the PADDED
    # geometry (masks and signatures ride the (C, max_nf, ...) union)
    head_tmpl = TR.pad_rows(jax.tree_util.tree_map(
        np.asarray, clients[0].params["heads"]), plan.max_nf) \
        if secure else None
    sig_stack = None
    if trust is not None and trust.watermark is not None:
        sig_stack = jax.tree_util.tree_map(
            jnp.asarray,
            TR.stack_trees_np([TR.pad_rows(fed._wm_sig(c), plan.max_nf)
                               for c in clients]))
    clip_total = 0
    wm_fail = np.zeros(C, np.int64)
    dp_pubs = np.zeros(C, np.int64)
    heads_rejected = 0
    live_np = np.asarray([[k < co.n_sub for co in plan.cohorts]
                          for k in range(n_sub_max)], bool)

    k_ex = fed.schedule.exchange_every
    exch = fed.schedule.exchange_mask(n_sub_max)
    n_exch_epoch = fed.schedule.exchanges(n_sub_max)
    exchange_rounds = 0
    pool_bytes = 0
    # per-device bytes one mesh exchange round moves (0 on one device):
    # padded-union pool heads + per-cohort probe gathers at native nf,
    # reduce sized by the padded union (ns = C * max_nf)
    heads_bytes = _tree_bytes(pool_heads)
    probe_bytes = sum(co.size * R * (co.nf * cfg.w + 1) * 4
                      for co in plan.cohorts)
    exch_bytes = _exchange_round_bytes(
        MF.mesh_devices(fed._exec_mesh()), heads_bytes, probe_bytes,
        C, plan.max_nf, C * plan.max_nf,
        pol.selection) if fed._exec_mesh() is not None else 0

    histories = [list(c.val_history) for c in clients]
    # device-resident learnable state across all cohorts (the participation
    # orchestrator's gather/scatter unit and bounded-working-set meter)
    state_bytes = sum(_tree_bytes((p, o, bp)) for p, o, bp in
                      zip(params_t, opt_t, best_params_t))
    n_rounds = np.zeros(C, np.int64)
    base_rounds = dict(fed.n_rounds)
    key = fed._key

    mesh = fed._exec_mesh()
    if mesh is not None:
        (params_t, opt_t, pool_heads, pool_age, key, best_val_t,
         best_params_t, rounds_t, val_t) = shard_hetero_fit_state(
            mesh, plan, cfg.w, params_t=params_t, opt_t=opt_t,
            pool_heads=pool_heads, pool_age=pool_age, key=key,
            best_val_t=best_val_t, best_params_t=best_params_t,
            rounds_t=rounds_t, val_t=val_t)

    def make_epoch_fn(do_federate: bool, do_eval: bool,
                      exchange_every: int = 1):
        if mesh is not None:
            return _make_mesh_hetero_epoch_fn(cfg.lr, plan, cfg.w, pol,
                                              use_kernel, do_federate,
                                              do_eval, mesh, exchange_every,
                                              admission, trust, tele)
        return _make_hetero_epoch_fn(cfg.lr, plan, pol, use_kernel,
                                     do_federate, do_eval, exchange_every,
                                     admission, trust, tele)

    def trust_args(act_rows, e_off: int = 0):
        """The epoch function's trailing ``trust_arrays`` argument for one
        dispatch.  ``act_rows`` is the (n_exch, C) per-exchange-round
        participation — on the cohort engine the publisher set varies per
        sub-round (clients drop out as their sub-rounds run dry), so the
        secure dropout correction is reconstructed per round from the
        round's own survivor set."""
        if trust is None:
            return ()
        if secure:
            n_exch = len(act_rows)
            wave = fed._trust_wave_base + fed.epoch
            masks = TR.net_masks(trust.secure_agg, wave, n_exch,
                                 fed._trust_ids, head_tmpl,
                                 round_offset=e_off)
            corrs = [TR.mask_correction(
                jax.tree_util.tree_map(lambda m: m[r:r + 1], masks),
                act_rows[r]) for r in range(n_exch)]
            if corrs:
                corr = jax.tree_util.tree_map(
                    lambda *cs: np.concatenate(cs), *corrs)
            else:
                corr = jax.tree_util.tree_map(
                    lambda m: np.zeros((0,) + m.shape[2:], m.dtype), masks)
            ta = jax.tree_util.tree_map(jnp.asarray, (masks, corr))
        elif sig_stack is not None:
            ta = sig_stack
        else:
            ta = jnp.zeros((), jnp.float32)
        if mesh is not None:
            ta = MF.replicate(mesh, ta)
        return (ta,)

    def account_trust(tstats, rej, opps):
        """Fold one dispatch's trust outputs into the fit's counters.
        ``opps``: (C,) per-client exchange publication opportunities this
        dispatch (zero everywhere on a non-federating dispatch)."""
        nonlocal clip_total
        if trust is None:
            return
        clip_r, wmf_r = (np.asarray(t) for t in tstats)
        clip_total += int(clip_r.sum())
        wmf_pc = wmf_r.reshape(-1, C).sum(axis=0).astype(np.int64)
        wm_fail[:] += wmf_pc
        if trust.dp is not None:
            rej_pc = (np.asarray(rej).reshape(-1, C).sum(axis=0)
                      if rej is not None else np.zeros(C, np.int64))
            dp_pubs[:] += np.asarray(opps, np.int64) - wmf_pc - rej_pc

    fused = not any(_wants_per_round(cb) for cb in cbs)
    n_dispatch = 0

    def sync():
        """Write the per-cohort loop state back into the clients / pool /
        rng — after the loop, and on demand for mid-fit checkpoints."""
        ages = np.asarray(pool_age)
        for k, co in enumerate(plan.cohorts):
            bv = np.asarray(best_val_t[k])
            for r, i in enumerate(co.members):
                c = clients[i]
                c.params = _tree_row(params_t[k], r)
                c.opt_state = _tree_row(opt_t[k], r)
                c.val_history = histories[i]
                c.best_val = float(bv[r])
                c.best_params = _tree_row(best_params_t[k], r)
        for i, c in enumerate(clients):
            row = jax.tree_util.tree_map(
                lambda p: p[i, :plan.nfs[i]], pool_heads)
            fed.pool.publish(c.name, row, plan.nfs[i], age=int(ages[i]))
            fed.n_rounds[c.name] = base_rounds[c.name] + int(n_rounds[i])
        fed._key = key

    fed._sync = sync
    for _ in range(n_epochs):
        epoch = fed.epoch
        active = np.asarray(pol.switch.active_mask(histories,
                                                   fed._switch_rng))
        if smask is not None:   # stragglers train but miss every exchange
            active = active & ~np.asarray(smask, bool)
        do_federate = bool(active.any()) and C >= 2
        # participation: epoch-active AND the client still has sub-rounds
        # left (the oracle's live set); the staleness clock ticks in every
        # sub-round where federation COULD run among still-live clients —
        # note >= (a client exhausted in exactly this round still counts,
        # matching the oracle's live-at-start-of-iteration semantics)
        part_np = active[None, :] & \
            (np.arange(n_sub_max)[:, None] < n_subs[None, :])
        if pol.pool.bounded and do_federate:
            tick_np = np.asarray(
                [(active & (n_subs >= k)).any() for k in range(n_sub_max)],
                np.int32)
        else:
            tick_np = np.zeros(n_sub_max, np.int32)
        part = jnp.asarray(part_np)
        tick = jnp.asarray(tick_np)
        live = jnp.asarray(live_np)
        if mesh is not None:
            part = MF.replicate(mesh, part)
            tick = MF.replicate(mesh, tick)
            live = MF.replicate(mesh, live)
        state = (params_t, opt_t, pool_heads, pool_age, key, best_val_t,
                 best_params_t)
        fed._mid_epoch = True
        if fused:
            epoch_fn = make_epoch_fn(do_federate, True, k_ex)
            act_rows = part_np[exch] if do_federate else part_np[:0]
            with TEL.span(rec, "dispatch", epoch=epoch, path="fused"):
                out = epoch_fn(*state,
                               tuple(r[0] for r in rounds_t),
                               tuple(r[1] for r in rounds_t),
                               tuple(r[2] for r in rounds_t),
                               part, tick, live,
                               tuple(v[0] for v in val_t),
                               tuple(v[1] for v in val_t),
                               tuple(v[2] for v in val_t),
                               *trust_args(act_rows))
            if tele is not None:   # telemetry rides LAST: pop it first
                tele_out, out = out[-1], out[:-1]
            if trust is not None:
                tstats, out = out[-1], out[:-1]
            if admission is not None:
                (*state, v_t, chosen, rej) = out
                heads_rejected += int(np.asarray(rej).sum())
            else:
                (*state, v_t, chosen) = out
                rej = None
            account_trust(tstats, rej, act_rows.sum(axis=0)) \
                if trust is not None else None
            n_dispatch += 1
        else:
            chunks = []
            tele_chunks = []
            e_done = 0          # exchange rounds executed so far this epoch
                                # (the trust layer's within-epoch mask index)
            for rnd in range(n_sub_max):
                # cadence on the chunked path: a non-exchange sub-round is
                # exactly a do_federate=False dispatch (train-only)
                fed_r = do_federate and bool(exch[rnd])
                epoch_fn = make_epoch_fn(fed_r, rnd == n_sub_max - 1)
                sl = slice(rnd, rnd + 1)
                act_rows = part_np[sl] if fed_r else part_np[:0]
                with TEL.span(rec, "dispatch", epoch=epoch, round=rnd,
                              path="chunked"):
                    out = epoch_fn(
                        *state,
                        tuple(r[0][sl] for r in rounds_t),
                        tuple(r[1][sl] for r in rounds_t),
                        tuple(r[2][sl] for r in rounds_t),
                        part[sl], tick[sl], live[sl],
                        tuple(v[0] for v in val_t),
                        tuple(v[1] for v in val_t),
                        tuple(v[2] for v in val_t),
                        *trust_args(act_rows, e_done))
                if tele is not None:
                    tele_chunks.append(out[-1])
                    out = out[:-1]
                if trust is not None:
                    tstats, out = out[-1], out[:-1]
                if admission is not None:
                    (*state, v_t, ch, rej) = out
                    heads_rejected += int(np.asarray(rej).sum())
                else:
                    (*state, v_t, ch) = out
                    rej = None
                account_trust(tstats, rej, act_rows.sum(axis=0)) \
                    if trust is not None else None
                if fed_r:
                    e_done += 1
                chunks.append(ch)
                n_dispatch += 1
                (params_t, opt_t, pool_heads, pool_age, key, best_val_t,
                 best_params_t) = state
                if exch[rnd]:
                    n_rounds += part_np[rnd]
                for cb in cbs:
                    cb.on_round(fed, epoch, rnd)
            if n_sub_max == 0:   # no trainable sub-round: eval-only dispatch
                epoch_fn = make_epoch_fn(do_federate, True)
                with TEL.span(rec, "dispatch", epoch=epoch,
                              path="eval-only"):
                    out = epoch_fn(
                        *state,
                        tuple(r[0] for r in rounds_t),
                        tuple(r[1] for r in rounds_t),
                        tuple(r[2] for r in rounds_t),
                        part, tick, live,
                        tuple(v[0] for v in val_t),
                        tuple(v[1] for v in val_t),
                        tuple(v[2] for v in val_t),
                        *trust_args(part_np[:0]))
                if tele is not None:
                    out = out[:-1]
                if trust is not None:
                    out = out[:-1]
                if admission is not None:
                    (*state, v_t, ch, _rej) = out
                else:
                    (*state, v_t, ch) = out
                chunks.append(ch)
                n_dispatch += 1
            chosen = jnp.concatenate(chunks) if chunks else None
            tele_out = tuple(
                np.concatenate([np.asarray(t[k]) for t in tele_chunks])
                for k in range(4)) if tele is not None and tele_chunks \
                else None
        (params_t, opt_t, pool_heads, pool_age, key, best_val_t,
         best_params_t) = state
        with TEL.span(rec, "exchange", epoch=epoch):
            if do_federate and chosen is not None:
                ch_np = np.asarray(chosen)      # (rounds, C, max_nf)
                for ch in ch_np:
                    for i in range(C):
                        if ch[i][0] >= 0:
                            nf_i = plan.nfs[i]
                            fed.selections[names[i]].append(
                                lut[i, ch[i][:nf_i]].tolist())
            if tele is not None and tele_out is not None:
                rec.record_epoch_rounds(epoch, tele_out, active)
        if fused:
            n_rounds += part_np[exch].sum(axis=0)
        if rec is not None:
            done = int(part_np[exch].sum())
            if done:
                rec.count("client_rounds", done)
        # refresh the live counters each epoch (idempotent with sync())
        for i, nm in enumerate(names):
            fed.n_rounds[nm] = base_rounds[nm] + int(n_rounds[i])
        if do_federate:
            exchange_rounds += n_exch_epoch
            pool_bytes += n_exch_epoch * exch_bytes
        v_all = np.empty(C, np.float64)
        for k, co in enumerate(plan.cohorts):
            v_all[np.asarray(co.members)] = np.asarray(v_t[k], np.float64)
        for i in range(C):
            histories[i].append(float(v_all[i]))
        fed.epoch += 1
        fed._mid_epoch = False
        for cb in cbs:
            cb.on_epoch_end(fed, epoch,
                            {names[i]: float(v_all[i]) for i in range(C)},
                            {names[i]: bool(active[i]) for i in range(C)})

    if trust is not None:
        fed._clip_events += clip_total
        for i, nm in enumerate(names):
            if wm_fail[i]:
                fed._wm_failures[nm] = (fed._wm_failures.get(nm, 0)
                                        + int(wm_fail[i]))
            if dp_pubs[i]:
                fed._dp_counts[nm] = (fed._dp_counts.get(nm, 0)
                                      + int(dp_pubs[i]))
    if rec is not None:
        # fold this fit's in-graph counters into the flight recorder (the
        # participation orchestrator may overwrite dispatch_stats later)
        if heads_rejected:
            rec.count("heads_rejected", int(heads_rejected))
        if trust is not None:
            if clip_total:
                rec.count("clip_events", int(clip_total))
            if wm_fail.sum():
                rec.count("watermark_failures", int(wm_fail.sum()))
    fed.dispatch_stats = {
        "engine": "batched",
        "path": "fused" if fused else "chunked",
        "devices": MF.mesh_devices(mesh),
        "cohorts": K,
        "per_cohort": [{"nf": co.nf, "clients": co.size,
                        "sub_rounds": co.n_sub, "dispatches": n_dispatch}
                       for co in plan.cohorts],
        "epochs": n_epochs, "dispatches": n_dispatch,
        "dispatches_per_epoch": n_dispatch / n_epochs,
        "exchange_every": k_ex,
        "exchange_rounds": exchange_rounds,
        "pool_bytes_gathered": pool_bytes,
        "state_bytes": state_bytes,
        **fed._fault_stats(heads_rejected),
        **fed._trust_stats()}
    sync()
    fed._sync = None
