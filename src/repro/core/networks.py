"""Paper networks (Table 4) + benchmark systems (DNN, BIBE, BIBEP).

All built on the framework's ParamSpec schema machinery, so they share init /
abstract / sharding tooling with the large-model zoo.

Table 4 exact layer widths:
  Head H:        Linear 16 - Sigmoid - Linear 256 - Sigmoid - Linear 64 -
                 LReLU - Linear 16 - LReLU - Linear 1
  Embedding E:   same trunk, final Linear w
  Prediction P:  Linear 32 - Sigmoid - Linear 256 - Sigmoid - Linear 16 -
                 LReLU - Linear 1 - LReLU - Linear 1
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.spec import ParamSpec

LRELU_SLOPE = 0.01


def _mlp_schema(dims: Sequence[int]):
    layers = {}
    for i in range(len(dims) - 1):
        layers[f"w{i}"] = ParamSpec((dims[i], dims[i + 1]), (None, None))
        layers[f"b{i}"] = ParamSpec((dims[i + 1],), (None,), init="zeros")
    return layers


def _mlp_apply(params, x, acts: Sequence[str]):
    n = len(acts) + 1
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < len(acts):
            if acts[i] == "sigmoid":
                x = jax.nn.sigmoid(x)
            elif acts[i] == "lrelu":
                x = jax.nn.leaky_relu(x, LRELU_SLOPE)
    return x


# ---------------------------------------------------------------------------
# HFL component networks (Table 4)
# ---------------------------------------------------------------------------

_H_ACTS = ("sigmoid", "sigmoid", "lrelu", "lrelu")


def head_schema(w: int):
    """Global head H_i: dense feature vector (w,) -> scalar preliminary y'."""
    return _mlp_schema((w, 16, 256, 64, 16, 1))


def head_apply(params, xd):
    """xd: (..., w) -> (...,)."""
    return _mlp_apply(params, xd, _H_ACTS)[..., 0]


def head_pool_apply(pool_stacked, xd):
    """Apply every head of a stacked pool to one probe batch.

    pool_stacked: head params with a leading pool dim (ns, ...);
    xd: (R, w).  Returns (ns, R) preliminary predictions."""
    return jax.vmap(lambda h: head_apply(h, xd))(pool_stacked)


def embed_schema(nf: int, w: int):
    """Local embedding E: sparse tensor (nf*w,) -> temporal embedding (w,)."""
    return _mlp_schema((nf * w, 16, 256, 64, 16, w))


def embed_apply(params, xs_flat):
    return _mlp_apply(params, xs_flat, _H_ACTS)


def pred_schema(nf: int, w: int):
    """Prediction P: [y'_1..y'_nf, e] (nf+w,) -> scalar y'."""
    return _mlp_schema((nf + w, 32, 256, 16, 1, 1))


def pred_apply(params, z):
    return _mlp_apply(params, z, _H_ACTS)[..., 0]


def hfl_schema(nf: int, w: int):
    from repro.sharding.spec import stack
    return {
        "heads": stack(head_schema(w), nf),     # stacked over features
        "embed": embed_schema(nf, w),
        "pred": pred_schema(nf, w),
    }


def hfl_forward(params, xs, xd):
    """xs, xd: (B, nf, w).  Returns (y_final (B,), y_prelim (B, nf))."""
    y_prelim = jax.vmap(head_apply, in_axes=(0, 1), out_axes=1)(
        params["heads"], xd)                             # (B, nf)
    e = embed_apply(params["embed"], xs.reshape(xs.shape[0], -1))  # (B, w)
    z = jnp.concatenate([y_prelim, e], axis=-1)
    y = pred_apply(params["pred"], z)
    return y, y_prelim


def hfl_loss(params, xs, xd, y):
    """Multi-task MSE (Eqs. 3 & 6): final + nf preliminary tasks."""
    y_hat, y_prelim = hfl_forward(params, xs, xd)
    final = jnp.mean((y - y_hat) ** 2)
    prelim = jnp.mean(jnp.sum((y[:, None] - y_prelim) ** 2, axis=-1))
    return final + prelim, (final, prelim)


# ---------------------------------------------------------------------------
# Benchmarks
# ---------------------------------------------------------------------------

def dnn_schema(nf: int, w: int):
    """Traditional benchmark: 4-layer DNN (64, 1024, 64, 1) on the
    concatenated [X^S, X^D] tensors (paper §5.2)."""
    return _mlp_schema((2 * nf * w, 64, 1024, 64, 1))


def dnn_apply(params, xs, xd):
    x = jnp.concatenate([xs.reshape(xs.shape[0], -1),
                         xd.reshape(xd.shape[0], -1)], axis=-1)
    return _mlp_apply(params, x, ("lrelu", "lrelu", "lrelu"))[..., 0]


def dnn_loss(params, xs, xd, y):
    y_hat = dnn_apply(params, xs, xd)
    mse = jnp.mean((y - y_hat) ** 2)
    return mse, (mse, jnp.zeros(()))


def bibe_schema(nf: int, w: int, ch: int = 48):
    """BIBE [12]: 1D-conv feature extractor over the (nf, w) tensors + MLP
    head.  Sized to roughly match the paper's ~132k parameter budget."""
    return {
        "conv1": ParamSpec((3, 2 * nf, ch), (None, None, None)),
        "b1": ParamSpec((ch,), (None,), init="zeros"),
        "conv2": ParamSpec((3, ch, ch), (None, None, None)),
        "b2": ParamSpec((ch,), (None,), init="zeros"),
        "mlp": _mlp_schema((ch, 256, 128, 1)),
    }


def _conv1d_same(x, w, b):
    """x: (B, L, Cin), w: (K, Cin, Cout)."""
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + b


def bibe_apply(params, xs, xd):
    x = jnp.concatenate([xs, xd], axis=1)          # (B, 2nf, w)
    x = x.swapaxes(1, 2)                           # (B, w, 2nf)
    h = jax.nn.leaky_relu(_conv1d_same(x, params["conv1"], params["b1"]),
                          LRELU_SLOPE)
    h = jax.nn.leaky_relu(_conv1d_same(h, params["conv2"], params["b2"]),
                          LRELU_SLOPE)
    h = jnp.mean(h, axis=1)                        # global average pool
    return _mlp_apply(params["mlp"], h, ("lrelu", "lrelu"))[..., 0]


def bibe_loss(params, xs, xd, y):
    y_hat = bibe_apply(params, xs, xd)
    mse = jnp.mean((y - y_hat) ** 2)
    return mse, (mse, jnp.zeros(()))


def bibe_pretrain_loss(params, xs, xd, rng):
    """BIBEP self-supervised pretraining: masked-window reconstruction — the
    conv trunk must predict the mean of the masked dense tensor half."""
    mask = jax.random.bernoulli(rng, 0.5, xd.shape).astype(xd.dtype)
    target = jnp.mean(xd * (1 - mask), axis=(1, 2))
    y_hat = bibe_apply(params, xs * mask, xd * mask)
    return jnp.mean((target - y_hat) ** 2)
