"""Client-sharded federation: the fused epoch across a device mesh.

The batched engine (``repro.core.federation._fit_batched``) stacks all C
clients' state on a leading axis and scans the whole epoch inside one jitted
dispatch.  This module runs that SAME epoch body under
:func:`jax.experimental.shard_map.shard_map` on a 1-D
:class:`jax.sharding.Mesh` with a ``clients`` axis, so the population is
*partitioned* across devices instead of living on one:

* **Device-local training.**  Per-client state (params, optimizer state,
  best-params, the epoch's R-batches, validation splits) is placed with a
  ``NamedSharding`` partitioning the leading client axis — derived from the
  ParamSpec schema via ``sharding.rules.FED_RULES``, which is what finally
  makes the schema-first sharding layer load-bearing for the federation
  path.  The vmapped Adam step and the per-epoch eval then run on each
  device's C/D-client block with no communication at all.

* **Explicit pool exchange, sharded scoring.**  The Eq.-7/Eq.-8 policy
  round is inherently sequential in the global client order (client i
  scores the heads already republished by clients < i in the same
  sub-round — the property that makes the batched engine
  selection-identical to the sequential oracle).  Each exchange round
  therefore ALL-GATHERS the pool candidates — the freshly trained heads
  plus that round's probe batches — along the ``clients`` axis (the probe
  gathers are issued before the train step so XLA may overlap them with
  its compute) and replays :func:`~repro.core.federation._policy_round_body`
  on the gathered view.  The sequential dependency lives in the pool
  CARRY, not in the scoring, so the expensive part — the Eq.-7 error
  matrix — is sharded: each device scores only its contiguous ``ns/D``
  chunk of the flattened pool against the scoring client's probes, takes
  a per-chunk argmin, and a tiny ``(D, nf)`` all-gather of (value, global
  index) pairs reduces to the global argmin
  (:func:`~repro.core.federation.merge_sharded_argmin` — ties to the
  LOWEST flat pool index, exactly ``jnp.argmin``'s first occurrence, the
  pinned tie-break rule).  Everything downstream of the argmin (blend,
  publish, aging, RNG fold-in) is O(pool) and runs replicated — same
  replicated PRNG key, same reduced index on every device — so the pool,
  its staleness ages, and the selection trace still end each round
  REPLICATED without a psum, and each device slices its own clients'
  blended heads back out.  Selection policies that need the full error
  matrix (not a pure argmin) all-gather their sharded chunks instead;
  policies that never score run replicated as before.  See docs/SCALING.md
  for the cost model (per-device O(C/D · pool) scoring + O(pool) gather
  replaces the old replicated O(C · pool) = O(C²) wall).

* **Bounded-staleness cadence.**  ``RoundSchedule(exchange_every=k)``
  exchanges on every k-th sub-round of an epoch (the segmented scan in
  ``federation._epoch_body``); intermediate rounds are pure local
  training — no gathers, no policy round, no pool aging.  k=1 is
  bit-identical to the historical per-sub-round exchange; k>1 rides the
  ``MaxStaleness`` PoolPolicy's bounded ages, which tick per EXCHANGE so
  ``max_age`` keeps its meaning in exchange rounds.  Per-epoch comms are
  accounted analytically in ``dispatch_stats["pool_bytes_gathered"]`` /
  ``["exchange_rounds"]``.

The mesh path is bit-compatible with the single-device engine: same scan
body, same key sequence, same selections (pinned by
``tests/test_mesh_federation.py`` both in-process and under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
:class:`~repro.core.federation.Federation` accepts ``mesh=`` and falls back
to the single-device path automatically when the mesh has one device.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import networks as N
from repro.core.policies import FederationPolicies
from repro.sharding import spec as S
from repro.sharding.rules import CLIENT_AXIS, FED_RULES


def make_mesh(axis_names=(CLIENT_AXIS,), devices=None) -> Mesh:
    """A 1-D device mesh for client-sharded federation.

    ``axis_names`` must be a 1-tuple naming the client axis (default
    ``("clients",)``, the name ``FED_RULES`` maps); ``devices`` defaults to
    every local device.  ``Federation(..., mesh=make_mesh())`` is the whole
    opt-in: with one device the engine falls back to the single-device
    fused path, with D devices the C clients are partitioned into C/D
    blocks (C must divide evenly — :func:`validate_mesh`).
    """
    if len(tuple(axis_names)) != 1:
        raise ValueError(
            f"client-sharded federation uses a 1-D mesh, got axes "
            f"{tuple(axis_names)} (shard other axes inside the model, not "
            f"across clients)")
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), tuple(axis_names))


def mesh_devices(mesh: Optional[Mesh]) -> int:
    """Device count of ``mesh`` (1 for None — the single-device path)."""
    return 1 if mesh is None else int(mesh.devices.size)


def client_axis(mesh: Mesh) -> str:
    """The mesh's client axis name (its only axis; validated)."""
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"client-sharded federation needs a 1-D mesh with a single "
            f"client axis; got axes {mesh.axis_names}")
    return mesh.axis_names[0]


def validate_mesh(mesh: Mesh, n_clients: int) -> None:
    """Raise unless ``mesh`` can host ``n_clients`` stacked clients: 1-D
    mesh, client count divisible by device count (each device owns a
    contiguous, equal block of clients — ragged blocks would silently
    change the all-gathered client order)."""
    client_axis(mesh)
    d = mesh_devices(mesh)
    if n_clients % d:
        raise ValueError(
            f"{n_clients} clients cannot shard evenly over {d} devices "
            f"(clients % devices must be 0); pad the population or use a "
            f"divisor-sized mesh")


def participation_multiple(mesh: Optional[Mesh]) -> int:
    """The granularity a sampled active set must respect on this mesh: the
    device count of a multi-device 1-D ``clients`` mesh, else 1.  The
    participation sampler rounds its per-wave counts to this multiple —
    and under a heterogeneous population the STRATIFIED sampler rounds
    each nf stratum to it, since every wave cohort must itself divide the
    device count (see :func:`validate_mesh` /
    ``cohorts.validate_cohort_mesh``)."""
    if mesh is None:
        return 1
    client_axis(mesh)
    return mesh_devices(mesh)


def param_pspecs(nf: int, w: int, n_clients: int, mesh: Mesh):
    """PartitionSpec tree for the stacked ``(C, ...)`` HFL parameter tree,
    derived from the ParamSpec schema: the per-client H/E/P schema is
    stacked on a logical ``clients`` axis and mapped through
    ``sharding.rules.FED_RULES`` — P(clients) on the leading axis of every
    leaf, everything else replicated."""
    schema = S.stack(N.hfl_schema(nf, w), n_clients,
                     axis_name=CLIENT_AXIS)
    rules = dict(FED_RULES)
    if client_axis(mesh) != CLIENT_AXIS:
        rules = {CLIENT_AXIS: client_axis(mesh)}
    return S.partition_specs(schema, rules, mesh)


def shard_fit_state(mesh: Mesh, nf: int, w: int, n_clients: int, *,
                    params, opt_state, pool_heads, pool_age, key,
                    best_val, best_params, rounds_data, val_data):
    """Place the batched engine's fit-state on the mesh and return it in the
    same order.  Per-client trees get the schema-derived client
    partitioning; the pool, its age vector and the PRNG key are replicated
    (every device carries the full pool — the policy round's invariant);
    the scan-stacked train data ``(n_sub, C, R, ...)`` partitions its
    SECOND axis."""
    validate_mesh(mesh, n_clients)
    axis = client_axis(mesh)
    pspecs = param_pspecs(nf, w, n_clients, mesh)
    named = lambda ps: NamedSharding(mesh, ps)
    clients_sh = named(P(axis))
    rep = named(P())
    params = jax.device_put(params, jax.tree_util.tree_map(named, pspecs))
    best_params = jax.device_put(
        best_params, jax.tree_util.tree_map(named, pspecs))
    opt_state = jax.device_put(opt_state, clients_sh)
    pool_heads = jax.device_put(pool_heads, rep)
    pool_age = jax.device_put(pool_age, rep)
    key = jax.device_put(key, rep)
    best_val = jax.device_put(best_val, clients_sh)
    rounds_data = tuple(jax.device_put(t, named(P(None, axis)))
                        for t in rounds_data)
    val_data = tuple(jax.device_put(t, clients_sh) for t in val_data)
    return (params, opt_state, pool_heads, pool_age, key, best_val,
            best_params, rounds_data, val_data)


def replicate(mesh: Mesh, x):
    """Put ``x`` on every device of ``mesh`` (the per-epoch activity mask)."""
    return jax.device_put(x, NamedSharding(mesh, P()))


@functools.lru_cache(maxsize=None)
def _make_mesh_epoch_fn(lr: float, nf: int, w: int,
                        policies: FederationPolicies, use_kernel: bool,
                        do_federate: bool, do_eval: bool, mesh: Mesh,
                        n_clients: int, exchange_every: int = 1,
                        admission=None, trust=None, telemetry=None):
    """Compile-cached client-sharded whole-epoch function — the mesh twin of
    ``federation._make_epoch_fn``: the SAME shared epoch computation
    (``federation._epoch_body``), same signature, same donation contract,
    wrapped in ``shard_map`` with the pool-exchange hooks injected:

    * train step + eval run on each device's local C/D-client block,
    * ``gather`` all-gathers (heads, probe batch) along the client axis so
      each exchange round replays the policy round on the global view
      (replicated PRNG key → identical computation on every device →
      the pool/ages/selections end the round replicated with no psum),
      ``shard=(axis, D)`` makes ``_policy_round_body`` score only each
      device's contiguous pool chunk and merge per-chunk argminima
      through a tiny (D, nf) gather, and ``local_rows`` slices the local
      clients' blended heads back out,
    * ``exchange_every`` segments the scan into k-round groups (see
      ``_epoch_body``) — the cadence is static, so every device traces the
      identical collective schedule (no ``lax.cond`` around collectives),
    * outputs: per-client values partitioned, pool/key/selections
      replicated.

    Cache key adds (w, mesh, n_clients, exchange_every) to the
    single-device key — the PartitionSpecs depend on the first three, and
    jit's per-shape cache sits underneath as before."""
    from repro.core.federation import _epoch_body

    axis = client_axis(mesh)
    c_loc = n_clients // mesh_devices(mesh)
    pspecs = param_pspecs(nf, w, n_clients, mesh)
    cl, rep, data = P(axis), P(), P(None, axis)

    def gather(tree):
        """Local client blocks -> the full (C, ...) tree in the global
        client order every device agrees on."""
        return jax.lax.all_gather(tree, axis, tiled=True)

    def local_rows(tree):
        """This device's C/D-client block of a gathered (C, ...) tree."""
        i0 = jax.lax.axis_index(axis) * c_loc
        return jax.tree_util.tree_map(
            lambda g: jax.lax.dynamic_slice_in_dim(g, i0, c_loc, 0), tree)

    epoch = _epoch_body(lr, nf, policies, use_kernel, do_federate, do_eval,
                        exchange_every=exchange_every, gather=gather,
                        local_rows=local_rows,
                        shard=(axis, mesh_devices(mesh)),
                        admission=admission, trust=trust,
                        telemetry=telemetry)
    out_specs = (pspecs, cl, rep, rep, rep, cl, pspecs,
                 cl if do_eval else None, rep)
    if admission is not None:
        # the admission guard's per-opportunity rejection mask is computed
        # from the replicated pool carry — replicated like the selections
        out_specs = out_specs + (rep,)
    in_specs = (pspecs, cl, rep, rep, rep, cl, pspecs,
                data, data, data, rep, cl, cl, cl)
    if trust is not None:
        # the trust layer's host-derived inputs (signature stack / mask
        # pair / dummy) and its per-round stats are replicated: the whole
        # publication tail runs inside the replicated policy round
        in_specs = in_specs + (rep,)
        out_specs = out_specs + (rep,)
    if telemetry is not None:
        # the in-graph per-round metrics series (selection histogram,
        # Eq.-7 score aggregates, staleness ages) is derived from the
        # replicated pool carry / psum-reduced sharded scores, so it comes
        # back replicated; a single ``rep`` covers the whole tuple (specs
        # are pytree prefixes, as for the trust stats pair above)
        out_specs = out_specs + (rep,)
    sharded = shard_map(
        epoch, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=False)
    return jax.jit(sharded, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
