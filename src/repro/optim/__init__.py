from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adam, adamw, apply_updates, clip_by_global_norm, chain, sgd,
    cosine_schedule, constant_schedule, warmup_cosine_schedule,
)
