"""Minimal optax-style optimizer library (optax is not installed offline).

An :class:`Optimizer` is an (init, update) pair over pytrees.  ``update``
returns (updates, new_state); apply with :func:`apply_updates`.  Composable
via :func:`chain`.  Schedules are plain callables step -> lr.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def _tree_zeros_like(params, dtype=None):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def f(step):
        t = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def warmup_cosine_schedule(lr: float, warmup: int, total_steps: int,
                           final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(1, total_steps - warmup), final_frac)
    def f(step):
        wu = lr * jnp.minimum(1.0, (step + 1) / max(1, warmup))
        return jnp.where(step < warmup, wu, cos(step - warmup))
    return f


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# Transforms
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        mom = _tree_zeros_like(params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params=None):
        step = state["step"]
        lr_t = sched(step)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mom"], grads)
            upd = jax.tree_util.tree_map(lambda m: -lr_t * m, mom)
            return upd, {"step": step + 1, "mom": mom}
        upd = jax.tree_util.tree_map(lambda g: -lr_t * g, grads)
        return upd, {"step": step + 1, "mom": None}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": _tree_zeros_like(params, jnp.float32),
            "v": _tree_zeros_like(params, jnp.float32),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = sched(state["step"])
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd_m(m, g):
            return b1 * m + (1 - b1) * g.astype(jnp.float32)

        def upd_v(v, g):
            g = g.astype(jnp.float32)
            return b2 * v + (1 - b2) * g * g

        m = jax.tree_util.tree_map(upd_m, state["m"], grads)
        v = jax.tree_util.tree_map(upd_v, state["v"], grads)

        def delta(m_, v_, p):
            d = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                d = d - lr_t * weight_decay * p.astype(jnp.float32)
            return d.astype(p.dtype)

        upd = jax.tree_util.tree_map(delta, m, v,
                                     params if params is not None else m)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params=None):
        leaves = jax.tree_util.tree_leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype),
                                      grads), state

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params=None):
        new_states = []
        upd = grads
        for o, s in zip(opts, state):
            upd, ns = o.update(upd, s, params)
            new_states.append(ns)
        return upd, tuple(new_states)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)
