"""Token pipelines for the architecture zoo.

Synthetic-but-structured corpora (offline container: no downloads):
  * text LMs: a Zipf-distributed Markov token stream with local n-gram
    structure, so cross-entropy has real signal to minimize;
  * VLM: token stream + stub patch embeddings (the ViT frontend carve-out)
    and M-RoPE position ids;
  * audio (musicgen): K parallel codebook streams with the delay pattern
    applied [arXiv:2306.05284].

Deterministic per (seed, step) => resumable without state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import VISION_DIM


@dataclasses.dataclass
class LMPipelineConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    n_patches: int = 64          # VLM image-prefix length (stub frontend)
    markov_order: int = 2


class TokenPipeline:
    """Markov-Zipf synthetic corpus."""

    def __init__(self, cfg: LMPipelineConfig, model_cfg: ModelConfig):
        self.cfg = cfg
        self.model_cfg = model_cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # sparse row-stochastic transition structure: each context hashes to a
        # small candidate set -> learnable bigram structure
        self._cands = rng.integers(0, V, size=(4096, 8))
        ranks = np.arange(1, V + 1)
        self._unigram = (1.0 / ranks ** 1.1)
        self._unigram /= self._unigram.sum()

    def _stream(self, rng: np.random.Generator, n: int) -> np.ndarray:
        V = self.cfg.vocab_size
        out = np.empty(n, np.int64)
        out[0] = rng.choice(V, p=self._unigram)
        for t in range(1, n):
            ctx = int(out[t - 1]) % 4096
            if rng.random() < 0.8:
                out[t] = self._cands[ctx][rng.integers(8)]
            else:
                out[t] = rng.choice(V, p=self._unigram)
        return out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, mc = self.cfg, self.model_cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch, cfg.seq_len
        if mc.n_codebooks > 1:
            return self._audio_batch(rng, B, S)
        tokens = np.stack([self._stream(rng, S) for _ in range(B)])
        out = {"tokens": tokens.astype(np.int32)}
        if mc.vlm:
            P = cfg.n_patches
            out["image_embeds"] = rng.normal(
                size=(B, P, VISION_DIM)).astype(np.float32)
            out["positions"] = self._mrope_positions(B, S, P)
        return out

    def _mrope_positions(self, B: int, S: int, P: int) -> np.ndarray:
        """Qwen2-VL M-RoPE ids: image patches get a (t=const, h, w) grid;
        text positions advance temporally after the image."""
        side = int(np.sqrt(P))
        pos = np.zeros((3, B, S), np.int32)
        hh, ww = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
        pos[1, :, :P] = np.resize(hh.ravel(), P)
        pos[2, :, :P] = np.resize(ww.ravel(), P)
        text = np.arange(S - P) + side
        pos[:, :, P:] = text[None, None, :]
        return pos

    def _audio_batch(self, rng, B, S):
        K = self.model_cfg.n_codebooks
        V = self.cfg.vocab_size
        base = np.stack([
            np.stack([self._stream(rng, S) for _ in range(K)])
            for _ in range(B)])                      # (B, K, S)
        # EnCodec delay pattern: codebook k shifted right by k
        delayed = np.zeros_like(base)
        for k in range(K):
            delayed[:, k, k:] = base[:, k, : S - k]
        return {"tokens": delayed.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
