"""Synthetic two-source sparse clinical time-series (the simulated MIMIC-III
gate — see DESIGN.md §7).

A shared latent physiological state z (OU process, irregular sampling) is
observed through *per-hospital* observation operators.  Hospital "carevue"
(source-rich) and hospital "metavision" (smaller target) expose DIFFERENT
feature channels with different scales/noise — heterogeneous feature spaces,
exactly the paper's setting (Table 3: e.g. 'SpO2' vs 'O2 saturation pulse
oximetry', 'Arterial BP' vs 'Non Invasive Blood Pressure').

At every tick exactly ONE channel is observed (paper §3's sparsity model),
channel frequencies mimic Table 3's record-count skew.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Sequence

import numpy as np

from repro.core.feature_tensors import EventStream, pack_feature_tensors

Z_DIM = 6

# (name, mean, std, latent weights, observation-frequency weight)
HOSPITALS = {
    "carevue": {
        "features": [
            ("heart_rate", 80.0, 14.0, (1.0, 0.3, 0.0, 0.0, 0.2, 0.0), 5.18),
            ("spo2", 96.5, 2.5, (0.0, -0.8, 0.4, 0.0, 0.0, 0.1), 3.42),
            ("resp_rate", 18.0, 4.5, (0.3, -0.5, 0.0, 0.6, 0.0, 0.0), 3.39),
            ("abp_sys", 122.0, 18.0, (0.5, 0.0, 0.9, 0.0, -0.2, 0.0), 2.10),
        ],
        "label": ("abp_dia", 64.0, 12.0, (0.4, 0.0, 0.8, 0.0, -0.3, 0.1), 2.09),
        "n_patients": 120,
    },
    "metavision": {
        "features": [
            ("heart_rate", 78.0, 13.0, (1.0, 0.25, 0.0, 0.0, 0.15, 0.0), 2.76),
            ("resp_rate", 18.5, 4.0, (0.3, -0.5, 0.0, 0.6, 0.0, 0.0), 2.74),
            ("o2_sat_pulse", 96.0, 2.8, (0.0, -0.8, 0.45, 0.0, 0.0, 0.1), 2.67),
            ("nibp_mean", 84.0, 13.0, (0.45, 0.0, 0.85, 0.0, -0.25, 0.05), 1.29),
        ],
        "label": ("nibp_sys", 118.0, 17.0, (0.5, 0.0, 0.9, 0.0, -0.2, 0.0), 1.29),
        "n_patients": 58,  # the smaller target domain
    },
}


@dataclasses.dataclass
class HospitalData:
    name: str
    feature_names: List[str]
    streams: List[EventStream]          # one per patient
    splits: Dict[str, List[int]]        # train/valid/test patient indices


def _ou_path(rng: np.random.Generator, times: np.ndarray) -> np.ndarray:
    """Ornstein-Uhlenbeck latent state sampled at irregular times."""
    theta, sigma = 0.08, 1.0
    z = np.zeros((len(times), Z_DIM), np.float64)
    z[0] = rng.normal(size=Z_DIM)
    for t in range(1, len(times)):
        dt = times[t] - times[t - 1]
        decay = np.exp(-theta * dt)
        var = (sigma ** 2) * (1 - decay ** 2) / (2 * theta)
        z[t] = z[t - 1] * decay + rng.normal(scale=np.sqrt(var), size=Z_DIM)
    return z


def make_patient(rng: np.random.Generator, hospital,
                 n_events: int, label_noise: float = 0.15) -> EventStream:
    """`hospital` is a name from HOSPITALS or a spec dict of the same shape
    (population hospitals are generated, not registered)."""
    spec = HOSPITALS[hospital] if isinstance(hospital, str) else hospital
    chans = spec["features"] + [spec["label"]]
    nf = len(spec["features"])
    freq = np.array([c[4] for c in chans])
    p = freq / freq.sum()
    gaps = rng.exponential(scale=1.0, size=n_events)
    times = np.cumsum(gaps)
    z = _ou_path(rng, times)
    channels = rng.choice(len(chans), size=n_events, p=p).astype(np.int32)
    values = np.empty(n_events, np.float32)
    for t in range(n_events):
        name, mu, sd, wz, _ = chans[channels[t]]
        wz = np.asarray(wz)
        sig = z[t] @ wz / max(1e-9, np.linalg.norm(wz))
        noise = label_noise if channels[t] == nf else 0.25
        values[t] = mu + sd * (0.9 * sig + noise * rng.normal())
    return EventStream(channels=channels, values=values,
                       times=times.astype(np.float32), nf=nf)


def make_hospital(hospital: str, seed: int = 0, n_patients: int = None,
                  n_events: int = 400) -> HospitalData:
    return make_hospital_from_spec(hospital, HOSPITALS[hospital], seed,
                                   n_patients, n_events)


def make_hospital_from_spec(name: str, spec: dict, seed: int = 0,
                            n_patients: int = None,
                            n_events: int = 400) -> HospitalData:
    # crc32, not hash(): str hashes are salted per process, which would make
    # "identical seed" runs train on different data across interpreter runs
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 100003)
    n = n_patients or spec["n_patients"]
    streams = [make_patient(rng, spec, n_events) for _ in range(n)]
    idx = rng.permutation(n)
    n_tr, n_va = int(0.6 * n), int(0.2 * n)
    splits = {"train": idx[:n_tr].tolist(),
              "valid": idx[n_tr:n_tr + n_va].tolist(),
              "test": idx[n_tr + n_va:].tolist()}
    return HospitalData(name, [c[0] for c in spec["features"]],
                        streams, splits)


# ---------------------------------------------------------------------------
# N-hospital populations (scaling beyond the paper's two-source setting)
# ---------------------------------------------------------------------------

# union of both paper hospitals' channel templates — population hospitals
# draw jittered variants of these, mimicking Table 3's near-synonymous
# channels ('SpO2' vs 'O2 saturation pulse oximetry', ...)
_CHANNEL_BANK = (HOSPITALS["carevue"]["features"]
                 + [HOSPITALS["carevue"]["label"]]
                 + HOSPITALS["metavision"]["features"]
                 + [HOSPITALS["metavision"]["label"]])


def population_spec(rng: np.random.Generator, nf: int = 4) -> dict:
    """One generated hospital: nf feature channels + 1 label channel, each a
    perturbed draw from the channel bank (different scales, noise, latent
    weights, observation frequencies — heterogeneous observation operators
    over the SAME latent physiology, exactly the paper's setting)."""
    n_chan = nf + 1
    replace = n_chan > len(_CHANNEL_BANK)
    picks = rng.choice(len(_CHANNEL_BANK), size=n_chan, replace=replace)
    chans = []
    for k, b in enumerate(picks):
        name, mu, sd, wz, freq = _CHANNEL_BANK[b]
        chans.append((
            f"{name}_v{k}",
            float(mu * (1 + 0.08 * rng.normal())),
            float(sd * abs(1 + 0.15 * rng.normal()) + 1e-3),
            tuple(np.asarray(wz, np.float64) + 0.1 * rng.normal(size=Z_DIM)),
            float(freq * np.exp(0.4 * rng.normal())),
        ))
    return {"features": chans[:nf], "label": chans[nf],
            # skewed domain sizes, echoing Table 3's carevue/metavision gap
            "n_patients": int(rng.integers(8, 25))}


def make_population(n_hospitals: int, seed: int = 0, nf: int = 4,
                    n_patients: int = None,
                    n_events: int = 300) -> List[HospitalData]:
    """Generate an N-hospital federated population.  Every hospital observes
    the shared OU latent state through its own generated observation operator
    (population_spec).  `n_patients=None` keeps the skewed per-hospital
    sizes; an int forces equal sizes (what the batched engine wants)."""
    rng = np.random.default_rng(seed)
    out = []
    for h in range(n_hospitals):
        spec = population_spec(rng, nf)
        out.append(make_hospital_from_spec(
            f"h{h:03d}", spec, seed=seed + 7919 * (h + 1),
            n_patients=n_patients, n_events=n_events))
    return out


def make_hetero_population(n_hospitals: int, seed: int = 0,
                           nf_choices: Sequence[int] = (3, 4, 5),
                           n_patients: int = None,
                           n_events: int = 300) -> List[HospitalData]:
    """Generate a *heterogeneous* N-hospital federated population: every
    hospital observes the shared OU latent state, but draws its feature
    COUNT from ``nf_choices`` as well as its observation operator — mixed
    feature spaces across hospitals, the paper's setting at population
    scale (the cohort engine's natural workload).

    Hospitals cycle deterministically through ``nf_choices`` (hospital h
    gets ``nf_choices[h % len(nf_choices)]``) so every nf group is
    populated evenly — callers that need cohort sizes divisible by a mesh
    device count can size ``n_hospitals`` as a multiple of
    ``len(nf_choices) * devices``.  ``n_patients=None`` keeps the skewed
    per-hospital sizes (fully ragged split lengths); an int forces equal
    patient counts (split lengths still vary with each hospital's label
    frequency — group-truncate per nf for stackable cohorts, see
    ``experiment.hetero_population_task_data``)."""
    rng = np.random.default_rng(seed)
    nf_choices = tuple(int(x) for x in nf_choices)
    if not nf_choices or any(x < 1 for x in nf_choices):
        raise ValueError(f"nf_choices must be positive ints, "
                         f"got {nf_choices}")
    out = []
    for h in range(n_hospitals):
        spec = population_spec(rng, nf_choices[h % len(nf_choices)])
        out.append(make_hospital_from_spec(
            f"h{h:03d}", spec, seed=seed + 7919 * (h + 1),
            n_patients=n_patients, n_events=n_events))
    return out


def population_spec_at(seed: int, h: int, nf: int = 4) -> dict:
    """Index-addressable population spec: hospital ``h``'s observation
    operator as a pure function of ``(seed, h)``.

    ``make_population`` draws specs *sequentially* from one generator, so
    materializing hospital h requires replaying draws 0..h-1 — fine for
    dozens of hospitals, disqualifying for the 10⁴–10⁶-client populations
    the participation subsystem samples from.  Here each index gets its own
    ``SeedSequence([seed, h])``-derived stream, so any subset of a
    100k-hospital population can be built without touching the rest.  The
    two families draw from the same channel bank but are NOT bit-equal for
    a given (seed, h)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, h]))
    return population_spec(rng, nf)


def make_hospital_at(seed: int, h: int, nf: int = 4,
                     n_patients: int = None,
                     n_events: int = 300) -> HospitalData:
    """Materialize ONE hospital of the index-addressable population —
    deterministic in ``(seed, h, nf, n_patients, n_events)`` alone, so a
    participation wave can build exactly its sampled subset.  Names carry
    six digits (``h000042``) to keep 100k-client populations sortable."""
    spec = population_spec_at(seed, h, nf)
    return make_hospital_from_spec(f"h{h:06d}", spec,
                                   seed=seed + 7919 * (h + 1),
                                   n_patients=n_patients, n_events=n_events)


def population_sizes_at(seed: int, indices: Sequence[int],
                        nfs: Sequence[int] = None) -> np.ndarray:
    """Declared patient counts for the given population indices (the
    ``n_patients`` field of each ``population_spec_at``) without packing any
    data — the weighted participation sampler's size metadata.  ``nfs``
    gives each index's feature count (the spec stream consumes nf+1 channel
    draws before the size draw, so size depends on nf); defaults to 4."""
    if nfs is None:
        nfs = [4] * len(indices)
    return np.array([population_spec_at(seed, int(h), int(nf))["n_patients"]
                     for h, nf in zip(indices, nfs)], dtype=np.int64)


def packed_split(data: HospitalData, split: str, w: int):
    """Concatenate packed tensors over a patient split.
    Returns (X_sparse, X_dense, y) float32 arrays."""
    xs, xd, ys = [], [], []
    for i in data.splits[split]:
        a, b, c = pack_feature_tensors(data.streams[i], w)
        xs.append(a)
        xd.append(b)
        ys.append(c)
    return (np.concatenate(xs), np.concatenate(xd), np.concatenate(ys))


def relabel(stream: EventStream, label_channel: int) -> EventStream:
    """Swap the label role to a different channel (the paper predicts each of
    the five channels in turn: use [CF1..CF4]->CF5, [CF1..CF3,CF5]->CF4, ...).
    Channel ids are remapped so features stay 0..nf-1 and label = nf."""
    nf = stream.nf
    old_label = nf
    mapping = {}
    nxt = 0
    for c in range(nf + 1):
        if c == label_channel:
            mapping[c] = nf
        else:
            mapping[c] = nxt
            nxt += 1
    # old label becomes an ordinary feature unless it IS the chosen label
    channels = np.array([mapping[c] for c in stream.channels], np.int32)
    return EventStream(channels=channels, values=stream.values,
                       times=stream.times, nf=nf)
