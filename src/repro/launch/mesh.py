"""Production mesh construction.

Functions, not module-level constants, so importing this module never touches
jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips) mesh.

    The ``pod`` axis is the federated-client axis of the HFL system: each pod
    is one hospital/client; parameters replicate across it and only the HFL
    head-pool blend communicates over it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real local devices (tests / smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh((data, max(1, min(model, n // data))), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
