import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may import jax.
"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes and record memory / cost / collective statistics.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]

Single-pod mesh: (data=16, model=16) = 256 chips.
Multi-pod mesh:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
HFL federated-client axis: train shapes lower the 2-client
`make_hfl_train_step` (per-client grads, NO cross-pod gradient all-reduce);
decode shapes shard the request batch (or the KV cache for batch=1) over
pod x data.

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed the
roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md §Roofline).
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.sharding import spec as S

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=")
SHAPE_RE = re.compile(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand sizes of every collective op in the HLO module."""
    per_kind = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # result shapes: everything between '=' and the op name; handles
        # tuple results "= (f32[..], f32[..]) all-gather-start("
        rhs = line.split("=", 1)[1]
        rhs = rhs.split(kind)[0]
        nbytes = 0
        for dt, dims in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", rhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        if nbytes:
            per_kind[kind] = per_kind.get(kind, 0) + nbytes
    per_kind["total"] = sum(per_kind.values())
    return per_kind


def _first_cost(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca) if ca else {}


def named(tree, mesh):
    """PartitionSpec tree -> NamedSharding tree (None leaves pass through)."""
    return jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s) if isinstance(s, P) else s,
        tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def lower_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True):
    cfg = steps.effective_config(get_config(arch), INPUT_SHAPES[shape_name])
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_clients = 2 if (multi_pod and shape.kind == "train") else 1
    opt = steps.default_optimizer()
    # mesh-aware model paths: padded-head sharding constraints (§Perf D2)
    mm = mesh if (cfg.attn is not None and cfg.attn.n_heads_padded) else None
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step_fn = (steps.make_hfl_train_step(cfg, opt, moe_mesh=mm)
                       if n_clients > 1
                       else steps.make_train_step(cfg, opt, moe_mesh=mm))
            state = steps.abstract_state(cfg, opt, n_clients=n_clients)
            st_specs = named(steps.state_pspecs(cfg, opt, mesh,
                                                n_clients=n_clients), mesh)
            batch = steps.batch_spec(cfg, shape, n_clients=n_clients)
            b_specs = named(steps.batch_pspecs(cfg, shape, mesh,
                                               n_clients=n_clients), mesh)
            lowered = jax.jit(step_fn,
                              in_shardings=(st_specs, b_specs),
                              out_shardings=(st_specs, None)).lower(state, batch)
        elif shape.kind == "prefill":
            fn = steps.make_prefill_step(cfg, moe_mesh=mm)
            p_specs, schema = steps.param_pspecs(cfg, mesh)
            p_specs = named(p_specs, mesh)
            params = S.abstract(schema)
            batch = steps.batch_spec(cfg, shape)
            b_specs = named(steps.batch_pspecs(cfg, shape, mesh), mesh)
            lowered = jax.jit(fn, in_shardings=(p_specs, b_specs),
                              out_shardings=None).lower(params, batch)
        else:  # decode
            fn = steps.make_serve_step(cfg, shape.seq_len)
            p_specs, schema = steps.param_pspecs(cfg, mesh)
            p_specs = named(p_specs, mesh)
            params = S.abstract(schema)
            cache, tokens, pos = steps.decode_inputs_spec(cfg, shape)
            c_specs = named(steps.cache_pspecs(cfg, shape, mesh), mesh)
            scalar = jax.NamedSharding(mesh, P())
            lowered = jax.jit(
                fn, in_shardings=(p_specs, c_specs, scalar, scalar),
                out_shardings=(None, c_specs)).lower(params, cache, tokens, pos)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = _first_cost(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "n_clients": n_clients,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(mem, "generated_code_size_in_bytes",
                                           None),
        },
        "hlo_collective_ops": len(COLLECTIVE_RE.findall(hlo)),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {result['mesh']}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"flops={result['flops']:.3e} bytes={result['bytes_accessed']:.3e} "
              f"coll={coll.get('total', 0):.3e}B", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
    return result


def lower_blend(arch: str, verbose: bool = True):
    """Lower the HFL blend/selection step (repro.core.hfl_llm) on the
    multi-pod mesh: 2 federated clients on the `pod` axis exchanging ONLY the
    shared subtree (Eq. 7 scoring + Eq. 8 blend)."""
    from repro.core.hfl_llm import make_blend_step, shared_fraction
    from repro.models.model import model_schema

    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    mesh = make_production_mesh(multi_pod=True)
    blend = make_blend_step(cfg)
    t0 = time.time()
    with mesh:
        p_specs, schema = steps.param_pspecs(cfg, mesh, n_clients=2)
        params = S.abstract(S.stack(model_schema(cfg), 2,
                                    axis_name="clients"))
        b_specs = named(steps.batch_pspecs(cfg, shape, mesh, n_clients=2), mesh)
        batch = steps.batch_spec(cfg, shape, n_clients=2)
        p_named = named(p_specs, mesh)
        lowered = jax.jit(blend, in_shardings=(p_named, b_specs),
                          out_shardings=(p_named, None)).lower(params, batch)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    cost = _first_cost(compiled)
    res = {
        "arch": arch, "shape": "train_4k", "mesh": "2x16x16",
        "kind": "hfl_blend", "n_chips": mesh.devices.size,
        "shared_fraction": shared_fraction(cfg),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll,
        "compile_s": round(time.time() - t0, 2),
    }
    if verbose:
        print(f"[dryrun] BLEND {arch}: shared={res['shared_fraction']:.3f} "
              f"coll={coll.get('total', 0):.3e}B flops={res['flops']:.3e}",
              flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--blend", action="store_true",
                    help="lower the HFL blend step instead of train/serve")
    args = ap.parse_args()

    if args.blend:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        archs = list_archs() if args.all or not args.arch else [args.arch]
        fails = []
        for arch in archs:
            out = OUT_DIR / f"{arch}__blend__2x16x16.json"
            if args.skip_existing and out.exists():
                continue
            try:
                out.write_text(json.dumps(lower_blend(arch), indent=1))
            except Exception as e:  # noqa: BLE001
                print(f"[dryrun] BLEND FAIL {arch}: {e}", flush=True)
                traceback.print_exc()
                fails.append(arch)
        sys.exit(1 if fails else 0)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only or (args.multi_pod and not args.all):
        meshes = [True]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                out = OUT_DIR / f"{tag}.json"
                if args.skip_existing and out.exists():
                    print(f"[dryrun] skip {tag} (exists)", flush=True)
                    continue
                try:
                    res = lower_one(arch, shape, mp)
                    out.write_text(json.dumps(res, indent=1))
                except Exception as e:  # noqa: BLE001
                    print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}",
                          flush=True)
                    traceback.print_exc()
                    failures.append(tag)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}", flush=True)
        sys.exit(1)
    print("[dryrun] all combinations lowered + compiled OK", flush=True)


if __name__ == "__main__":
    main()
