"""Train / serve step factories + input specs + sharding trees.

Used both by real training (examples, smoke tests) and by the multi-pod
dry-run (everything here works on ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.optim import Optimizer, adamw, apply_updates, chain, clip_by_global_norm
from repro.sharding import rules as R
from repro.sharding import spec as S

N_PATCHES = 256  # VLM stub: image-prefix length supplied by the frontend stub


# ---------------------------------------------------------------------------
# Effective config per input shape
# ---------------------------------------------------------------------------

def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k: full-attention layers get the sliding-window override so
    decode cost/cache are O(window), not O(524k).  Native sub-quadratic archs
    (ssm / hybrid local-attn) are untouched.  See DESIGN.md §5."""
    if shape.name == "long_500k" and cfg.attn is not None:
        if cfg.attn.window is None and cfg.long_ctx_window is not None:
            return dataclasses.replace(
                cfg, attn=dataclasses.replace(cfg.attn,
                                              window=cfg.long_ctx_window))
    return cfg


# ---------------------------------------------------------------------------
# Input specs (abstract stand-ins; the dry-run contract)
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, shape: InputShape,
               n_clients: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one training/prefill batch."""
    B, Sq = shape.global_batch, shape.seq_len
    lead = (n_clients,) if n_clients > 1 else ()
    sds = jax.ShapeDtypeStruct
    if n_clients > 1:
        assert B % n_clients == 0
        B = B // n_clients
    out: Dict[str, Any] = {}
    if cfg.n_codebooks > 1:
        out["tokens"] = sds(lead + (B, cfg.n_codebooks, Sq), jnp.int32)
    else:
        out["tokens"] = sds(lead + (B, Sq), jnp.int32)
    if cfg.vlm:
        out["image_embeds"] = sds(lead + (B, N_PATCHES, M.VISION_DIM),
                                  jnp.float32)
        out["positions"] = sds((3,) + lead + (B, Sq), jnp.int32)
    return out


def decode_inputs_spec(cfg: ModelConfig, shape: InputShape,
                       kv_quant: bool = False):
    """(cache, tokens, pos) abstract inputs for serve_step."""
    B, L = shape.global_batch, shape.seq_len
    cache = S.abstract(M.cache_schema(cfg, B, L, jnp.bfloat16,
                                      kv_quant=kv_quant))
    sds = jax.ShapeDtypeStruct
    if cfg.n_codebooks > 1:
        tokens = sds((B, cfg.n_codebooks, 1), jnp.int32)
    else:
        tokens = sds((B, 1), jnp.int32)
    pos = sds((), jnp.int32)
    return cache, tokens, pos


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------

def _mesh_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _batch_assign(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_pspecs(cfg: ModelConfig, mesh, n_clients: int = 1):
    rules = dict(R.PARAM_RULES_FSDP if cfg.fsdp else R.PARAM_RULES)
    schema = M.model_schema(cfg)
    if n_clients > 1:
        schema = S.stack(schema, n_clients, axis_name="clients")
        rules["clients"] = "pod"
    return S.partition_specs(schema, rules, mesh), schema


def batch_pspecs(cfg: ModelConfig, shape: InputShape, mesh,
                 n_clients: int = 1):
    """PartitionSpecs matching batch_spec structure."""
    sizes = _mesh_sizes(mesh)
    if n_clients > 1:
        lead: Tuple = ("pod",)
        per_client = shape.global_batch // n_clients
        bassign = "data" if per_client % sizes.get("data", 1) == 0 else None
    else:
        lead = ()
        axes = _batch_assign(mesh)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        if shape.global_batch % total == 0:
            bassign = axes if len(axes) > 1 else axes[0]
        elif shape.global_batch % sizes.get("data", 1) == 0:
            bassign = "data"
        else:
            bassign = None
    out = {}
    if cfg.n_codebooks > 1:
        out["tokens"] = P(*lead, bassign, None, None)
    else:
        out["tokens"] = P(*lead, bassign, None)
    if cfg.vlm:
        out["image_embeds"] = P(*lead, bassign, None, None)
        out["positions"] = P(None, *lead, bassign, None)
    return out


def cache_pspecs(cfg: ModelConfig, shape: InputShape, mesh,
                 kv_quant: bool = False):
    sizes = _mesh_sizes(mesh)
    batch_axes = _batch_assign(mesh)
    total = 1
    for a in batch_axes:
        total *= sizes.get(a, 1)
    kv_eff = (cfg.attn.n_kv_heads_padded or cfg.attn.n_kv_heads) \
        if cfg.attn is not None else 1
    kv_shardable = (cfg.attn is not None and cfg.attn.mla is None and
                    kv_eff % max(1, sizes.get("model", 1)) == 0)
    if shape.global_batch >= total and shape.global_batch % total == 0:
        rules = dict(R.ACT_RULES_BATCH,
                     batch=batch_axes if len(batch_axes) > 1 else batch_axes[0])
        if not kv_shardable:
            # kv_heads won't divide the model axis: shard the cache sequence
            # over `model` instead (flash-decode style partial softmax) so the
            # KV cache never replicates across the model group.
            rules["cache"] = "model"
            rules["kv_heads"] = None
    else:
        # batch too small to fill the batch axes: shard cache sequence over
        # them (long-context mode); kv_heads may still take `model`.
        rules = dict(R.ACT_RULES_SEQ,
                     cache=batch_axes if len(batch_axes) > 1 else batch_axes[0])
        if not kv_shardable:
            rules["kv_heads"] = None
    schema = M.cache_schema(cfg, shape.global_batch, shape.seq_len,
                            jnp.bfloat16, kv_quant=kv_quant)
    return S.partition_specs(schema, rules, mesh)


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

def default_optimizer(lr: float = 3e-4) -> Optimizer:
    return chain(clip_by_global_norm(1.0), adamw(lr))


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    use_kernels: bool = False, dtype=jnp.bfloat16,
                    unroll: bool = False, moe_mesh=None):
    def train_step(state, batch):
        def loss_fn(params):
            return M.lm_loss(params, cfg, batch, use_kernels=use_kernels,
                             dtype=dtype, unroll=unroll, moe_mesh=moe_mesh)

        (total, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        updates, opt_state = optimizer.update(grads, state["opt"],
                                              state["params"])
        params = apply_updates(state["params"], updates)
        metrics = {"total": total, **parts, "step": state["step"] + 1}
        return {"params": params, "opt": opt_state,
                "step": state["step"] + 1}, metrics

    return train_step


def make_hfl_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                        use_kernels: bool = False, dtype=jnp.bfloat16,
                        moe_mesh=None):
    """Multi-client federated step: state carries a leading `clients` dim
    (sharded over the `pod` mesh axis).  Each client computes grads on its own
    batch and updates its own replica — NO gradient all-reduce across pods;
    clients only communicate in the HFL blend step (repro.core.hfl)."""

    def one_client(params, opt_state, step, batch):
        def loss_fn(p):
            return M.lm_loss(p, cfg, batch, use_kernels=use_kernels,
                             dtype=dtype, moe_mesh=moe_mesh)

        (total, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, total, parts["loss"]

    def train_step(state, batch):
        # positions for M-RoPE are (3, C, B, S): client dim on axis 1
        baxes = {k: (1 if k == "positions" else 0) for k in batch}
        n_clients = batch["tokens"].shape[0]
        params, opt, total, loss = jax.vmap(
            one_client, in_axes=(0, 0, 0, baxes))(
            state["params"], state["opt"],
            jnp.broadcast_to(state["step"], (n_clients,)), batch)
        metrics = {"total": total, "loss": loss, "step": state["step"] + 1}
        return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, use_kernels: bool = False,
                      dtype=jnp.bfloat16, unroll: bool = False,
                      moe_mesh=None):
    def prefill(params, batch):
        h, _ = M.forward(params, cfg, batch, use_kernels=use_kernels,
                         dtype=dtype, unroll=unroll, moe_mesh=moe_mesh)
        return M.output_logits(params, cfg, h)

    return prefill


def make_serve_step(cfg: ModelConfig, cache_len: int, *, dtype=jnp.bfloat16,
                    unroll: bool = False):
    def serve_step(params, cache, tokens, pos):
        return M.decode_step(params, cfg, cache, tokens, pos,
                             cache_len=cache_len, dtype=dtype, unroll=unroll)

    return serve_step


# ---------------------------------------------------------------------------
# State init
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, optimizer: Optimizer, rng,
               n_clients: int = 1, param_dtype=jnp.float32):
    schema = M.model_schema(cfg)
    if n_clients > 1:
        params = [S.materialize(schema, jax.random.fold_in(rng, c),
                                dtype_override=param_dtype)
                  for c in range(n_clients)]
        params = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *params)
        opt = jax.vmap(optimizer.init)(params)
    else:
        params = S.materialize(schema, rng, dtype_override=param_dtype)
        opt = optimizer.init(params)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def abstract_state(cfg: ModelConfig, optimizer: Optimizer, n_clients: int = 1):
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    schema = M.model_schema(cfg)
    if n_clients > 1:
        schema = S.stack(schema, n_clients, axis_name="clients")
    params = S.abstract(schema)
    init = jax.vmap(optimizer.init) if n_clients > 1 else optimizer.init
    opt = jax.eval_shape(init, params)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params, "opt": opt, "step": step}


def state_pspecs(cfg: ModelConfig, optimizer: Optimizer, mesh,
                 n_clients: int = 1):
    p_pspecs, schema = param_pspecs(cfg, mesh, n_clients)
    abs_params = S.abstract(schema)
    init = jax.vmap(optimizer.init) if n_clients > 1 else optimizer.init
    abs_opt = jax.eval_shape(init, abs_params)
    params_struct = jax.tree_util.tree_structure(abs_params)

    def mirror(node):
        try:
            if jax.tree_util.tree_structure(node) == params_struct:
                return p_pspecs
        except Exception:
            pass
        if isinstance(node, dict):
            return {k: mirror(v) for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            return type(node)(mirror(v) for v in node)
        if node is None:
            return None
        return P()

    return {"params": p_pspecs, "opt": mirror(abs_opt), "step": P()}
