"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --federated --clients 2 --R 20

Runs on the local devices (CPU in this container); the production-mesh
lowering of the same step functions is exercised by launch/dryrun.py.
`--federated` trains N HFL clients: independent updates + plateau-gated
Eq.7/Eq.8 blend of the shared subtree (repro.core.hfl_llm).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config, list_archs, smoke_config
from repro.core.hfl_llm import make_blend_step
from repro.data.lm_pipeline import LMPipelineConfig, TokenPipeline
from repro.launch import steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--federated", action="store_true")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--R", type=int, default=20)
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt = steps.default_optimizer(args.lr)
    C = args.clients if args.federated else 1
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0), n_clients=C)
    pipes = [TokenPipeline(LMPipelineConfig(batch=args.batch, seq_len=args.seq,
                                            vocab_size=cfg.vocab_size,
                                            seed=100 + c,
                                            n_patches=8), cfg)
             for c in range(C)]

    if args.federated:
        train_step = jax.jit(steps.make_hfl_train_step(cfg, opt))
        blend = jax.jit(make_blend_step(cfg, alpha=args.alpha))
    else:
        train_step = jax.jit(steps.make_train_step(cfg, opt))
    mgr = CheckpointManager(args.ckpt) if args.ckpt else None

    t0 = time.time()
    batch = None
    for step in range(args.steps):
        raw = [pipes[c].batch_at(step) for c in range(C)]
        if args.federated:
            batch = {k: jnp.stack([jnp.asarray(r[k]) for r in raw])
                     for k in raw[0]}
        else:
            batch = {k: jnp.asarray(v) for k, v in raw[0].items()}
        state, metrics = train_step(state, batch)
        if args.federated and (step + 1) % args.R == 0:
            state = dict(state)
            state["params"], losses = blend(state["params"], batch)
            print(f"  [blend @ {step + 1}] selection losses:\n{losses}")
        if (step + 1) % args.log_every == 0:
            loss = metrics["loss"]
            loss = [round(float(x), 4) for x in jnp.atleast_1d(loss)]
            print(f"step {step + 1:5d} loss={loss} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)", flush=True)
        if mgr and (step + 1) % 100 == 0:
            mgr.save_step(step + 1, state)
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
