"""Batched serving engine: prefill + KV-cache decode with sampling.

Production lowering of `serve_step` (sharded cache, cache donation) is in
launch/dryrun.py; this engine is the host-side request loop used by
examples/serve_batched.py and the serving tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import steps
from repro.models import model as M


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 64
    temperature: float = 0.8
    top_k: Optional[int] = None
    seed: int = 0


class ServingEngine:
    """Holds compiled prefill/decode functions + the ring-buffered cache."""

    def __init__(self, cfg: ModelConfig, params, cache_len: int,
                 dtype=jnp.bfloat16):
        self.cfg, self.params, self.cache_len = cfg, params, cache_len
        self.dtype = dtype
        # cache donation: the update happens in place (EXPERIMENTS §Perf B3)
        self._step = jax.jit(steps.make_serve_step(cfg, cache_len,
                                                   dtype=dtype),
                             donate_argnums=(1,))

    def new_cache(self, batch: int):
        return M.init_cache(self.cfg, batch, self.cache_len, self.dtype)

    def prefill(self, cache, prompts):
        """prompts: (B, P) or (B, K, P).  Returns (last_logits, cache, P)."""
        P = prompts.shape[-1]
        logits = None
        for t in range(P):
            logits, cache = self._step(self.params, cache,
                                       prompts[..., t:t + 1], jnp.int32(t))
        return logits, cache, P

    def _sample(self, logits, key, gen: GenerationConfig):
        x = logits.astype(jnp.float32) / max(1e-6, gen.temperature)
        if gen.top_k:
            thresh = jnp.sort(x, axis=-1)[..., -gen.top_k][..., None]
            x = jnp.where(x < thresh, -jnp.inf, x)
        return jax.random.categorical(key, x, axis=-1)

    def generate(self, prompts, gen: GenerationConfig):
        """Batched autoregressive generation.  Returns (B, max_new_tokens)
        (or (B, K, T) for multi-codebook models)."""
        cache = self.new_cache(prompts.shape[0])
        logits, cache, P = self.prefill(cache, prompts)
        key = jax.random.PRNGKey(gen.seed)
        cur = prompts[..., -1:]
        outs = []
        for t in range(P, P + gen.max_new_tokens):
            logits, cache = self._step(self.params, cache, cur, jnp.int32(t))
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub, gen)
            cur = nxt.swapaxes(1, 2) if self.cfg.n_codebooks > 1 else nxt
            outs.append(cur)
        return jnp.concatenate(outs, axis=-1)
