"""Logical-axis -> mesh-axis sharding rules.

Single source of truth for how tensors shard onto the production meshes.
``pod`` is the federated-client axis: parameters are *replicated* across it
(each pod is an HFL client with its own replica); only the HFL blend step
communicates across pods.

The federation engine's client-sharded execution (``FED_RULES``) is the
small-model dual of the pod axis: the whole stacked-client state of the
batched HFL engine is *partitioned* over a 1-D ``clients`` mesh axis —
each device owns a contiguous block of hospitals — while everything inside
one client (its tiny H/E/P network) stays replicated-per-client, i.e.
device-local.  See ``repro.core.mesh_federation`` and docs/SCALING.md.
"""
from __future__ import annotations

from typing import Dict, Mapping, Tuple, Union

Rules = Dict[str, Union[str, Tuple[str, ...]]]

# Name of the federated-client mesh axis AND of the logical leading axis the
# batched engine stacks per-client state on (repro.sharding.spec.stack with
# axis_name=CLIENT_AXIS); keeping them equal makes FED_RULES the identity on
# the one axis that shards.
CLIENT_AXIS = "clients"

# Federation rules: the stacked per-client leading axis partitions over the
# mesh's `clients` axis; every other logical axis (head width, feature
# count, MLP dims) is absent from the mapping and therefore replicated —
# one hospital's model is a few KB, partitioning *within* a client would be
# pure collective overhead.
FED_RULES: Rules = {CLIENT_AXIS: CLIENT_AXIS}

# Parameter rules: tensor-parallel over "model"; experts expert-parallel.
PARAM_RULES: Rules = {
    "vocab": "model",
    "heads": "model",        # attention query heads
    "kv_heads": "model",     # dropped automatically when not divisible
    "ffn": "model",
    "experts": "model",
    "rnn": "model",          # RG-LRU / xLSTM recurrent width
    "codebooks": None,
    "embed": None,
    "layers": None,
}

# FSDP-style variant used by very large configs (deepseek-v3): experts spread
# over BOTH data and model axes.  NOTE (Perf iter A2): an earlier version also
# sharded the `embed` dim of 2D weights over "data" (ZeRO-3 style); that made
# every embedding lookup / logits matmul column-sharded against batch-sharded
# activations, and GSPMD fell back to full rematerialization — ~230 GB/step of
# batch all-gathers at DeepSeek scale.  Weight-gather ZeRO is reintroduced
# selectively via the ffn dimension only.
PARAM_RULES_FSDP: Rules = dict(
    PARAM_RULES,
    experts=("data", "model"),
    ffn=("model",),
)

# Activation rules (training / prefill): batch over data, heads over model.
ACT_RULES: Rules = {
    "batch": "data",
    "seq": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "embed": None,
    "experts": "model",
    "rnn": "model",
}

# Long-context decode (batch too small to fill "data"): shard the KV cache
# sequence dimension over the data axis instead (flash-decode style).
ACT_RULES_SEQ: Rules = dict(ACT_RULES, batch=None, cache="data")
ACT_RULES_BATCH: Rules = dict(ACT_RULES, cache=None)


def act_rules_for(shape_name: str, global_batch: int, data_axis: int) -> Rules:
    if global_batch >= data_axis:
        return ACT_RULES_BATCH
    return ACT_RULES_SEQ
