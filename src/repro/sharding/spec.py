"""Parameter schema machinery.

Models in this framework are *schemas first*: a pytree of :class:`ParamSpec`
leaves describing shape, logical axes, and initializer.  From one schema we
derive
  * materialized parameters  (``materialize``)      -- real training,
  * abstract parameters      (``abstract``)          -- dry-run lowering,
  * PartitionSpecs           (``partition_specs``)   -- pjit shardings,
without ever duplicating shape logic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Logical = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""

    shape: Tuple[int, ...]
    logical: Logical
    init: str = "fan_in"  # fan_in | normal | zeros | ones | constant | embed
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(
                f"shape {self.shape} and logical axes {self.logical} rank mismatch")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.scale, spec.dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
    if spec.init == "fan_in":
        # truncated-normal with stddev 1/sqrt(fan_in); fan_in = prod of all but last dim
        fan_in = max(1, int(np.prod(spec.shape[:-1])) if len(spec.shape) > 1 else spec.shape[0])
        std = spec.scale / np.sqrt(fan_in)
        return (std * jax.random.truncated_normal(key, -2.0, 2.0, spec.shape)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _tree_paths_and_leaves(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)
    return flat, treedef


def materialize(schema, rng: jax.Array, dtype_override=None):
    """Instantiate a schema pytree into real arrays (deterministic per path)."""
    flat, treedef = _tree_paths_and_leaves(schema)
    leaves = []
    for path, spec in flat:
        assert is_spec(spec), f"non-spec leaf at {path}: {spec}"
        key = jax.random.fold_in(rng, _path_hash(path))
        arr = _init_leaf(spec, key)
        if dtype_override is not None and jnp.issubdtype(arr.dtype, jnp.floating):
            arr = arr.astype(dtype_override)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _path_hash(path) -> int:
    s = jax.tree_util.keystr(path)
    h = 2166136261
    for ch in s:
        h = ((h ^ ord(ch)) * 16777619) & 0x7FFFFFFF
    return h


def abstract(schema, dtype_override=None):
    """Schema -> pytree of ShapeDtypeStruct (zero allocation, for .lower())."""

    def leaf(spec: ParamSpec):
        dt = spec.dtype
        if dtype_override is not None and jnp.issubdtype(jnp.dtype(dt), jnp.floating):
            dt = dtype_override
        return jax.ShapeDtypeStruct(spec.shape, dt)

    return jax.tree_util.tree_map(leaf, schema, is_leaf=is_spec)


def logical_to_pspec(shape: Sequence[int], logical: Logical,
                     rules: Mapping[str, Union[str, Tuple[str, ...]]],
                     mesh_axis_sizes: Mapping[str, int]) -> P:
    """Map logical axes to mesh axes, dropping any non-divisible assignment.

    ``rules`` maps a logical axis name to a mesh axis name (or tuple of mesh
    axis names for multi-axis sharding).  An assignment is kept only when the
    dimension size divides evenly by the product of the mesh axis sizes —
    otherwise that dimension is replicated.  Mesh axes may be used at most
    once per tensor.
    """
    out = []
    used = set()
    for dim, name in zip(shape, logical):
        assign = rules.get(name) if name is not None else None
        if assign is None:
            out.append(None)
            continue
        axes = (assign,) if isinstance(assign, str) else tuple(assign)
        if any(a in used for a in axes):
            out.append(None)
            continue
        total = 1
        for a in axes:
            total *= mesh_axis_sizes.get(a, 1)
        if total <= 1 or dim % total != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def partition_specs(schema, rules, mesh) -> Any:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf(spec: ParamSpec):
        return logical_to_pspec(spec.shape, spec.logical, rules, sizes)

    return jax.tree_util.tree_map(leaf, schema, is_leaf=is_spec)


def stack(schema, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking dimension (for scan-over-layers segments)."""

    def leaf(spec: ParamSpec):
        return ParamSpec((n,) + spec.shape, (axis_name,) + spec.logical,
                         spec.init, spec.scale, spec.dtype)

    return jax.tree_util.tree_map(leaf, schema, is_leaf=is_spec)


def zeros(schema):
    """Schema -> deterministic-init arrays (cache initialization).  Respects
    zeros/ones/constant; any stochastic init also becomes zeros."""

    def leaf(s: ParamSpec):
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "constant":
            return jnp.full(s.shape, s.scale, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree_util.tree_map(leaf, schema, is_leaf=is_spec)


def count_params(schema) -> int:
    return sum(s.size for s in jax.tree_util.tree_leaves(schema, is_leaf=is_spec)
               if is_spec(s))


def cast_floating(tree, dtype):
    def leaf(x):
        if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree_util.tree_map(leaf, tree)
