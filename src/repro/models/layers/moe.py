"""Mixture-of-Experts layer with expert-parallel sort-based dispatch.

TPU adaptation (see DESIGN.md §3): instead of a GShard one-hot dispatch tensor
(T x E x C — prohibitive at DeepSeek scale) we sort token assignments by
expert id and scatter them into per-expert capacity buckets, then run one
batched (E_local, C, d) x (E_local, d, f) matmul per projection.  Experts are
sharded over the `model` mesh axis (optionally `data x model` for FSDP
configs); activations stay replicated over `model`, so the combine step's
scatter-add produces partial sums that GSPMD turns into one all-reduce —
the same collective pattern as Megatron tensor parallelism.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers.common import activation
from repro.sharding.spec import ParamSpec


def moe_schema(d_model: int, cfg: MoEConfig, act: str):
    E, F = cfg.n_experts, cfg.d_ff_expert
    sch = {
        "router": ParamSpec((d_model, E), ("embed", None), init="normal",
                            scale=0.02),
        "wg": ParamSpec((E, d_model, F), ("experts", "embed", None)),
        "wu": ParamSpec((E, d_model, F), ("experts", "embed", None)),
        "wd": ParamSpec((E, F, d_model), ("experts", None, "embed")),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        sch["shared"] = {
            "wg": ParamSpec((d_model, Fs), ("embed", "ffn")),
            "wu": ParamSpec((d_model, Fs), ("embed", "ffn")),
            "wd": ParamSpec((Fs, d_model), ("ffn", "embed")),
        }
    return sch


def _router(params, x_flat, cfg: MoEConfig):
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if cfg.router_score == "sigmoid":        # deepseek-v3
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(scores, cfg.top_k)          # (T, k)
    if cfg.router_score == "sigmoid":
        weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-20)
    return scores, weights, ids


def moe_apply(params, x, cfg: MoEConfig, act: str) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (out, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    x_flat = x.reshape(T, d)
    scores, weights, ids = _router(params, x_flat, cfg)

    # --- load-balance aux loss (Switch-style) -----------------------------
    probs_mean = jnp.mean(scores, axis=0)                         # (E,)
    counts = jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1))
    frac = counts / jnp.maximum(1.0, T * K)
    aux = cfg.aux_loss_weight * E * jnp.sum(frac * probs_mean)

    # --- sort-based capacity dispatch --------------------------------------
    C = min(T * K, max(cfg.min_capacity,
                       int(cfg.capacity_factor * T * K / E)))
    flat_ids = ids.reshape(-1)                                    # (T*K,)
    flat_w = weights.reshape(-1).astype(x.dtype)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    order = jnp.argsort(flat_ids)                                 # stable
    s_ids, s_tok, s_w = flat_ids[order], flat_tok[order], flat_w[order]
    group_sizes = jnp.bincount(flat_ids, length=E)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    pos = jnp.arange(T * K, dtype=jnp.int32) - offsets[s_ids]
    keep = pos < C
    pos = jnp.where(keep, pos, C)                                  # C drops OOB

    tok_buf = jnp.full((E, C), T, jnp.int32).at[s_ids, pos].set(
        s_tok, mode="drop")                                        # (E, C)
    w_buf = jnp.zeros((E, C), x.dtype).at[s_ids, pos].set(s_w, mode="drop")

    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = x_pad[tok_buf]                                      # (E, C, d)

    f = activation(act)
    g = f(jnp.einsum("ecd,edf->ecf", gathered, params["wg"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", gathered, params["wu"].astype(x.dtype))
    expert_out = jnp.einsum("ecf,efd->ecd", g * u,
                            params["wd"].astype(x.dtype))          # (E, C, d)

    combined = jnp.zeros((T + 1, d), x.dtype).at[tok_buf].add(
        expert_out * w_buf[..., None])
    out = combined[:T].reshape(B, S, d)

    if cfg.n_shared_experts:
        from repro.models.layers.mlp import mlp_apply
        out = out + mlp_apply(params["shared"], x, act)
    return out, aux
