"""Shared primitives: norms, dense layers, activations, causal depthwise conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.spec import ParamSpec


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_schema(d: int):
    # zero-centered scale (gemma convention): y = x_hat * (1 + scale)
    return {"scale": ParamSpec((d,), ("embed",), init="zeros")}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xhat = xf * jax.lax.rsqrt(var + eps)
    return (xhat * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def head_rmsnorm(scale, x, eps: float = 1e-6):
    """Per-head qk-norm over the trailing head_dim."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xhat = xf * jax.lax.rsqrt(var + eps)
    return (xhat * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def linear_schema(d_in: int, d_out: int, lin: str = "embed", lout: str = "ffn",
                  init: str = "fan_in", scale: float = 1.0):
    return ParamSpec((d_in, d_out), (lin, lout), init=init, scale=scale)


def dense(w, x):
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_plain": lambda x: jax.nn.gelu(x, approximate=True),
        "tanh": jnp.tanh,
    }[name]


def softcap(x, cap: float):
    if cap and cap > 0.0:
        return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (RG-LRU / xLSTM front conv)
# ---------------------------------------------------------------------------

def causal_conv_schema(width: int, d: int, channel_logical: str = "rnn"):
    return {"w": ParamSpec((width, d), (None, channel_logical),
                           init="normal", scale=0.1),
            "b": ParamSpec((d,), (channel_logical,), init="zeros")}


def causal_conv(params, x):
    """x: (B, S, d).  y_t = b + sum_k w[k] * x_{t-k}."""
    w, b = params["w"], params["b"]
    width = w.shape[0]
    out = jnp.zeros_like(x)
    for k in range(width):
        xk = x if k == 0 else jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :-k or None][:, : x.shape[1]]
        out = out + xk * w[k].astype(x.dtype)
    return out + b.astype(x.dtype)


def causal_conv_step(params, conv_state, x_t):
    """One decode step.  conv_state: (B, width-1, d) most-recent-last.
    Returns (y_t, new_state)."""
    w, b = params["w"], params["b"]
    width = w.shape[0]
    hist = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, width, d)
    # hist[:, -1] is x_t (k=0), hist[:, -2] is x_{t-1} (k=1), ...
    taps = w[::-1].astype(x_t.dtype)                             # align order
    y = jnp.einsum("bwd,wd->bd", hist, taps) + b.astype(x_t.dtype)
    return y, hist[:, 1:]
