"""RecurrentGemma / Griffin recurrent block with the RG-LRU [arXiv:2402.19427].

Block:  x -> (gate branch: linear+GeLU) and (main: linear -> causal conv ->
RG-LRU) -> elementwise product -> output linear.

RG-LRU recurrence (per channel, gates block-diagonal over heads):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(lambda) * r_t)  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan (the Pallas kernel in
src/repro/kernels/rg_lru is the TPU chunked version; this module's jnp scan is
its oracle); decode is a single fused step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.layers.common import (activation, causal_conv,
                                        causal_conv_schema, causal_conv_step)
from repro.sharding.spec import ParamSpec

_C = 8.0


def rglru_schema(d_model: int, cfg: RGLRUConfig):
    d, H = cfg.width, cfg.n_heads
    dh = d // H
    return {
        "w_gate": ParamSpec((d_model, d), ("embed", "rnn")),
        "w_in": ParamSpec((d_model, d), ("embed", "rnn")),
        "conv": causal_conv_schema(cfg.conv_width, d),
        "lam": ParamSpec((d,), ("rnn",), init="constant", scale=0.7),
        "wa": ParamSpec((H, dh, dh), ("heads", None, None)),
        "ba": ParamSpec((d,), ("rnn",), init="constant", scale=2.0),
        "wx": ParamSpec((H, dh, dh), ("heads", None, None)),
        "bx": ParamSpec((d,), ("rnn",), init="zeros"),
        "w_out": ParamSpec((d, d_model), ("rnn", "embed")),
    }


def _blockdiag(w, b, x, H):
    """x: (..., d) -> per-head block-diagonal linear."""
    d = x.shape[-1]
    dh = d // H
    xh = x.reshape(x.shape[:-1] + (H, dh))
    y = jnp.einsum("...hk,hkj->...hj", xh, w.astype(x.dtype))
    return y.reshape(x.shape) + b.astype(x.dtype)


def _gates(params, cfg: RGLRUConfig, u):
    """u: (..., d_rnn) conv output -> (log_a, b) of the recurrence."""
    r = jax.nn.sigmoid(_blockdiag(params["wa"], params["ba"], u,
                                  cfg.n_heads).astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(params["wx"], params["bx"], u,
                                  cfg.n_heads).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, 1.0))
    b = mult * (i * u.astype(jnp.float32))
    return a, b


def rglru_scan(params, cfg: RGLRUConfig, u, h0=None):
    """u: (B, S, d_rnn).  Linear recurrence via associative scan."""
    a, b = _gates(params, cfg, u)
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(u.dtype)


def rglru_block_apply(params, cfg: RGLRUConfig, x, act: str = "gelu"):
    """Full-sequence path.  x: (B, S, d_model)."""
    gate = activation(act)(jnp.einsum("bsd,dr->bsr", x,
                                      params["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,dr->bsr", x, params["w_in"].astype(x.dtype))
    u = causal_conv(params["conv"], u)
    h = rglru_scan(params, cfg, u)
    return jnp.einsum("bsr,rd->bsd", h * gate, params["w_out"].astype(x.dtype))


def rglru_state_schema(cfg: RGLRUConfig, batch: int, dtype):
    return {
        "h": ParamSpec((batch, cfg.width), ("batch", "rnn"), init="zeros",
                       dtype=jnp.float32),
        "conv": ParamSpec((batch, cfg.conv_width - 1, cfg.width),
                          ("batch", None, "rnn"), init="zeros", dtype=dtype),
    }


def rglru_block_decode(params, cfg: RGLRUConfig, x, state, act: str = "gelu"):
    """One token.  x: (B, 1, d_model)."""
    xt = x[:, 0]
    gate = activation(act)(xt @ params["w_gate"].astype(x.dtype))
    u = xt @ params["w_in"].astype(x.dtype)
    u, conv_state = causal_conv_step(params["conv"], state["conv"], u)
    a, b = _gates(params, cfg, u)
    h = a * state["h"] + b
    y = (h.astype(x.dtype) * gate) @ params["w_out"].astype(x.dtype)
    return y[:, None], {"h": h, "conv": conv_state}
