"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent gate connections) [arXiv:2405.04517].

mLSTM recurrence per head (exp-gating with m-stabilizer):
    m_t = max(f~_t + m_{t-1}, i~_t)
    C_t = exp(f~_t + m_{t-1} - m_t) C_{t-1} + exp(i~_t - m_t) v_t k_t^T
    n_t = exp(f~_t + m_{t-1} - m_t) n_{t-1} + exp(i~_t - m_t) k_t
    h_t = C_t^T q_t / max(|n_t . q_t|, 1)

The jnp implementations here (sequential lax.scan) are the oracles for the
chunkwise Pallas kernel in src/repro/kernels/mlstm.  sLSTM is inherently
sequential (h_{t-1} feeds the gates) and stays a scan everywhere.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models.layers.common import (causal_conv, causal_conv_schema,
                                        causal_conv_step, head_rmsnorm,
                                        rmsnorm, rmsnorm_schema)
from repro.sharding.spec import ParamSpec


# ===========================================================================
# mLSTM block
# ===========================================================================

def _m_dims(d_model: int, cfg: XLSTMConfig):
    d_in = int(cfg.proj_factor_m * d_model)
    dh = d_in // cfg.n_heads
    return d_in, dh


def mlstm_schema(d_model: int, cfg: XLSTMConfig):
    """Sharding design (EXPERIMENTS.md §Perf iter C1): heads (often 4) rarely
    divide the `model` axis, so head-sharding degrades to contraction-dim
    psums — 7+ output all-reduces per layer.  Instead the VALUE head_dim
    (dh_v, logical "rnn") is model-sharded: the matrix memory C = k v^T is
    column-sharded and every recurrence op stays local; q/k/gates are
    replicated (tiny); the only per-layer collective is the down-projection
    psum.  GroupNorm is per-head (as in the xLSTM paper), so its reduction is
    over the sharded dh_v — a scalar-sized psum."""
    d_in, dh = _m_dims(d_model, cfg)
    H = cfg.n_heads
    return {
        # u-branch feeds contractions (conv -> q/k/gates): replicated.
        # z-branch is purely elementwise against the dh_v-sharded h: sharded
        # (iter C2 — halves the replicated up-projection activation).
        "wu": ParamSpec((d_model, d_in), ("embed", None)),
        "wz": ParamSpec((d_model, H, dh), ("embed", "heads", "rnn")),
        "conv": causal_conv_schema(cfg.conv_width, d_in, channel_logical=None),
        "wq": ParamSpec((d_in, H, dh), (None, "heads", None)),
        "wk": ParamSpec((d_in, H, dh), (None, "heads", None)),
        "wv": ParamSpec((d_in, H, dh), (None, "heads", "rnn")),
        "wi": ParamSpec((d_in, H), (None, "heads"), init="normal", scale=0.02),
        "bi": ParamSpec((H,), ("heads",), init="zeros"),
        "wf": ParamSpec((d_in, H), (None, "heads"), init="normal", scale=0.02),
        "bf": ParamSpec((H,), ("heads",), init="constant", scale=3.0),
        "gn": {"scale": ParamSpec((H, dh), ("heads", "rnn"), init="zeros")},
        "wd": ParamSpec((H, dh, d_model), ("heads", "rnn", "embed")),
    }


def mlstm_qkv_gates(params, cfg: XLSTMConfig, x):
    """x: (B, S, d_model) -> q,k,v (B,S,H,dh), gate pre-acts (B,S,H),
    z (B,S,H,dh)."""
    d_in, dh = _m_dims(x.shape[-1], cfg)
    u = jnp.einsum("bsd,df->bsf", x, params["wu"].astype(x.dtype))
    z = jnp.einsum("bsd,dhk->bshk", x, params["wz"].astype(x.dtype))
    uc = jax.nn.silu(causal_conv(params["conv"], u))
    q = jnp.einsum("bsf,fhk->bshk", uc, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsf,fhk->bshk", uc, params["wk"].astype(x.dtype)) / math.sqrt(dh)
    v = jnp.einsum("bsf,fhk->bshk", u, params["wv"].astype(x.dtype))
    i_pre = (jnp.einsum("bsf,fh->bsh", uc, params["wi"].astype(x.dtype))
             + params["bi"].astype(x.dtype)).astype(jnp.float32)
    f_pre = (jnp.einsum("bsf,fh->bsh", uc, params["wf"].astype(x.dtype))
             + params["bf"].astype(x.dtype)).astype(jnp.float32)
    return q, k, v, i_pre, f_pre, z


def mlstm_recurrence(q, k, v, i_pre, f_pre, state=None):
    """Sequential stabilized scan.  q,k,v: (B,S,H,dh); gates (B,S,H).
    state: optional (C, n, m) carry.  Returns (h, new_state)."""
    B, S, H, dh = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        log_f = jax.nn.log_sigmoid(ft)              # f gate: sigmoid-form log
        m_new = jnp.maximum(log_f + m, it)
        f_eff = jnp.exp(log_f + m - m_new)          # (B,H)
        i_eff = jnp.exp(it - m_new)
        ktf = kt.astype(jnp.float32)
        vtf = vt.astype(jnp.float32)
        C = f_eff[..., None, None] * C + i_eff[..., None, None] * (
            ktf[..., :, None] * vtf[..., None, :])
        n = f_eff[..., None] * n + i_eff[..., None] * ktf
        qtf = qt.astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, qtf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qtf)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.swapaxes(0, 1), (C, n, m)            # (B,S,H,dh)


def mlstm_block_apply(params, cfg: XLSTMConfig, x, use_kernel: bool = False):
    q, k, v, i_pre, f_pre, z = mlstm_qkv_gates(params, cfg, x)
    if use_kernel:
        from repro.kernels.mlstm.ops import mlstm_chunkwise
        h = mlstm_chunkwise(q, k, v, i_pre, f_pre)
    else:
        h, _ = mlstm_recurrence(q, k, v, i_pre, f_pre)
    B, S, H, dh = h.shape
    h = h.astype(x.dtype)
    # per-head GroupNorm (xLSTM GN groups == heads); reduction over the
    # model-sharded dh_v is a scalar-sized psum
    h = head_rmsnorm(params["gn"]["scale"], h)
    h = h * jax.nn.silu(z)
    return jnp.einsum("bshk,hkd->bsd", h, params["wd"].astype(x.dtype))


def mlstm_state_schema(d_model: int, cfg: XLSTMConfig, batch: int, dtype):
    d_in, dh = _m_dims(d_model, cfg)
    H = cfg.n_heads
    return {
        "C": ParamSpec((batch, H, dh, dh), ("batch", "heads", None, None),
                       init="zeros", dtype=jnp.float32),
        "n": ParamSpec((batch, H, dh), ("batch", "heads", None),
                       init="zeros", dtype=jnp.float32),
        "m": ParamSpec((batch, H), ("batch", "heads"),
                       init="constant", scale=-1e30, dtype=jnp.float32),
        "conv": ParamSpec((batch, cfg.conv_width - 1, d_in),
                          ("batch", None, "rnn"), init="zeros", dtype=dtype),
    }


def mlstm_block_decode(params, cfg: XLSTMConfig, x, state):
    """x: (B, 1, d_model)."""
    d_in, dh = _m_dims(x.shape[-1], cfg)
    xt = x[:, 0]
    u = xt @ params["wu"].astype(x.dtype)
    z = jnp.einsum("bd,dhk->bhk", xt, params["wz"].astype(x.dtype))
    uc, conv_state = causal_conv_step(params["conv"], state["conv"], u)
    uc = jax.nn.silu(uc)
    H = cfg.n_heads
    q = jnp.einsum("bf,fhk->bhk", uc, params["wq"].astype(x.dtype))
    k = jnp.einsum("bf,fhk->bhk", uc, params["wk"].astype(x.dtype)) / math.sqrt(dh)
    v = jnp.einsum("bf,fhk->bhk", u, params["wv"].astype(x.dtype))
    i_pre = (jnp.einsum("bf,fh->bh", uc, params["wi"].astype(x.dtype))
             + params["bi"].astype(x.dtype)).astype(jnp.float32)
    f_pre = (jnp.einsum("bf,fh->bh", uc, params["wf"].astype(x.dtype))
             + params["bf"].astype(x.dtype)).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    f_eff = jnp.exp(log_f + state["m"] - m_new)
    i_eff = jnp.exp(i_pre - m_new)
    kf, vf, qf = (k.astype(jnp.float32), v.astype(jnp.float32),
                  q.astype(jnp.float32))
    C = (f_eff[..., None, None] * state["C"]
         + i_eff[..., None, None] * (kf[..., :, None] * vf[..., None, :]))
    n = f_eff[..., None] * state["n"] + i_eff[..., None] * kf
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).astype(x.dtype)          # (B, H, dh)
    h = head_rmsnorm(params["gn"]["scale"], h)
    h = h * jax.nn.silu(z)
    y = jnp.einsum("bhk,hkd->bd", h, params["wd"].astype(x.dtype))
    return y[:, None], {"C": C, "n": n, "m": m_new, "conv": conv_state}


# ===========================================================================
# sLSTM block
# ===========================================================================

def _s_dims(d_model: int, cfg: XLSTMConfig):
    dh = d_model // cfg.n_heads
    d_ff = int(round(cfg.proj_factor_s * d_model))
    return dh, d_ff


def slstm_schema(d_model: int, cfg: XLSTMConfig):
    H = cfg.n_heads
    dh, d_ff = _s_dims(d_model, cfg)
    gate = lambda bias_scale=0.0, init="fan_in": {
        "w": ParamSpec((d_model, d_model), ("embed", "rnn")),
        "r": ParamSpec((H, dh, dh), ("heads", None, None), init="normal",
                       scale=0.02),
        "b": ParamSpec((d_model,), ("rnn",),
                       init="constant" if bias_scale else "zeros",
                       scale=bias_scale),
    }
    return {
        "conv": causal_conv_schema(cfg.conv_width, d_model),
        "z": gate(), "i": gate(), "f": gate(bias_scale=3.0), "o": gate(),
        "gn": rmsnorm_schema(d_model),
        "wup": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "wdown": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def _slstm_gate(g, x_c, h_prev, H):
    d = x_c.shape[-1]
    dh = d // H
    hh = h_prev.reshape(h_prev.shape[:-1] + (H, dh))
    rec = jnp.einsum("...hk,hkj->...hj", hh, g["r"].astype(h_prev.dtype))
    rec = rec.reshape(h_prev.shape)
    return (x_c @ g["w"].astype(x_c.dtype) + rec
            + g["b"].astype(x_c.dtype)).astype(jnp.float32)


def slstm_step(params, cfg: XLSTMConfig, x_c_t, state):
    """One recurrence step.  x_c_t: (B, d) conv-activated input."""
    c, n, m, h = state
    hx = h.astype(x_c_t.dtype)
    z = jnp.tanh(_slstm_gate(params["z"], x_c_t, hx, cfg.n_heads))
    i_pre = _slstm_gate(params["i"], x_c_t, hx, cfg.n_heads)
    f_pre = _slstm_gate(params["f"], x_c_t, hx, cfg.n_heads)
    o = jax.nn.sigmoid(_slstm_gate(params["o"], x_c_t, hx, cfg.n_heads))
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)
    f_eff = jnp.exp(log_f + m - m_new)
    i_eff = jnp.exp(i_pre - m_new)
    c_new = f_eff * c + i_eff * z
    n_new = f_eff * n + i_eff
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_block_apply(params, cfg: XLSTMConfig, x):
    """x: (B, S, d_model)."""
    B, S, d = x.shape
    x_c = jax.nn.silu(causal_conv(params["conv"], x))
    state = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
             jnp.full((B, d), -1e30, jnp.float32), jnp.zeros((B, d), jnp.float32))

    def step(carry, xt):
        new = slstm_step(params, cfg, xt, carry)
        return new, new[3]

    _, hs = jax.lax.scan(step, state, x_c.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    h = rmsnorm(params["gn"], h)
    ff = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, params["wup"].astype(x.dtype)),
                     approximate=True)
    return jnp.einsum("bsf,fd->bsd", ff, params["wdown"].astype(x.dtype))


def slstm_state_schema(d_model: int, cfg: XLSTMConfig, batch: int, dtype):
    vec = lambda init="zeros", scale=1.0: ParamSpec(
        (batch, d_model), ("batch", "rnn"), init=init, scale=scale,
        dtype=jnp.float32)
    return {
        "c": vec(), "n": vec(), "m": vec("constant", -1e30), "h": vec(),
        "conv": ParamSpec((batch, cfg.conv_width - 1, d_model),
                          ("batch", None, "rnn"), init="zeros", dtype=dtype),
    }


def slstm_block_decode(params, cfg: XLSTMConfig, x, state):
    xt = x[:, 0]
    u, conv_state = causal_conv_step(params["conv"], state["conv"], xt)
    x_c = jax.nn.silu(u)
    c, n, m, h = slstm_step(params, cfg, x_c,
                            (state["c"], state["n"], state["m"], state["h"]))
    ho = rmsnorm(params["gn"], h.astype(x.dtype))
    ff = jax.nn.gelu(ho @ params["wup"].astype(x.dtype), approximate=True)
    y = ff @ params["wdown"].astype(x.dtype)
    return y[:, None], {"c": c, "n": n, "m": m, "h": h, "conv": conv_state}
