"""Rotary position embeddings, including Qwen2-VL multimodal M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def _angles(positions, dim: int, theta: float):
    """positions: (...,) -> (..., dim/2) angle table."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D), positions: (B, S) absolute positions."""
    B, S, H, D = x.shape
    ang = _angles(positions, D, theta)            # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL M-RoPE [arXiv:2409.12191].

    x: (B, S, H, D); positions3: (3, B, S) = (temporal, height, width) ids;
    sections: split of D/2 rotary frequencies among the three position kinds.
    """
    B, S, H, D = x.shape
    assert sum(sections) == D // 2, (sections, D)
    ang_all = _angles(positions3, D, theta)       # (3, B, S, D/2)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)         # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
