"""Attention: GQA (qk-norm / softcap / sliding-window) and DeepSeek MLA.

Two execution paths per variant:
  * full-sequence (train / prefill) — q-chunked causal attention so the
    (S x S) score matrix never materializes for long sequences;
  * decode — one new token against a (possibly ring-buffered sliding-window)
    KV cache.

The Pallas flash-attention kernel (src/repro/kernels/flash_attention) is the
TPU fast path for the full-sequence case; `use_kernel=False` (default on CPU
and in the dry-run) uses the jnp implementation below, which is also the
kernel's oracle.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig
from repro.models.layers.common import head_rmsnorm, softcap as _softcap
from repro.models.layers.rope import apply_mrope, apply_rope
from repro.sharding.spec import ParamSpec

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------

def attention_schema(d_model: int, cfg: AttnConfig):
    if cfg.mla is not None:
        return mla_schema(d_model, cfg)
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sch = {
        "wq": ParamSpec((d_model, H, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, KV, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d_model, KV, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, hd, d_model), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        sch["q_norm"] = ParamSpec((hd,), (None,), init="zeros")
        sch["k_norm"] = ParamSpec((hd,), (None,), init="zeros")
    return sch


def mla_schema(d_model: int, cfg: AttnConfig):
    m = cfg.mla
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamSpec((d_model, m.q_lora_rank), ("embed", None)),
        "q_norm": ParamSpec((m.q_lora_rank,), (None,), init="zeros"),
        "wq_b": ParamSpec((m.q_lora_rank, H, qk_dim), (None, "heads", None)),
        "wkv_a": ParamSpec((d_model, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", None)),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), init="zeros"),
        "wkv_b": ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                           (None, "heads", None)),
        "wo": ParamSpec((H, m.v_head_dim, d_model), ("heads", None, "embed")),
    }


# ---------------------------------------------------------------------------
# Core causal attention (q-chunked)
# ---------------------------------------------------------------------------

def causal_attention(q, k, v, *, window: Optional[int] = None,
                     logit_softcap: float = 0.0, q_offset: int = 0,
                     q_chunk: int = 2048):
    """q: (B, Sq, H, D), k/v: (B, Skv, KV, D) with H % KV == 0.

    Causal mask with optional sliding window.  ``q_offset`` is the absolute
    position of q[0] relative to k[0] (prefill: 0 with Sq == Skv).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    kt = k.swapaxes(1, 2)  # (B, KV, Skv, D)
    vt = v.swapaxes(1, 2)
    kv_pos = jnp.arange(k.shape[1])

    def chunk_attn(q_chunk_arr, chunk_start):
        # q_chunk_arr: (B, C, H, D)
        C = q_chunk_arr.shape[1]
        qh = q_chunk_arr.swapaxes(1, 2).reshape(B, KV, G * C, D)
        scores = jnp.einsum("bkqd,bksd->bkqs", qh.astype(jnp.float32),
                            kt.astype(jnp.float32)) * scale
        scores = scores.reshape(B, KV, G, C, -1)
        if logit_softcap:
            scores = logit_softcap * jnp.tanh(scores / logit_softcap)
        q_pos = chunk_start + q_offset + jnp.arange(C)
        mask = kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > (q_pos[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bksd->bkgqd", probs.astype(vt.dtype), vt)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, C, H, D)

    if Sq <= q_chunk:
        return chunk_attn(q, 0)

    Sq_pad = -(-Sq // q_chunk) * q_chunk
    q_in = q if Sq_pad == Sq else jnp.pad(
        q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    n_chunks = Sq_pad // q_chunk
    qs = q_in.reshape(B, n_chunks, q_chunk, H, D).swapaxes(0, 1)

    def body(i, qc):
        return chunk_attn(qc, i * q_chunk)

    outs = jax.lax.map(lambda args: body(*args),
                       (jnp.arange(n_chunks), qs))
    out = outs.swapaxes(0, 1).reshape(B, Sq_pad, H, D)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, valid_mask, *,
                     logit_softcap: float = 0.0, k_scale=None, v_scale=None):
    """q: (B, 1, H, D); caches: (B, L, KV, D); valid_mask: (B, L) bool.

    The cache operands stay in their storage dtype with fp32 ACCUMULATION
    via preferred_element_type — materializing fp32 copies of the cache
    tripled decode bytes-accessed (EXPERIMENTS.md §Perf iter B2).  With an
    int8-quantized cache (k_scale/v_scale given, §Perf iter B4) the per-slot
    scales fold into the score/context products, so dequantization never
    materializes a full-width cache copy."""
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    qh = q.reshape(B, KV, G, D)  # heads grouped by kv head
    scores = jnp.einsum("bkgd,blkd->bkgl", qh,
                        k_cache.astype(qh.dtype) if k_scale is not None
                        else k_cache,
                        preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        scores = scores * k_scale.astype(jnp.float32).transpose(0, 2, 1)[
            :, :, None, :]
    if logit_softcap:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    scores = jnp.where(valid_mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        probs_w = probs * v_scale.astype(jnp.float32).transpose(0, 2, 1)[
            :, :, None, :]
        out = jnp.einsum("bkgl,blkd->bkgd", probs_w.astype(q.dtype),
                         v_cache.astype(q.dtype))
    else:
        out = jnp.einsum("bkgl,blkd->bkgd", probs.astype(v_cache.dtype),
                         v_cache)
    return out.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------

def _pad_heads(w, h_pad: Optional[int], axis: int):
    """Zero-pad a weight's head dimension to `h_pad` (inert heads: their wo
    rows are zero so both contributions and gradients are exactly zero)."""
    if h_pad is None or w.shape[axis] == h_pad:
        return w
    pads = [(0, 0)] * w.ndim
    pads[axis] = (0, h_pad - w.shape[axis])
    return jnp.pad(w, pads)


def _project_qkv(params, cfg: AttnConfig, x, positions):
    wq = _pad_heads(params["wq"], cfg.n_heads_padded, 1)
    wk = _pad_heads(params["wk"], cfg.n_kv_heads_padded, 1)
    wv = _pad_heads(params["wv"], cfg.n_kv_heads_padded, 1)
    q = jnp.einsum("bsd,dhk->bshk", x, wq.astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, wk.astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, wv.astype(x.dtype))
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _constrain_heads(q, k, v, cfg: AttnConfig, mesh):
    """Padded head dims don't shard by propagation alone (the stored weights
    are replicated) — force the activation sharding (§Perf iter D2)."""
    if mesh is None or cfg.n_heads_padded is None or \
            "model" not in mesh.axis_names:
        return q, k, v
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = mesh.devices.shape[list(mesh.axis_names).index("model")]
    def c(t):
        if t.shape[2] % m == 0:
            batch_ax = "data" if t.shape[0] % dict(
                zip(mesh.axis_names, mesh.devices.shape)).get("data", 1) == 0 \
                else None
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(mesh, P(batch_ax, None, "model", None)))
        return t
    return c(q), c(k), c(v)


def attention_apply(params, cfg: AttnConfig, x, positions, *,
                    window: Optional[int], use_kernel: bool = False,
                    mesh=None):
    """Full-sequence path.  x: (B, S, d); positions: (B,S) or (3,B,S)."""
    if cfg.mla is not None:
        return mla_apply_train(params, cfg, x, positions, window=window)
    q, k, v = _project_qkv(params, cfg, x, positions)
    q, k, v = _constrain_heads(q, k, v, cfg, mesh)
    if use_kernel:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, window=window,
                              logit_softcap=cfg.logit_softcap)
    else:
        out = causal_attention(q, k, v, window=window,
                               logit_softcap=cfg.logit_softcap)
    wo = _pad_heads(params["wo"], cfg.n_heads_padded, 0)
    return jnp.einsum("bshk,hkd->bsd", out, wo.astype(x.dtype))


def kv_cache_schema(cfg: AttnConfig, batch: int, cache_len: int,
                    window: Optional[int], dtype, quant: bool = False):
    """ParamSpec schema of one attention layer's decode cache (ring-buffered
    to `window` for sliding-window layers).  ``quant=True`` stores int8
    entries with a per-(slot, kv_head) fp16 absmax scale — halves the cache
    bytes that dominate the decode memory roofline (§Perf iter B4)."""
    L = min(cache_len, window) if window else cache_len
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": ParamSpec((batch, L, m.kv_lora_rank),
                              ("batch", "cache", None), init="zeros",
                              dtype=dtype),
            "k_rope": ParamSpec((batch, L, m.qk_rope_head_dim),
                                ("batch", "cache", None), init="zeros",
                                dtype=dtype),
        }
    KV = cfg.n_kv_heads_padded or cfg.n_kv_heads
    if quant:
        kv = ParamSpec((batch, L, KV, cfg.head_dim),
                       ("batch", "cache", "kv_heads", None), init="zeros",
                       dtype=jnp.int8)
        sc = ParamSpec((batch, L, KV),
                       ("batch", "cache", "kv_heads"), init="zeros",
                       dtype=jnp.float16)
        return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc}
    kv = ParamSpec((batch, L, KV, cfg.head_dim),
                   ("batch", "cache", "kv_heads", None), init="zeros",
                   dtype=dtype)
    return {"k": kv, "v": kv}


def _ring_slot(pos, L):
    return jnp.mod(pos, L)


def _cache_valid_mask(pos, L, batch):
    """Valid slots for a ring cache of length L when the current absolute
    position is `pos` (the new token is already inserted at its slot)."""
    slots = jnp.arange(L)
    n_filled = jnp.minimum(pos + 1, L)
    # slots are valid if their "age" < n_filled; with ring writes the set of
    # valid slots is simply the n_filled most recent, which for a ring is
    # every slot when full, else slots <= pos.
    valid = slots[None, :] < n_filled
    return jnp.broadcast_to(valid, (batch, L))


def _quantize_kv(t):
    """t: (B, 1, KV, D) -> (int8 values, fp16 per-(slot,head) scale)."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def attention_decode(params, cfg: AttnConfig, x, cache, pos, *,
                     window: Optional[int], cache_len: int):
    """x: (B, 1, d); pos: scalar absolute position of the new token."""
    if cfg.mla is not None:
        return mla_apply_decode(params, cfg, x, cache, pos,
                                window=window, cache_len=cache_len)
    B = x.shape[0]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos, (3, B, 1))
    else:
        positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = _project_qkv(params, cfg, x, positions)
    L = cache["k"].shape[1]
    slot = _ring_slot(pos, L)
    quant = "k_scale" in cache
    if quant:
        k_new, ks_new = _quantize_kv(k)
        v_new, vs_new = _quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                               (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                               (0, slot, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(cache["k_scale"], ks_new,
                                               (0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(cache["v_scale"], vs_new,
                                               (0, slot, 0))
        new_cache = {"k": k_cache, "v": v_cache,
                     "k_scale": k_scale, "v_scale": v_scale}
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        k_scale = v_scale = None
        new_cache = {"k": k_cache, "v": v_cache}
    valid = _cache_valid_mask(pos, L, B)
    out = decode_attention(q, k_cache, v_cache, valid,
                           logit_softcap=cfg.logit_softcap,
                           k_scale=k_scale, v_scale=v_scale)
    wo = _pad_heads(params["wo"], cfg.n_heads_padded, 0)
    y = jnp.einsum("bshk,hkd->bsd", out, wo.astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def _mla_q(params, cfg, x, positions):
    m = cfg.mla
    cq = head_rmsnorm(params["q_norm"], jnp.einsum(
        "bsd,dr->bsr", x, params["wq_a"].astype(x.dtype)))
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"].astype(x.dtype))
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_compress(params, cfg, x, positions):
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(x.dtype))
    c_kv = head_rmsnorm(params["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = apply_rope(kv[..., m.kv_lora_rank:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_apply_train(params, cfg: AttnConfig, x, positions, *, window):
    m = cfg.mla
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv, k_rope = _mla_kv_compress(params, cfg, x, positions)
    # expand compressed kv into per-head K_nope and V (naive/train form)
    kv_b = params["wkv_b"].astype(x.dtype)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, kv_b[..., : m.qk_nope_head_dim])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, kv_b[..., m.qk_nope_head_dim:])
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # pad V up to qk head dim so we can reuse the shared attention core
    out = causal_attention(q, k, v_pad(v, q.shape[-1]), window=window)
    out = out[..., : m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def v_pad(v, d):
    if v.shape[-1] == d:
        return v
    return jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, d - v.shape[-1]),))


def mla_apply_decode(params, cfg: AttnConfig, x, cache, pos, *,
                     window, cache_len):
    """Absorbed MLA decode: attend in the compressed kv_lora space, so the
    cache stays (B, L, 512+64) regardless of the 128 heads."""
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope = _mla_q(params, cfg, x, positions)       # (B,1,H,*)
    c_kv_new, k_rope_new = _mla_kv_compress(params, cfg, x, positions)
    L = cache["c_kv"].shape[1]
    slot = _ring_slot(pos, L)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, slot, 0))
    kv_b = params["wkv_b"].astype(x.dtype)
    # absorb W_UK into q:  (B,1,H,dn) x (r,H,dn) -> (B,1,H,r)
    q_eff = jnp.einsum("bshk,rhk->bshr", q_nope, kv_b[..., : m.qk_nope_head_dim])
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # bf16 operands, fp32 accumulation: no fp32 copy of the compressed cache
    scores = (jnp.einsum("bshr,blr->bshl", q_eff, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,blk->bshl", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    valid = _cache_valid_mask(pos, L, B)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bshl,blr->bshr", probs.astype(c_kv.dtype), c_kv)
    v_out = jnp.einsum("bshr,rhk->bshk", ctx, kv_b[..., m.qk_nope_head_dim:])
    y = jnp.einsum("bshk,hkd->bsd", v_out, params["wo"].astype(x.dtype))
    return y, {"c_kv": c_kv, "k_rope": k_rope}
