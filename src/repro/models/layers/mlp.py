"""Gated MLP (SwiGLU / GeGLU) and plain-GELU MLP."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers.common import activation
from repro.sharding.spec import ParamSpec


def mlp_schema(d_model: int, d_ff: int, act: str):
    if act == "gelu_plain":
        return {
            "wi": ParamSpec((d_model, d_ff), ("embed", "ffn")),
            "wd": ParamSpec((d_ff, d_model), ("ffn", "embed")),
        }
    return {
        "wg": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "wu": ParamSpec((d_model, d_ff), ("embed", "ffn")),
        "wd": ParamSpec((d_ff, d_model), ("ffn", "embed")),
    }


def mlp_apply(params, x, act: str):
    f = activation(act)
    if act == "gelu_plain":
        h = f(jnp.einsum("...d,df->...f", x, params["wi"].astype(x.dtype)))
        return jnp.einsum("...f,fd->...d", h, params["wd"].astype(x.dtype))
    g = f(jnp.einsum("...d,df->...f", x, params["wg"].astype(x.dtype)))
    u = jnp.einsum("...d,df->...f", x, params["wu"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", g * u, params["wd"].astype(x.dtype))
