"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

Why this exists (EXPERIMENTS.md §Perf, iter A3): the pjit/gather formulation
in moe.py builds capacity buffers by GLOBAL token index; with tokens sharded
over `data` and experts over `data x model`, GSPMD can only satisfy the
gather by all-gathering the full (T, d) token matrix to every device
(~30 GB/layer fwd at DeepSeek scale, x3 with remat+bwd).  The communication-
minimal schedule — each token travels to the (at most k) devices owning its
experts and back — is an all-to-all, which GSPMD cannot infer from a gather.
This module expresses it explicitly with shard_map:

  1. slice the model-replicated activations by `model` index (free): each of
     the D x M devices now owns T_loc = T/(D*M) unique tokens;
  2. route locally; sort token assignments by OWNER DEVICE; fill per-
     destination capacity buckets (N_ep, C, d);
  3. all_to_all over the joint ("data","model") expert-parallel axis
     (~T_loc * k * d bytes per device per direction, the information-
     theoretic minimum for capacity-based MoE);
  4. locally sub-dispatch to the E/(D*M) resident experts, run the gated
     FFN, all_to_all the outputs back, combine with router weights;
  5. reassemble the sequence with an S-axis all-gather over `model`.

Experts whose count does not divide the joint axis fall back to EP over
`model` only (olmoe: 64 experts / 16 model shards); if that fails too the
caller uses the gather path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.models.layers.common import activation
from repro.models.layers.moe import _router


def ep_axes_for(cfg: MoEConfig, mesh) -> Optional[Tuple[str, ...]]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    joint = sizes.get("data", 1) * sizes.get("model", 1)
    if cfg.n_experts % joint == 0:
        return ("data", "model")
    if cfg.n_experts % sizes.get("model", 1) == 0:
        return ("model",)
    return None


def _fill_buckets(ids, payload_tok, n_buckets, cap):
    """Sort-based bucketing: ids (N,) in [0, n_buckets); returns
    (bucket_tok (n_buckets, cap) int32 indices-with-sentinel, keep mask)."""
    N = ids.shape[0]
    order = jnp.argsort(ids)
    s_ids = ids[order]
    s_tok = payload_tok[order]
    sizes = jnp.bincount(ids, length=n_buckets)
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32),
                            jnp.cumsum(sizes)[:-1].astype(jnp.int32)])
    pos = jnp.arange(N, dtype=jnp.int32) - offs[s_ids]
    keep = pos < cap
    pos = jnp.where(keep, pos, cap)
    buf = jnp.full((n_buckets, cap), -1, jnp.int32).at[s_ids, pos].set(
        jnp.where(keep, s_tok, -1), mode="drop")
    return buf


def moe_apply_a2a(params, x, cfg: MoEConfig, act: str, mesh,
                  ep_axes: Tuple[str, ...]):
    """x: (B, S, d) sharded P('data', None, None), model-replicated.
    Returns (out with the same sharding, aux scalar)."""
    B, S, d = x.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    D, Mx = sizes.get("data", 1), sizes.get("model", 1)
    E, K = cfg.n_experts, cfg.top_k
    n_ep = 1
    for a in ep_axes:
        n_ep *= sizes.get(a, 1)
    e_per_dev = E // n_ep
    f = activation(act)

    # per-device unique token count after the model-axis sequence slice
    S_loc = S // Mx
    T_loc = (B // D) * S_loc
    # per-destination capacity (paper-standard capacity-factor semantics)
    cap = max(cfg.min_capacity,
              int(cfg.capacity_factor * T_loc * K / n_ep))

    def body(x_loc, router_w, wg, wu, wd):
        # x_loc: (B/D, S, d) — model-replicated; take this shard's S-slice
        m_idx = jax.lax.axis_index("model")
        xs = jax.lax.dynamic_slice_in_dim(x_loc, m_idx * S_loc, S_loc, axis=1)
        xt = xs.reshape(T_loc, d)

        scores, weights, ids = _router({"router": router_w}, xt, cfg)
        # load-balance statistics: average the per-expert vectors globally
        # BEFORE the product so the aux loss equals the global formulation
        probs_mean = jnp.mean(scores, axis=0)
        counts = jnp.sum(jax.nn.one_hot(ids, E, dtype=jnp.float32),
                         axis=(0, 1))
        frac = counts / jnp.maximum(1.0, T_loc * K)
        probs_mean = jax.lax.pmean(jax.lax.pmean(probs_mean, "data"), "model")
        frac = jax.lax.pmean(jax.lax.pmean(frac, "data"), "model")
        aux = cfg.aux_loss_weight * E * jnp.sum(frac * probs_mean)

        flat_ids = ids.reshape(-1)                       # (T_loc*K,)
        flat_tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), K)
        dst = flat_ids // e_per_dev                      # owner device
        buf_tok = _fill_buckets(dst, flat_tok, n_ep, cap)   # (n_ep, cap)
        # local expert id of each slot (for the resident sub-dispatch)
        buf_assign = jnp.full((n_ep, cap), -1, jnp.int32)
        order = jnp.argsort(dst)
        s_dst, s_eid = dst[order], flat_ids[order]
        sizes_b = jnp.bincount(dst, length=n_ep)
        offs = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                jnp.cumsum(sizes_b)[:-1].astype(jnp.int32)])
        pos = jnp.arange(dst.shape[0], dtype=jnp.int32) - offs[s_dst]
        keep = pos < cap
        pos = jnp.where(keep, pos, cap)
        buf_assign = buf_assign.at[s_dst, pos].set(
            jnp.where(keep, s_eid % e_per_dev, -1), mode="drop")

        xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
        send = xpad[jnp.where(buf_tok >= 0, buf_tok, T_loc)]  # (n_ep, cap, d)

        def a2a(v):
            # all_to_all over the (possibly joint) expert-parallel axis;
            # tiled: split dim 0 (size n_ep) across the group, re-concat
            return jax.lax.all_to_all(v, ep_axes, split_axis=0,
                                      concat_axis=0, tiled=True)

        recv = a2a(send)                                   # (n_ep, cap, d)
        recv_assign = a2a(buf_assign)                      # (n_ep, cap)

        # resident sub-dispatch: group received rows by local expert
        flat_recv = recv.reshape(n_ep * cap, d)
        flat_assign = recv_assign.reshape(n_ep * cap)
        valid = flat_assign >= 0
        lid = jnp.where(valid, flat_assign, 0)
        onehot = (jax.nn.one_hot(lid, e_per_dev, dtype=flat_recv.dtype)
                  * valid[:, None].astype(flat_recv.dtype))
        grouped = jnp.einsum("nd,ne->end", flat_recv, onehot)  # (e, N, d)?
        # NOTE: for e_per_dev small this dense grouping is cheap and local
        g = f(jnp.einsum("end,edf->enf", grouped, wg.astype(x.dtype)))
        u = jnp.einsum("end,edf->enf", grouped, wu.astype(x.dtype))
        eo = jnp.einsum("enf,efd->end", g * u, wd.astype(x.dtype))
        out_rows = jnp.einsum("end,ne->nd", eo, onehot)    # back to rows
        out_send = out_rows.reshape(n_ep, cap, d)
        out_recv = a2a(out_send)                           # back at source
        out_recv = out_recv.reshape(n_ep, cap, d)

        # combine at source with router weights
        flat_w = weights.reshape(-1).astype(x.dtype)
        w_buf = jnp.zeros((n_ep, cap), x.dtype).at[s_dst, pos].set(
            jnp.where(keep, flat_w[order], 0.0), mode="drop")
        yt = jnp.zeros((T_loc + 1, d), x.dtype).at[
            jnp.where(buf_tok >= 0, buf_tok, T_loc)].add(
            out_recv * w_buf[..., None])
        ys = yt[:T_loc].reshape(B // D, S_loc, d)
        # reassemble the full sequence across the model axis
        y_full = jax.lax.all_gather(ys, "model", axis=1, tiled=True)
        return y_full, aux

    in_specs = (P("data", None, None), P(), P(ep_axes, None, None),
                P(ep_axes, None, None), P(ep_axes, None, None))
    out_specs = (P("data", None, None), P())
    # jax.shard_map / check_vma is the jax>=0.7 spelling; this repo runs on
    # jax 0.4, whose entry point is the experimental one (same semantics,
    # check_rep spelling)
    from jax.experimental.shard_map import shard_map
    body_mapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
    out, aux = body_mapped(x, params["router"], params["wg"], params["wu"],
                           params["wd"])
    if cfg.n_shared_experts:
        from repro.models.layers.mlp import mlp_apply
        out = out + mlp_apply(params["shared"], x, act)
    return out, aux
