"""Composable decoder model: schema construction, full-sequence forward
(train / prefill), KV-cache decode (`serve_step` body), and losses.

A model is: embedding (+ modality projector) -> a list of scanned Segments ->
final norm -> output head(s).  Layers inside a Segment's repeating pattern are
dispatched on :class:`LayerSpec` (mixer x ffn kind).  All parameters are
ParamSpec schemas (see repro.sharding.spec), so dry-run lowering never
allocates.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, LayerSpec, ModelConfig
from repro.models.layers.attention import (attention_apply, attention_decode,
                                           attention_schema, kv_cache_schema)
from repro.models.layers.common import rmsnorm, rmsnorm_schema
from repro.models.layers.mlp import mlp_apply, mlp_schema
from repro.models.layers.moe import moe_apply, moe_schema
from repro.models.layers.rglru import (rglru_block_apply, rglru_block_decode,
                                       rglru_state_schema, rglru_schema)
from repro.models.layers.xlstm import (mlstm_block_apply, mlstm_block_decode,
                                       mlstm_state_schema, mlstm_schema,
                                       slstm_block_apply, slstm_block_decode,
                                       slstm_state_schema, slstm_schema)
from repro.sharding.spec import ParamSpec, stack

VISION_DIM = 1280  # stub ViT output width (qwen2-vl merged patch embedding)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

def layer_schema(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    d = cfg.d_model
    sch: Dict[str, Any] = {"norm_mixer": rmsnorm_schema(d)}
    if spec.mixer in ("attn", "attn_local"):
        sch["attn"] = attention_schema(d, cfg.attn)
    elif spec.mixer == "rglru":
        sch["rglru"] = rglru_schema(d, cfg.rglru)
    elif spec.mixer == "mlstm":
        sch["mlstm"] = mlstm_schema(d, cfg.xlstm)
    elif spec.mixer == "slstm":
        sch["slstm"] = slstm_schema(d, cfg.xlstm)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "mlp":
        sch["norm_ffn"] = rmsnorm_schema(d)
        sch["mlp"] = mlp_schema(d, cfg.d_ff, cfg.act)
    elif spec.ffn == "moe":
        sch["norm_ffn"] = rmsnorm_schema(d)
        sch["moe"] = moe_schema(d, cfg.moe, cfg.act)
    elif spec.ffn != "none":
        raise ValueError(spec.ffn)
    return sch


def model_schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    sch: Dict[str, Any] = {}
    if cfg.n_codebooks > 1:
        sch["embed"] = ParamSpec((cfg.n_codebooks, V, d),
                                 ("codebooks", "vocab", "embed"),
                                 init="embed", scale=0.02)
    else:
        sch["embed"] = ParamSpec((V, d), ("vocab", "embed"),
                                 init="embed", scale=0.02)
    if cfg.vlm:
        sch["vis_proj"] = ParamSpec((VISION_DIM, d), (None, "embed"))
    for si, seg in enumerate(cfg.segments):
        pat = {f"l{i}": layer_schema(cfg, s) for i, s in enumerate(seg.pattern)}
        sch[f"seg{si}"] = stack(pat, seg.repeats, axis_name="layers")
    sch["final_norm"] = rmsnorm_schema(d)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            sch["lm_head"] = ParamSpec((cfg.n_codebooks, d, V),
                                       ("codebooks", "embed", "vocab"))
        else:
            sch["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))
    if cfg.mtp_depth > 0:
        dense_spec = LayerSpec(mixer="attn", ffn="mlp")
        sch["mtp"] = {
            "norm_h": rmsnorm_schema(d),
            "norm_e": rmsnorm_schema(d),
            "proj": ParamSpec((2 * d, d), (None, "embed")),
            "layer": layer_schema(cfg, dense_spec),
            "final_norm": rmsnorm_schema(d),
        }
    return sch


# ---------------------------------------------------------------------------
# Layer application (full sequence)
# ---------------------------------------------------------------------------

def _mixer_window(cfg: ModelConfig, spec: LayerSpec) -> Optional[int]:
    if spec.mixer == "attn_local":
        return cfg.local_window
    return cfg.attn.window if cfg.attn else None


def apply_layer(cfg: ModelConfig, spec: LayerSpec, params, x, positions, aux,
                use_kernels: bool = False, moe_mesh=None):
    h = rmsnorm(params["norm_mixer"], x, cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        h = attention_apply(params["attn"], cfg.attn, h, positions,
                            window=_mixer_window(cfg, spec),
                            use_kernel=use_kernels, mesh=moe_mesh)
    elif spec.mixer == "rglru":
        h = rglru_block_apply(params["rglru"], cfg.rglru, h, cfg.act)
    elif spec.mixer == "mlstm":
        h = mlstm_block_apply(params["mlstm"], cfg.xlstm, h,
                              use_kernel=use_kernels)
    elif spec.mixer == "slstm":
        h = slstm_block_apply(params["slstm"], cfg.xlstm, h)
    x = x + h
    if spec.ffn == "mlp":
        h = rmsnorm(params["norm_ffn"], x, cfg.norm_eps)
        x = x + mlp_apply(params["mlp"], h, cfg.act)
    elif spec.ffn == "moe":
        h = rmsnorm(params["norm_ffn"], x, cfg.norm_eps)
        if moe_mesh is not None:
            from repro.models.layers.moe_a2a import ep_axes_for, moe_apply_a2a
            ep = ep_axes_for(cfg.moe, moe_mesh)
            if ep is not None:
                h, daux = moe_apply_a2a(params["moe"], h, cfg.moe, cfg.act,
                                        moe_mesh, ep)
            else:
                h, daux = moe_apply(params["moe"], h, cfg.moe, cfg.act)
        else:
            h, daux = moe_apply(params["moe"], h, cfg.moe, cfg.act)
        x = x + h
        aux = aux + daux
    return x, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, batch, dtype):
    tokens = batch["tokens"]
    if cfg.n_codebooks > 1:                      # musicgen: (B, K, S)
        x = 0.0
        for k in range(cfg.n_codebooks):
            x = x + params["embed"][k][tokens[:, k]]
    else:
        x = params["embed"][tokens]              # (B, S, d)
    x = x.astype(dtype)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    if cfg.vlm and "image_embeds" in batch:
        img = jnp.einsum("bpv,vd->bpd", batch["image_embeds"].astype(dtype),
                         params["vis_proj"].astype(dtype))
        P = img.shape[1]
        x = jnp.concatenate([img, x[:, P:]], axis=1)
    return x


def output_logits(params, cfg: ModelConfig, x):
    if cfg.n_codebooks > 1:
        w = params["lm_head"]                    # (K, d, V)
        return jnp.einsum("bsd,kdv->bskv", x, w.astype(x.dtype))
    if cfg.tie_embeddings:
        w = params["embed"].T                    # (d, V)
    else:
        w = params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if cfg.final_softcap:
        logits = (cfg.final_softcap
                  * jnp.tanh(logits.astype(jnp.float32) / cfg.final_softcap))
    return logits


def default_positions(cfg: ModelConfig, batch):
    if "positions" in batch:
        return batch["positions"]
    tokens = batch["tokens"]
    B = tokens.shape[0]
    S = tokens.shape[-1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.attn is not None and cfg.attn.mrope_sections is not None:
        pos = jnp.broadcast_to(pos, (3, B, S))
    return pos


# ---------------------------------------------------------------------------
# Full-sequence forward
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, batch, *, use_kernels: bool = False,
            dtype=jnp.bfloat16, remat: bool = True, unroll: bool = False,
            moe_mesh=None):
    """Returns (hidden_states, aux_loss).  `unroll=True` replaces the
    layer-scan with a python loop — used by the roofline harness, where XLA's
    cost_analysis counts scan bodies only once.  `moe_mesh`: pass the device
    mesh to route MoE layers through the explicit all-to-all dispatch."""
    x = embed_tokens(params, cfg, batch, dtype)
    positions = default_positions(cfg, batch)
    aux = jnp.zeros((), jnp.float32)

    for si, seg in enumerate(cfg.segments):
        seg_params = params[f"seg{si}"]

        def body(carry, layer_params, _seg=seg):
            x, aux = carry
            for i, spec in enumerate(_seg.pattern):
                x, aux = apply_layer(cfg, spec, layer_params[f"l{i}"], x,
                                     positions, aux, use_kernels,
                                     moe_mesh=moe_mesh)
            return (x, aux), None

        if remat:
            body = jax.checkpoint(body)
        if seg.repeats == 1:
            first = jax.tree_util.tree_map(lambda p: p[0], seg_params)
            (x, aux), _ = body((x, aux), first)
        elif unroll:
            for r in range(seg.repeats):
                sl = jax.tree_util.tree_map(lambda p, _r=r: p[_r], seg_params)
                (x, aux), _ = body((x, aux), sl)
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux), seg_params)
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return h, aux


def _xent(logits, labels, mask):
    """Cross entropy in fp32.  logits: (..., V); labels int; mask float.

    The label logit is extracted with a masked SUM over the vocab axis, not
    take_along_axis: with vocab sharded over `model`, a gather by label index
    forces GSPMD to all-gather the full logits (tens of GB/step at DeepSeek
    scale), while iota-compare + sum reduces locally per shard and
    all-reduces only the (B, S) result.  See EXPERIMENTS.md §Perf iter A1.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(idx == labels[..., None], logits, 0.0), axis=-1)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params, cfg: ModelConfig, batch, *, use_kernels: bool = False,
            dtype=jnp.bfloat16, unroll: bool = False, moe_mesh=None):
    """Next-token cross-entropy (+ MoE aux, + MTP aux for deepseek)."""
    h, aux = forward(params, cfg, batch, use_kernels=use_kernels, dtype=dtype,
                     unroll=unroll, moe_mesh=moe_mesh)
    tokens = batch["tokens"]
    if cfg.n_codebooks > 1:
        logits = output_logits(params, cfg, h)          # (B, S, K, V)
        labels = tokens[:, :, 1:].swapaxes(1, 2)        # (B, S-1, K)
        mask = jnp.ones(labels.shape[:2], jnp.float32)[..., None]
        loss = _xent(logits[:, :-1], labels, jnp.broadcast_to(mask, labels.shape))
    else:
        logits = output_logits(params, cfg, h)          # (B, S, V)
        labels = tokens[:, 1:]
        mask = jnp.ones(labels.shape, jnp.float32)
        if cfg.vlm and "image_embeds" in batch:
            P = batch["image_embeds"].shape[1]
            pos_ids = jnp.arange(labels.shape[1])
            mask = mask * (pos_ids >= P)[None, :]
        loss = _xent(logits[:, :-1], labels, mask)
    total = loss + aux
    if cfg.mtp_depth > 0 and cfg.n_codebooks == 1:
        total = total + 0.3 * _mtp_loss(params, cfg, h, batch, dtype)
    return total, {"loss": loss, "aux": aux}


def _mtp_loss(params, cfg: ModelConfig, h, batch, dtype):
    """DeepSeek-V3 multi-token prediction (depth 1): combine hidden state at t
    with the embedding of token t+1 to predict token t+2."""
    p = params["mtp"]
    tokens = batch["tokens"]
    emb_next = params["embed"][tokens[:, 1:]].astype(dtype)        # (B, S-1, d)
    h_cur = h[:, :-1]
    merged = jnp.concatenate([rmsnorm(p["norm_h"], h_cur, cfg.norm_eps),
                              rmsnorm(p["norm_e"], emb_next, cfg.norm_eps)],
                             axis=-1)
    x = jnp.einsum("bsd,df->bsf", merged, p["proj"].astype(dtype))
    positions = default_positions(cfg, batch)
    if positions.ndim == 3:
        positions = positions[:, :, : x.shape[1]]
    else:
        positions = positions[:, : x.shape[1]]
    x, _ = apply_layer(cfg, LayerSpec("attn", "mlp"), p["layer"], x,
                       positions, jnp.zeros((), jnp.float32))
    x = rmsnorm(p["final_norm"], x, cfg.norm_eps)
    logits = output_logits(params, cfg, x)                          # (B,S-1,V)
    labels = tokens[:, 2:]
    mask = jnp.ones(labels.shape, jnp.float32)
    return _xent(logits[:, :-1], labels, mask)


# ---------------------------------------------------------------------------
# Decode (serve_step body)
# ---------------------------------------------------------------------------

def cache_schema(cfg: ModelConfig, batch: int, cache_len: int, dtype,
                 kv_quant: bool = False):
    """ParamSpec schema of the full decode cache (segment-stacked).  Derive
    real zeros via ``spec.zeros``, abstract inputs via ``spec.abstract`` and
    shardings via ``spec.partition_specs`` — all from this one tree.
    ``kv_quant``: int8 cache entries + fp16 scales (§Perf iter B4)."""
    caches = {}
    for si, seg in enumerate(cfg.segments):
        def one_layer(spec: LayerSpec):
            if spec.mixer in ("attn", "attn_local"):
                return kv_cache_schema(cfg.attn, batch, cache_len,
                                       _mixer_window(cfg, spec), dtype,
                                       quant=kv_quant)
            if spec.mixer == "rglru":
                return rglru_state_schema(cfg.rglru, batch, dtype)
            if spec.mixer == "mlstm":
                return mlstm_state_schema(cfg.d_model, cfg.xlstm, batch, dtype)
            if spec.mixer == "slstm":
                return slstm_state_schema(cfg.d_model, cfg.xlstm, batch, dtype)
            raise ValueError(spec.mixer)

        pat = {f"l{i}": one_layer(s) for i, s in enumerate(seg.pattern)}
        caches[f"seg{si}"] = stack(pat, seg.repeats, axis_name="layers")
    return caches


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype,
               kv_quant: bool = False):
    from repro.sharding import spec as spec_lib
    return spec_lib.zeros(cache_schema(cfg, batch, cache_len, dtype,
                                       kv_quant=kv_quant))


def apply_layer_decode(cfg: ModelConfig, spec: LayerSpec, params, x, cache,
                       pos, cache_len: int):
    h = rmsnorm(params["norm_mixer"], x, cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        h, new_cache = attention_decode(params["attn"], cfg.attn, h, cache,
                                        pos, window=_mixer_window(cfg, spec),
                                        cache_len=cache_len)
    elif spec.mixer == "rglru":
        h, new_cache = rglru_block_decode(params["rglru"], cfg.rglru, h, cache,
                                          cfg.act)
    elif spec.mixer == "mlstm":
        h, new_cache = mlstm_block_decode(params["mlstm"], cfg.xlstm, h, cache)
    elif spec.mixer == "slstm":
        h, new_cache = slstm_block_decode(params["slstm"], cfg.xlstm, h, cache)
    x = x + h
    if spec.ffn == "mlp":
        h = rmsnorm(params["norm_ffn"], x, cfg.norm_eps)
        x = x + mlp_apply(params["mlp"], h, cfg.act)
    elif spec.ffn == "moe":
        h = rmsnorm(params["norm_ffn"], x, cfg.norm_eps)
        h, _ = moe_apply(params["moe"], h, cfg.moe, cfg.act)
        x = x + h
    return x, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                cache_len: int, dtype=jnp.bfloat16, unroll: bool = False):
    """One decode step.  tokens: (B, 1) (or (B, K, 1) for multi-codebook);
    pos: scalar int32 absolute position.  Returns (logits, new_cache)."""
    x = embed_tokens(params, cfg, {"tokens": tokens}, dtype)
    new_caches = {}
    for si, seg in enumerate(cfg.segments):
        seg_params = params[f"seg{si}"]
        seg_cache = cache[f"seg{si}"]

        def body(x, inp, _seg=seg):
            layer_params, layer_cache = inp
            new_cache = {}
            for i, spec in enumerate(_seg.pattern):
                x, nc = apply_layer_decode(cfg, spec, layer_params[f"l{i}"], x,
                                           layer_cache[f"l{i}"], pos, cache_len)
                new_cache[f"l{i}"] = nc
            return x, new_cache

        if seg.repeats == 1:
            first = jax.tree_util.tree_map(lambda p: p[0],
                                           (seg_params, seg_cache))
            x, nc = body(x, first)
            new_caches[f"seg{si}"] = jax.tree_util.tree_map(
                lambda a: a[None], nc)
        elif unroll:
            ncs = []
            for r in range(seg.repeats):
                sl = jax.tree_util.tree_map(lambda p, _r=r: p[_r],
                                            (seg_params, seg_cache))
                x, nc = body(x, sl)
                ncs.append(nc)
            new_caches[f"seg{si}"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *ncs)
        else:
            x, nc = jax.lax.scan(body, x, (seg_params, seg_cache))
            new_caches[f"seg{si}"] = nc
    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = output_logits(params, cfg, h)
    return logits, new_caches
