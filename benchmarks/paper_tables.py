"""Paper Tables 5 / 6 / 7 on the simulated MIMIC-III (see DESIGN.md §7).

Absolute MSEs are not comparable to the paper (different data — the real
MIMIC-III sits behind a PhysioNet DUA); the CLAIMS under validation are the
paper's orderings:
  T5: HFL ranks best on (most of) the small target domain's tasks,
  T6: HFL stays competitive when the domains swap,
  T7: ablation ordering — selection beats random, switch beats always-on.

Protocol mirrors §5.2 (Adam lr 0.01, batch = R periods, save-best) with a
reduced default budget for the CPU container; REPRO_BENCH_FULL=1 restores
50 epochs / full patient counts / 5 seeds.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core.experiment import run_task, train_hfl
from repro.core.hfl import HFLConfig

OUT = Path(__file__).resolve().parent.parent / "experiments" / "paper"

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))
# 50 epochs is NOT negotiable: the Table-4 heads pass through two sigmoid
# layers and only become load-bearing late in training — below ~30 epochs the
# blend provably cannot influence the final prediction (see EXPERIMENTS.md
# §Repro "Budget sensitivity").  FULL additionally restores paper-scaled
# patient counts and 5 seeds.
EPOCHS = 50
N_PATIENTS = None if FULL else 24      # None -> paper-scaled counts
N_EVENTS = 400 if FULL else 220
SEEDS = (0, 1, 2, 3, 4) if FULL else (0,)
LABELS = (0, 1, 2, 3, 4)


def _cfg(mode="hfl"):
    return HFLConfig(epochs=EPOCHS, mode=mode)


def _avg(runs, key):
    return float(np.mean([r[key] for r in runs]))


def table5_prediction(labels=LABELS):
    """Target = metavision (smaller domain), systems DNN/BIBE/BIBEP/HFL."""
    rows = []
    for lbl in labels:
        per_sys = {}
        for system in ("dnn", "bibe", "bibep", "hfl"):
            runs = [run_task("metavision", lbl, [system], _cfg(), seed=s,
                             n_patients=N_PATIENTS, n_events=N_EVENTS)[system]
                    for s in SEEDS]
            per_sys[system] = {"valid": _avg(runs, "valid"),
                               "test": _avg(runs, "test")}
        ranks = sorted(per_sys, key=lambda s: per_sys[s]["test"])
        rows.append({"label": f"MF{lbl + 1}", **{
            s: per_sys[s] for s in per_sys}, "best": ranks[0]})
    return {"table": "5_prediction", "target": "metavision", "rows": rows,
            "protocol": {"epochs": EPOCHS, "seeds": len(SEEDS), "full": FULL}}


def table6_robustness(labels=LABELS):
    """Domains swapped: target = carevue."""
    rows = []
    for lbl in labels:
        per_sys = {}
        for system in ("dnn", "bibe", "bibep", "hfl"):
            runs = [run_task("carevue", lbl, [system], _cfg(), seed=s,
                             n_patients=N_PATIENTS, n_events=N_EVENTS)[system]
                    for s in SEEDS]
            per_sys[system] = {"valid": _avg(runs, "valid"),
                               "test": _avg(runs, "test")}
        ranks = sorted(per_sys, key=lambda s: per_sys[s]["test"])
        rows.append({"label": f"CF{lbl + 1}", **per_sys, "best": ranks[0]})
    return {"table": "6_robustness", "target": "carevue", "rows": rows,
            "protocol": {"epochs": EPOCHS, "seeds": len(SEEDS), "full": FULL}}


def table7_ablation(labels=LABELS):
    """HFL-No / HFL-Random / HFL-Always / HFL on both hospitals."""
    rows = []
    for target in ("carevue", "metavision"):
        for lbl in labels:
            per_mode = {}
            for mode in ("no", "random", "always", "hfl"):
                runs = [train_hfl(target, lbl, _cfg(mode), seed=s,
                                  n_patients=N_PATIENTS, n_events=N_EVENTS)
                        for s in SEEDS]
                per_mode[mode] = {"test": _avg(runs, "test"),
                                  "rounds": _avg(runs, "rounds")}
            prefix = "CF" if target == "carevue" else "MF"
            rows.append({"label": f"{prefix}{lbl + 1}", "target": target,
                         **per_mode,
                         "best": min(per_mode, key=lambda m:
                                     per_mode[m]["test"])})
    return {"table": "7_ablation", "rows": rows,
            "protocol": {"epochs": EPOCHS, "seeds": len(SEEDS), "full": FULL}}


def run_all(labels=LABELS, tables=("5", "6", "7")):
    OUT.mkdir(parents=True, exist_ok=True)
    results = {}
    fns = {"5": table5_prediction, "6": table6_robustness,
           "7": table7_ablation}
    for t in tables:
        t0 = time.time()
        res = fns[t](labels)
        res["elapsed_s"] = round(time.time() - t0, 1)
        (OUT / f"table{t}.json").write_text(json.dumps(res, indent=1))
        results[t] = res
        print(f"[paper] table{t} done in {res['elapsed_s']}s", flush=True)
    return results


if __name__ == "__main__":
    import sys
    labels = LABELS if len(sys.argv) < 2 else tuple(
        int(x) for x in sys.argv[1].split(","))
    out = run_all(labels)
    for t, res in out.items():
        print(f"== table {t} ==")
        for row in res["rows"]:
            print(json.dumps(row))
