"""Membership-inference benchmark for the DP trust layer.

  PYTHONPATH=src python -m benchmarks.privacy_bench [--sigmas 0.3,1,2]

Runs a federation of deterministic random-tensor hospitals whose labels are
PURE noise (``tensor_population`` draws y independent of x), so the only way
any head lowers its training error is by memorizing individual examples —
the worst case for release privacy and the cleanest target for a membership
attack.  The geometry is deliberately overfit-friendly (tiny train split,
many epochs, lr above the paper default) so the no-DP attack has signal.

The attacker is strong: they observe the public head pool AND are granted
the victim's local body (embedding + prediction nets) and seed-deterministic
init heads.  Granting the body is what isolates the RELEASE pathway — body
memorization appears identically in both terms of the score and cancels:

  score(example) = prelim_err(init_heads, example)
                 - prelim_err(published_heads, example)

i.e. how much the published (Eq. 7 preliminary-task) error on that example
improved over init.  Member examples shaped the head trajectory, so their
error improves more; every bit of that signal flows through the published
heads, which is exactly the object ``repro.core.trust.DPNoise`` clips and
noises.  Per client, member scores (train split) are ranked against
non-member scores (a held-out split the client never trained on) with the
Mann-Whitney AUC; the benchmark row reports the mean over clients.

Expected shape of the curve (pinned loosely by tests/CI): the no-DP row
sits meaningfully above 0.5 (~0.73 at the default geometry) and every
DP-on row collapses to ~0.5 while ``epsilon_spent`` composes analytically
across the run's releases.  ``--smoke`` shrinks epochs for CI, where the
DP-on rows keep their near-0.5 AUC (privacy holds at any training length)
even though the no-DP signal is weaker.

Writes ``BENCH_privacy.json`` at the repo root (``--out`` to redirect,
``--out ""`` to disable); :func:`validate_payload` pins its schema and
tests/test_bench_schema.py re-validates the committed file.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO_ROOT / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import networks as N
from repro.core import trust as TR
from repro.core.experiment import tensor_population
from repro.core.federation import Federation
from repro.core.hfl import HFLConfig


def mann_whitney_auc(pos, neg) -> float:
    """P(pos > neg) + 0.5 P(pos == neg) over all pairs — the rank-sum AUC
    of the membership classifier ``score > t`` swept over thresholds."""
    pos, neg = np.asarray(pos, np.float64), np.asarray(neg, np.float64)
    gt = (pos[:, None] > neg[None, :]).mean()
    eq = (pos[:, None] == neg[None, :]).mean()
    return float(gt + 0.5 * eq)


def prelim_errors(heads, split) -> np.ndarray:
    """Per-example preliminary-task error sum_f (y - H_f(xd_f))^2 — the
    head-only prediction pathway (Eq. 7), no body involved."""
    _, xd, y = split
    y_prelim = jax.vmap(N.head_apply, in_axes=(0, 1), out_axes=1)(
        heads, jnp.asarray(xd))
    return np.asarray(((jnp.asarray(y)[:, None] - y_prelim) ** 2).sum(-1))


def attack_federation(fed: Federation, init_heads: dict) -> float:
    """Mean per-client membership AUC against the post-fit public pool."""
    aucs = []
    for cl in fed.clients:
        rows = [fed.pool.entries[(cl.name, f)] for f in range(cl.nf)]
        pub = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *rows)
        h0 = jax.tree_util.tree_map(jnp.asarray, init_heads[cl.name])
        member = prelim_errors(h0, cl.train) - prelim_errors(pub, cl.train)
        non = prelim_errors(h0, cl.test) - prelim_errors(pub, cl.test)
        aucs.append(mann_whitney_auc(member, non))
    return float(np.mean(aucs))


def run_point(args, dp: "TR.DPNoise | None") -> dict:
    cfg = HFLConfig(epochs=args.epochs, R=args.R, mode="always",
                    seed=args.seed, lr=args.lr)
    pop = tensor_population(args.clients, cfg, seed=args.seed,
                            nf_choices=(args.nf,), n_train=args.n_train,
                            n_eval=args.n_eval).build(range(args.clients))
    trust = TR.TrustPlan(dp=dp) if dp is not None else None
    fed = Federation(pop, cfg, engine=args.engine, trust=trust)
    init_heads = {cl.name: jax.tree_util.tree_map(np.array,
                                                  cl.params["heads"])
                  for cl in fed.clients}
    hist = fed.fit()
    stats = fed.dispatch_stats
    releases = sum(fed._dp_counts.values()) if dp is not None else 0
    return {
        "dp": dp is not None,
        "sigma": float(dp.sigma) if dp is not None else 0.0,
        "clip": float(dp.clip) if dp is not None else None,
        "epsilon": float(stats.get("epsilon_spent", 0.0)),
        "releases": int(releases),
        "clip_events": int(stats.get("clip_events", 0)),
        "attack_auc": attack_federation(fed, init_heads),
        "mean_val": float(np.mean([hist[n]["val"][-1] for n in hist])),
    }


def validate_payload(payload: dict) -> None:
    """Structural schema check for BENCH_privacy.json — mirrored by
    tests/test_bench_schema.py so the schema can't drift silently."""
    def need(obj, key, types, where):
        if key not in obj:
            raise ValueError(f"{where}: missing key {key!r}")
        if not isinstance(obj[key], types):
            raise ValueError(f"{where}[{key!r}]: expected {types}, "
                             f"got {type(obj[key]).__name__}")

    need(payload, "benchmark", str, "payload")
    if payload["benchmark"] != "privacy":
        raise ValueError(f"payload[benchmark]: {payload['benchmark']!r}")
    need(payload, "unix_time", int, "payload")
    need(payload, "backend", str, "payload")
    need(payload, "device_count", int, "payload")
    need(payload, "platform", str, "payload")
    need(payload, "config", dict, "payload")
    need(payload, "results", list, "payload")
    cfg = payload["config"]
    for k in ("clients", "epochs", "R", "nf", "n_train", "n_eval", "seed"):
        need(cfg, k, int, "config")
    need(cfg, "lr", (int, float), "config")
    need(cfg, "clip", (int, float), "config")
    need(cfg, "delta", (int, float), "config")
    need(cfg, "engine", str, "config")
    need(cfg, "sigmas", list, "config")
    if not all(isinstance(s, (int, float)) and s > 0
               for s in cfg["sigmas"]):
        raise ValueError("config[sigmas]: expected positive numbers")
    if not payload["results"]:
        raise ValueError("results: empty")
    for i, r in enumerate(payload["results"]):
        where = f"results[{i}]"
        need(r, "dp", bool, where)
        need(r, "sigma", (int, float), where)
        need(r, "clip", (int, float, type(None)), where)
        need(r, "epsilon", (int, float), where)
        need(r, "releases", int, where)
        need(r, "clip_events", int, where)
        need(r, "attack_auc", (int, float), where)
        need(r, "mean_val", (int, float), where)
        if not 0.0 <= r["attack_auc"] <= 1.0:
            raise ValueError(f"{where}[attack_auc]: must be in [0, 1], "
                             f"got {r['attack_auc']}")
        if r["releases"] < 0 or r["clip_events"] < 0:
            raise ValueError(f"{where}: DP counters must be >= 0")
        if r["dp"]:
            if r["epsilon"] <= 0 or r["releases"] <= 0:
                raise ValueError(f"{where}: DP-on rows must spend epsilon")
            if r["sigma"] <= 0 or not r["clip"]:
                raise ValueError(f"{where}: DP-on rows need sigma/clip > 0")
        else:
            if r["epsilon"] != 0 or r["sigma"] != 0:
                raise ValueError(f"{where}: DP-off rows must not spend "
                                 f"epsilon")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--R", type=int, default=8)
    ap.add_argument("--nf", type=int, default=3)
    ap.add_argument("--n-train", type=int, default=8)
    ap.add_argument("--n-eval", type=int, default=40)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="batched",
                    choices=("sequential", "batched"))
    ap.add_argument("--clip", type=float, default=5.0)
    ap.add_argument("--delta", type=float, default=1e-5)
    ap.add_argument("--sigmas", default="0.3,1.0,2.0",
                    help="comma-separated DP noise multipliers; a no-DP "
                    "row is always emitted first")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: 12 epochs, one DP point")
    ap.add_argument("--out", default=str(_REPO_ROOT / "BENCH_privacy.json"))
    args = ap.parse_args(argv)
    if args.smoke:
        args.epochs, args.n_eval, args.sigmas = 12, 20, "1.0"
    sigmas = [float(s) for s in args.sigmas.split(",") if s]

    print("dp,sigma,epsilon,releases,clip_events,attack_auc,mean_val",
          flush=True)
    records = []
    for dp in [None] + [TR.DPNoise(clip=args.clip, sigma=s,
                                   delta=args.delta, seed=args.seed)
                        for s in sigmas]:
        r = run_point(args, dp)
        records.append(r)
        print(f"{int(r['dp'])},{r['sigma']:g},{r['epsilon']:.3f},"
              f"{r['releases']},{r['clip_events']},{r['attack_auc']:.4f},"
              f"{r['mean_val']:.4f}", flush=True)

    if args.out:
        payload = {
            "benchmark": "privacy",
            "unix_time": int(time.time()),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "config": {"clients": args.clients, "epochs": args.epochs,
                       "R": args.R, "nf": args.nf,
                       "n_train": args.n_train, "n_eval": args.n_eval,
                       "lr": args.lr, "seed": args.seed,
                       "engine": args.engine, "clip": args.clip,
                       "delta": args.delta, "sigmas": sigmas},
            "results": records,
        }
        validate_payload(payload)
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
