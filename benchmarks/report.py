"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/.  (The narrative sections of EXPERIMENTS.md are
hand-written; this keeps the big tables regenerable.)

  PYTHONPATH=src python -m benchmarks.report [--write]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DRY = ROOT / "experiments" / "dryrun"
ROOF = ROOT / "experiments" / "roofline"

ARCH_ORDER = ["qwen3-0.6b", "deepseek-v3-671b", "olmoe-1b-7b",
              "recurrentgemma-2b", "gemma2-9b", "granite-3-2b",
              "granite-3-8b", "qwen2-vl-7b", "musicgen-medium", "xlstm-350m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt(x, nd=2):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.{nd}e}"


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | per-dev FLOPs* | per-dev bytes* | coll bytes | "
        "args/dev | temp/dev | compile |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                p = DRY / f"{arch}__{shape}__{mesh}.json"
                if not p.exists():
                    continue
                d = json.loads(p.read_text())
                m = d["memory"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {_fmt(d['flops'])} | "
                    f"{_fmt(d['bytes_accessed'])} | "
                    f"{_fmt(d['collective_bytes'].get('total', 0))} | "
                    f"{_fmt(m.get('argument_size'))} | "
                    f"{_fmt(m.get('temp_size'))} | "
                    f"{d.get('compile_s', 0):.1f}s |")
    lines.append("")
    lines.append("\\* scan bodies counted once by XLA — see §Roofline/Method "
                 "for depth-corrected totals.")
    return "\n".join(lines)


def blend_table() -> str:
    lines = [
        "| arch | shared fraction | blend coll bytes | blend FLOPs |",
        "|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        p = DRY / f"{arch}__blend__2x16x16.json"
        if not p.exists():
            continue
        d = json.loads(p.read_text())
        lines.append(f"| {arch} | {d['shared_fraction']:.3f} | "
                     f"{_fmt(d['collective_bytes'].get('total', 0))} | "
                     f"{_fmt(d['flops'])} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL_FLOPS | useful ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = ROOF / f"{arch}__{shape}.json"
            if not p.exists():
                continue
            d = json.loads(p.read_text())
            rows.append(d)
            lines.append(
                f"| {arch} | {shape} | {_fmt(d['compute_s'], 3)} | "
                f"{_fmt(d['memory_s'], 3)} | {_fmt(d['collective_s'], 3)} | "
                f"**{d['dominant']}** | {_fmt(d['model_flops'])} | "
                f"{d['useful_ratio']:.3f} |")
    # summary of dominant terms
    from collections import Counter
    c = Counter(r["dominant"] for r in rows)
    lines.append("")
    lines.append(f"Dominant-term census over {len(rows)} pairs: "
                 + ", ".join(f"{k}: {v}" for k, v in c.most_common()))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", choices=["dryrun", "roofline", "blend",
                                          "all"], default="all")
    args = ap.parse_args()
    if args.section in ("dryrun", "all"):
        print("### Dry-run table (per-device, compiled HLO)\n")
        print(dryrun_table())
        print("\n### HFL blend step (multi-pod)\n")
        print(blend_table())
    if args.section in ("roofline", "all"):
        print("\n### Roofline table (single-pod 16x16, depth-corrected)\n")
        print(roofline_table())


if __name__ == "__main__":
    main()
