"""Federated-round scaling benchmark: sequential oracle vs batched engine.

  PYTHONPATH=src python -m benchmarks.fl_scale_bench [--clients 2,8,32,128]

Sweeps the number of simulated hospitals and reports, per engine, the mean
wall time of one federated sub-round (train step + selection + blend +
publication for every client) and the round throughput in client-rounds/s.
The sequential engine dispatches C train steps, C x nf pool scorings, and
C x nf host-side argmin syncs per sub-round; the batched engine dispatches
one vmapped step and one fused scan.  Each engine run is preceded by an
identically-shaped warmup run so compile time is excluded.

Uses deterministic random tensors (not the synthetic-hospital generator) so
the sweep measures the engine, not data generation; ``--population`` switches
to `repro.data.synthetic.make_population` data instead.

Besides the CSV on stdout, writes a machine-readable ``BENCH_fl_scale.json``
at the repo root (``--out`` to redirect, ``--out ""`` to disable) so the
perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO_ROOT / "src"))

import jax
import numpy as np

from repro.core.federation import Federation
from repro.core.hfl import FederatedClient, HFLConfig


def _make_clients(C: int, cfg: HFLConfig, nf: int, n: int, w: int,
                  population: bool):
    if population:
        from repro.core.experiment import population_task_data
        # ~1/5 of events are label ticks, so size the streams to give each
        # patient enough packed samples for the requested sub-round count
        packs = population_task_data(C, w, seed=0, n_patients=6,
                                     n_events=max(10 * n, 300), nf=nf)
        return [FederatedClient(p["name"], nf, cfg, p["train"], p["valid"],
                                p["test"], jax.random.PRNGKey(31 * i))
                for i, p in enumerate(packs)]
    out = []
    for i in range(C):
        rng = np.random.default_rng(1000 + i)
        mk = lambda m: (rng.normal(size=(m, nf, w)).astype(np.float32),
                        rng.normal(size=(m, nf, w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"h{i:03d}", nf, cfg, mk(n), mk(2 * cfg.R),
                                   mk(2 * cfg.R), jax.random.PRNGKey(i)))
    return out


def _run_once(engine: str, C: int, cfg: HFLConfig, nf: int, n: int,
              population: bool):
    clients = _make_clients(C, cfg, nf, n, cfg.w, population)
    # population data has a data-dependent (truncated) length, so the
    # sub-round count must come from the actual tensors, not from n
    n_eff = len(clients[0].train[2])
    sub_rounds = cfg.epochs * max(0, (n_eff - cfg.R) // cfg.R + 1)
    if sub_rounds == 0:
        raise SystemExit(
            f"train split too short for a single sub-round "
            f"(n={n_eff} < R={cfg.R}); raise --batches or the data sizes")
    t0 = time.perf_counter()
    hist = Federation(clients, cfg, engine=engine).fit()
    elapsed = time.perf_counter() - t0
    total_rounds = sum(h["rounds"] for h in hist.values())
    assert total_rounds == C * sub_rounds, (total_rounds, C, sub_rounds)
    return elapsed, sub_rounds


def bench(engine: str, C: int, cfg: HFLConfig, nf: int, n: int,
          population: bool):
    _run_once(engine, C, cfg, nf, n, population)          # warmup + compile
    elapsed, sub_rounds = _run_once(engine, C, cfg, nf, n, population)
    return {
        "round_ms": 1e3 * elapsed / sub_rounds,           # all C clients
        "client_rounds_per_s": C * sub_rounds / elapsed,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="2,8,32,128")
    ap.add_argument("--engines", default="sequential,batched")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--R", type=int, default=20)
    ap.add_argument("--nf", type=int, default=4)
    ap.add_argument("--batches", type=int, default=3,
                    help="train sub-rounds per epoch")
    ap.add_argument("--population", action="store_true",
                    help="use generated N-hospital data instead of random "
                         "tensors")
    ap.add_argument("--out", default=str(_REPO_ROOT / "BENCH_fl_scale.json"),
                    help="machine-readable results path (empty to disable)")
    args = ap.parse_args()
    counts = [int(x) for x in args.clients.split(",")]
    engines = args.engines.split(",")
    cfg = HFLConfig(mode="always", epochs=args.epochs, R=args.R)
    n = args.batches * args.R

    records = []
    print("clients,engine,round_ms,client_rounds_per_s,speedup_vs_sequential")
    for C in counts:
        rows = {}
        for engine in engines:
            rows[engine] = bench(engine, C, cfg, args.nf, n, args.population)
        for engine in engines:
            r = rows[engine]
            speedup = (r["client_rounds_per_s"]
                       / rows["sequential"]["client_rounds_per_s"]
                       if "sequential" in rows else float("nan"))
            print(f"{C},{engine},{r['round_ms']:.2f},"
                  f"{r['client_rounds_per_s']:.1f},{speedup:.2f}",
                  flush=True)
            records.append({"clients": C, "engine": engine,
                            "round_ms": r["round_ms"],
                            "client_rounds_per_s": r["client_rounds_per_s"],
                            "speedup_vs_sequential":
                                None if speedup != speedup else speedup})
    if args.out:
        payload = {
            "benchmark": "fl_scale",
            "unix_time": int(time.time()),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "config": {"epochs": args.epochs, "R": args.R, "nf": args.nf,
                       "batches": args.batches, "mode": cfg.mode,
                       "population": bool(args.population),
                       "clients": counts, "engines": engines},
            "results": records,
        }
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
