"""Federated-round scaling benchmark: sequential oracle vs batched engine.

  PYTHONPATH=src python -m benchmarks.fl_scale_bench [--clients 2,8,32,128]

Sweeps the number of simulated hospitals and reports, per engine, the mean
wall time of one federated sub-round (train step + selection + blend +
publication for every client), the round throughput in client-rounds/s, and
the number of compiled-function dispatches per epoch.  The sequential
engine dispatches C train steps, C x nf pool scorings, and C x nf host-side
argmin syncs per sub-round; the batched engine scans the WHOLE epoch inside
one jitted dispatch (train steps, policy rounds, eval, save-best merge)
with donated state buffers.  Each engine run is preceded by an
identically-shaped warmup run so compile time is excluded.

``--mesh`` adds a ``batched+mesh`` row per client count: the same fused
epoch, client-sharded over a `clients` device mesh spanning every local
device (see `repro.core.mesh_federation` and docs/SCALING.md) — the
devices x clients scaling axis.  ``--force-devices N`` splits the host CPU
into N virtual devices (must be handled before jax initializes, so it is
read straight from argv) to exercise the sharded path without
accelerators; client counts not divisible by the device count skip the
mesh row.

``--exchange-every 1,2`` sweeps bounded-staleness cadences
(``RoundSchedule.exchange_every``): heads are exchanged every k-th
sub-round, so each row also reports ``exchange_rounds`` and the analytic
``pool_bytes_gathered`` comms counter from ``dispatch_stats``.  The
sequential oracle runs only at k=1 (the speedup baseline), and
``--max-seq-clients`` skips it entirely above a client count (its Python
loop dominates at large C; speedup becomes null).  Throughput counts TRAIN
sub-rounds at every cadence, so rows at different k measure the same work.

Uses deterministic random tensors (not the synthetic-hospital generator) so
the sweep measures the engine, not data generation; ``--population`` switches
to `repro.data.synthetic.make_population` data instead.  ``--profile`` adds
a per-phase (train / policy / eval) wall-time split of the batched engine's
building blocks at each client count.

``--hetero`` additionally sweeps a MIXED-nf population (feature counts
cycling nf-1 / nf / nf+1 — up to three cohorts): the batched engine routes it
through the cohort subsystem (`repro.core.cohorts` — per-cohort stacks, one
fused dispatch per epoch, padded union-pool exchange) while the sequential
oracle remains the only other engine that can run it at all.  Those rows
are tagged ``hetero: true`` and carry the cohort count, and their
speedup-vs-sequential column is computed within the hetero pair.

``--population-size N`` adds a SAMPLED-PARTICIPATION row per cadence: a
lazy ``tensor_population`` of N clients (declared in O(N) metadata — no
tensors materialize until sampled) trained through
`repro.core.participation.ParticipatingFederation`, with ``--fraction`` /
``--participation {uniform,weighted,stratified}`` / ``--waves`` shaping
the policy.  Those rows report the POPULATION columns every row now
carries: ``population`` (total declared clients), ``participation_fraction``,
``resident_clients`` and ``resident_state_bytes`` (the peak device-resident
learnable state — the bounded-working-set meter; full-population rows
report their own C / 1.0 / C / state_bytes).  This is how the 100k-client
row in BENCH_fl_scale.json is produced.

``--fault-rate 0,0.2,0.4`` (with ``--population-size``) adds one
fault-injected participation row per rate — a seeded
`repro.core.faults.FaultPlan` with that per-wave dropout probability and
``--byzantine-frac`` NaN-head corruption — emitting the
graceful-degradation curve: every row carries ``fault_rate`` /
``byzantine_frac`` / ``heads_rejected`` / ``waves_degraded`` / ``mean_val``
(final-wave mean validation MSE over finite clients), 0 / 0 / 0 / 0 / null
on faultless rows.

Besides the CSV on stdout, writes a machine-readable ``BENCH_fl_scale.json``
at the repo root (``--out`` to redirect, ``--out ""`` to disable;
:func:`validate_payload` pins its schema, and CI smoke-runs a tiny sweep
against it) so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import warnings
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO_ROOT / "src"))


def _force_devices_from_argv() -> None:
    """Apply ``--force-devices N`` BEFORE jax first initializes — jax locks
    the host platform device count at first init, so argparse (which runs
    after the imports below) would be too late.  Accepts both the
    space-separated and ``--force-devices=N`` spellings; a missing value
    is left for argparse to report."""
    n = None
    for i, arg in enumerate(sys.argv):
        if arg == "--force-devices" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
        elif arg.startswith("--force-devices="):
            n = arg.split("=", 1)[1]
    if n is None:
        return
    try:
        count = int(n)
    except ValueError:
        count = -1
    if count < 1:
        raise SystemExit(f"--force-devices must be a positive integer, "
                         f"got {n!r}")
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={count}").strip()


_force_devices_from_argv()

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import FederatedClient, HFLConfig
from repro.core.mesh_federation import make_mesh, mesh_devices
from repro.core.telemetry import metric_spec


def _make_clients(C: int, cfg: HFLConfig, nf: int, n: int, w: int,
                  population: bool, hetero: bool = False):
    if population:
        if hetero:
            from repro.core.experiment import hetero_population_clients
            clients, _ = hetero_population_clients(
                C, cfg, seed=0, n_patients=6, n_events=max(10 * n, 300),
                nf_choices=(max(1, nf - 1), nf, nf + 1))
            return clients
        from repro.core.experiment import population_task_data
        # ~1/5 of events are label ticks, so size the streams to give each
        # patient enough packed samples for the requested sub-round count
        packs = population_task_data(C, w, seed=0, n_patients=6,
                                     n_events=max(10 * n, 300), nf=nf)
        return [FederatedClient(p["name"], nf, cfg, p["train"], p["valid"],
                                p["test"], jax.random.PRNGKey(31 * i))
                for i, p in enumerate(packs)]
    out = []
    # --hetero: mixed feature counts cycling (nf-1, nf, nf+1) — 3 cohorts
    # of ~C/3 clients on the batched engine's cohort path (lengths stay
    # uniform so the client-round accounting below holds exactly)
    nfs = [max(1, nf - 1), nf, nf + 1] if hetero else [nf]
    for i in range(C):
        nf_i = nfs[i % len(nfs)]
        rng = np.random.default_rng(1000 + i)
        mk = lambda m, nf_i=nf_i: (
            rng.normal(size=(m, nf_i, w)).astype(np.float32),
            rng.normal(size=(m, nf_i, w)).astype(np.float32),
            rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"h{i:03d}", nf_i, cfg, mk(n),
                                   mk(2 * cfg.R), mk(2 * cfg.R),
                                   jax.random.PRNGKey(i)))
    return out


def _run_once(engine: str, C: int, cfg: HFLConfig, nf: int, n: int,
              population: bool, mesh=None, hetero: bool = False,
              exchange_every: int = 1, telemetry=None):
    clients = _make_clients(C, cfg, nf, n, cfg.w, population, hetero)
    # population (and hetero) data has data-dependent per-client lengths,
    # so the expected round counts come from the actual tensors, not n
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=exchange_every)
    train_per_client = [cfg.epochs * sched.sub_rounds(len(c.train[2]))
                        for c in clients]
    # under a k-cadence a client participates in sub_rounds // k exchanges
    # per epoch — what the engines' per-client round counters track
    exch_per_client = [
        cfg.epochs * (sched.sub_rounds(len(c.train[2])) // exchange_every)
        for c in clients]
    if not any(train_per_client):
        raise SystemExit(
            f"train splits too short for a single sub-round "
            f"(< R={cfg.R} events); raise --batches or the data sizes")
    fed = Federation(clients, cfg, engine=engine, mesh=mesh, schedule=sched,
                     telemetry=telemetry)
    t0 = time.perf_counter()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)   # ragged-length drop
        hist = fed.fit()
    elapsed = time.perf_counter() - t0
    total_rounds = sum(h["rounds"] for h in hist.values())
    assert total_rounds == sum(exch_per_client), (total_rounds,
                                                  exch_per_client)
    # global sub-rounds executed = the longest client's (epochs x per-epoch);
    # throughput counts TRAIN sub-rounds (k-independent, so rows at
    # different cadences measure the same work)
    sub_rounds = max(train_per_client)
    return elapsed, sub_rounds, sum(train_per_client), fed.dispatch_stats


def bench(engine: str, C: int, cfg: HFLConfig, nf: int, n: int,
          population: bool, mesh=None, hetero: bool = False,
          exchange_every: int = 1):
    _run_once(engine, C, cfg, nf, n, population, mesh, hetero,
              exchange_every)                                     # warmup
    elapsed, sub_rounds, train_rounds, dispatch = _run_once(
        engine, C, cfg, nf, n, population, mesh, hetero, exchange_every)
    return {
        "round_ms": 1e3 * elapsed / sub_rounds,           # all C clients
        "client_rounds_per_s": train_rounds / elapsed,
        "dispatches_per_epoch": dispatch["dispatches_per_epoch"],
        "dispatch_path": dispatch["path"],
        "devices": dispatch.get("devices", 1),
        "cohorts": dispatch.get("cohorts", 1),
        "exchange_every": dispatch.get("exchange_every", 1),
        "exchange_rounds": dispatch.get("exchange_rounds", 0),
        "pool_bytes_gathered": dispatch.get("pool_bytes_gathered", 0),
        # full-population run: everyone is resident every round
        "population": C,
        "participation_fraction": 1.0,
        "resident_clients": C,
        "resident_state_bytes": int(dispatch.get("state_bytes", 0)),
    }


_PARTICIPATIONS = {"uniform": "UniformParticipation",
                   "weighted": "WeightedParticipation",
                   "stratified": "StratifiedParticipation"}


def _run_sampled(args, cfg: HFLConfig, n: int, exchange_every: int,
                 faults=None):
    from repro.core import participation as PT
    from repro.core.experiment import tensor_population

    pop = tensor_population(args.population_size, cfg, seed=0,
                            nf_choices=(args.nf,), n_train=n,
                            n_eval=2 * cfg.R,
                            weighted_sizes=args.participation == "weighted")
    policy_cls = getattr(PT, _PARTICIPATIONS[args.participation])
    pf = PT.ParticipatingFederation(
        pop, cfg,
        participation=policy_cls(fraction=args.fraction, min_clients=2),
        schedule=RoundSchedule(args.waves, cfg.R,
                               exchange_every=exchange_every),
        faults=faults)
    t0 = time.perf_counter()
    pf.fit()
    elapsed = time.perf_counter() - t0
    st = pf.dispatch_stats
    # throughput counts TRAIN sub-rounds (k-independent), same as bench():
    # each resident client trains sub_rounds-per-epoch rounds per wave
    sub = RoundSchedule(1, cfg.R).sub_rounds(n)
    train_rounds = sum(len(w["active"]) * sub for w in pf.wave_log)
    mean_val = pf.wave_log[-1]["mean_val"] if pf.wave_log else None
    return elapsed, args.waves * sub, train_rounds, st, mean_val


def bench_sampled(args, cfg: HFLConfig, n: int, exchange_every: int,
                  faults=None):
    """One sampled-participation row: warmup run (compile — the stratified
    sampler keeps every wave's cohort geometry identical, so one warmup
    covers all waves), then the measured run.  ``faults`` (a
    :class:`repro.core.faults.FaultPlan`) makes it a graceful-degradation
    row: the row carries the fault rates, the rejection/degradation
    counters, and the final wave's mean validation MSE."""
    _run_sampled(args, cfg, n, exchange_every, faults)            # warmup
    elapsed, sub_rounds, train_rounds, st, mean_val = _run_sampled(
        args, cfg, n, exchange_every, faults)
    return {
        "round_ms": 1e3 * elapsed / sub_rounds,
        "client_rounds_per_s": train_rounds / elapsed,
        "dispatches_per_epoch": st["dispatches_per_epoch"],
        "dispatch_path": st["path"],
        "devices": st["devices"],
        "cohorts": st["cohorts"],
        "exchange_every": st["exchange_every"],
        "exchange_rounds": st["exchange_rounds"],
        "pool_bytes_gathered": st["pool_bytes_gathered"],
        "population": st["population"],
        "participation_fraction": st["participation_fraction"],
        "resident_clients": st["resident_clients"],
        "resident_state_bytes": st["resident_state_bytes"],
        "fault_rate": float(faults.dropout) if faults is not None else 0.0,
        "byzantine_frac": (float(faults.byzantine)
                           if faults is not None else 0.0),
        "heads_rejected": int(st.get("heads_rejected", 0)),
        "waves_degraded": int(st.get("waves_degraded", 0)),
        "mean_val": (None if mean_val is None or mean_val != mean_val
                     else float(mean_val)),
    }


def profile_phases(C: int, cfg: HFLConfig, nf: int, n: int,
                   population: bool, repeats: int = 20):
    """Per-phase wall time of the batched engine's building blocks at this
    client count: one vmapped train step, one fused policy round, one
    vmapped eval — the three phases the fused epoch scan stitches together.
    Returns per-dispatch microseconds plus each phase's share of an epoch
    (train and policy run once per sub-round, eval once per epoch)."""
    from repro.core.federation import (_make_batched_fns, _stack_trees,
                                       fused_policy_round, stack_pool)
    from repro.core.policies import FederationPolicies

    clients = _make_clients(C, cfg, nf, n, cfg.w, population)
    pol = FederationPolicies.from_config(cfg)
    R = cfg.R
    xs = jnp.stack([np.asarray(c.train[0][:R]) for c in clients])
    xd = jnp.stack([np.asarray(c.train[1][:R]) for c in clients])
    y = jnp.stack([np.asarray(c.train[2][:R]) for c in clients])
    val = tuple(jnp.stack([np.asarray(c.valid[k]) for c in clients])
                for k in range(3))
    params = _stack_trees([c.params for c in clients])
    opt_state = _stack_trees([c.opt_state for c in clients])
    # the engine's own stacked-pool layout, from a Federation's initial
    # publication — profiled shapes cannot drift from executed shapes
    fed = Federation(clients, cfg)
    pool_heads = stack_pool(fed.pool, [c.name for c in clients], nf)
    pool_age = jnp.zeros(C, jnp.int32)
    active = jnp.ones(C, bool)
    key = jax.random.PRNGKey(0)
    step_fn, eval_fn = _make_batched_fns(cfg.lr)

    def timed(fn):
        jax.block_until_ready(fn())                       # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn()
        jax.block_until_ready(out)
        return 1e6 * (time.perf_counter() - t0) / repeats

    train_us = timed(lambda: step_fn(params, opt_state, xs, xd, y))
    policy_us = timed(lambda: fused_policy_round(
        params["heads"], pool_heads, pool_age, xd, y, active, key,
        nf=nf, policies=pol, use_kernel=False))
    eval_us = timed(lambda: eval_fn(params, *val))

    n_eff = len(clients[0].train[2])
    sub = RoundSchedule(cfg.epochs, R).sub_rounds(n_eff)
    epoch_us = sub * (train_us + policy_us) + eval_us
    return {
        "train_us_per_round": train_us,
        "policy_us_per_round": policy_us,
        "eval_us_per_epoch": eval_us,
        "sub_rounds_per_epoch": sub,
        "phase_split": {
            "train": sub * train_us / epoch_us,
            "policy": sub * policy_us / epoch_us,
            "eval": eval_us / epoch_us,
        },
    }


def bench_telemetry_overhead(C: int, cfg: HFLConfig, nf: int, n: int,
                             population: bool, repeats: int = 5) -> dict:
    """--telemetry: the metrics-carry cost row.  Runs the fused batched
    epoch with the in-graph telemetry carry ON vs OFF and reports the
    throughput regression — the number the <3% acceptance gate in CI
    checks.  The carry adds four small per-round outputs to the epoch
    scan; the epoch still compiles to ONE dispatch either way.

    Measurement discipline: one compile warmup apiece, then the on/off
    timings are INTERLEAVED (off, on, off, on, ...) so slow machine-load
    drift hits both arms equally, and each arm reports its best (noise
    floor) throughput over ``repeats`` runs."""
    from repro.core.telemetry import TelemetryPlan

    plans = {"off": None, "on": TelemetryPlan()}
    for telemetry in plans.values():                            # warmups
        _run_once("batched", C, cfg, nf, n, population, telemetry=telemetry)
    thr = {"off": [], "on": []}
    for _ in range(repeats):
        for arm, telemetry in plans.items():
            elapsed, _, train_rounds, _ = _run_once(
                "batched", C, cfg, nf, n, population, telemetry=telemetry)
            thr[arm].append(train_rounds / elapsed)
    off, on = max(thr["off"]), max(thr["on"])
    return {"clients": C,
            "on_client_rounds_per_s": on,
            "off_client_rounds_per_s": off,
            "overhead_pct": 100.0 * (off - on) / off}


def _engine_tag_valid(tag: str) -> bool:
    """The closed set of engine row tags this bench emits: the three full
    engines plus ``participating+<policy>`` / ``participating+fault<rate>``.
    Downstream dashboards key on these strings, so an unknown tag is a
    schema violation, not a forward-compatible extension."""
    if tag in ("sequential", "batched", "batched+mesh"):
        return True
    if tag.startswith("participating+"):
        rest = tag[len("participating+"):]
        if rest in ("uniform", "weighted", "stratified"):
            return True
        if rest.startswith("fault"):
            try:
                return 0.0 <= float(rest[len("fault"):]) <= 1.0
            except ValueError:
                return False
    return False


#: The bench-row columns, in emission order.  Each name is a catalog
#: entry in ``repro.core.telemetry.METRICS`` — ``validate_payload`` takes
#: the accepted types from there, ONE schema for engines and bench alike.
BENCH_ROW_FIELDS = (
    "clients", "engine", "devices", "hetero", "cohorts", "round_ms",
    "client_rounds_per_s", "dispatches_per_epoch", "dispatch_path",
    "exchange_every", "exchange_rounds", "pool_bytes_gathered",
    "population", "participation_fraction", "resident_clients",
    "resident_state_bytes", "fault_rate", "byzantine_frac",
    "heads_rejected", "waves_degraded", "mean_val",
    "speedup_vs_sequential",
)


def validate_payload(payload: dict) -> None:
    """Structural schema check for BENCH_fl_scale.json — CI smoke-runs a
    tiny sweep and validates the emitted file through this, so the schema
    can't drift silently under downstream tooling.  Row columns are
    validated against the telemetry metrics registry (see
    ``BENCH_ROW_FIELDS``)."""
    def need(obj, key, types, where):
        if key not in obj:
            raise ValueError(f"{where}: missing key {key!r}")
        if not isinstance(obj[key], types):
            raise ValueError(f"{where}[{key!r}]: expected {types}, "
                             f"got {type(obj[key]).__name__}")

    need(payload, "benchmark", str, "payload")
    if payload["benchmark"] != "fl_scale":
        raise ValueError(f"payload[benchmark]: {payload['benchmark']!r}")
    need(payload, "unix_time", int, "payload")
    need(payload, "backend", str, "payload")
    need(payload, "device_count", int, "payload")
    need(payload, "platform", str, "payload")
    need(payload, "config", dict, "payload")
    need(payload, "results", list, "payload")
    for k in ("epochs", "R", "nf", "batches"):
        need(payload["config"], k, int, "config")
    need(payload["config"], "clients", list, "config")
    need(payload["config"], "engines", list, "config")
    need(payload["config"], "exchange_every", list, "config")
    need(payload["config"], "population_size", (int, type(None)), "config")
    need(payload["config"], "fraction", (int, float, type(None)), "config")
    need(payload["config"], "participation", (str, type(None)), "config")
    need(payload["config"], "waves", (int, type(None)), "config")
    need(payload["config"], "fault_rate", list, "config")
    need(payload["config"], "byzantine_frac", (int, float), "config")
    if not all(isinstance(k, int) and k >= 1
               for k in payload["config"]["exchange_every"]):
        raise ValueError("config[exchange_every]: expected a list of "
                         "positive ints")
    if not payload["results"]:
        raise ValueError("results: empty")
    for i, r in enumerate(payload["results"]):
        where = f"results[{i}]"
        # the row schema IS the metrics registry: every bench column
        # resolves through repro.core.telemetry.METRICS (name + accepted
        # JSON types), so the bench columns and the engines' own
        # dispatch_stats names cannot drift apart
        for key in BENCH_ROW_FIELDS:
            need(r, key, metric_spec(key).types, where)
        if not _engine_tag_valid(r["engine"]):
            raise ValueError(f"{where}[engine]: unknown engine tag "
                             f"{r['engine']!r}")
        if not 0 <= r["fault_rate"] <= 1:
            raise ValueError(f"{where}[fault_rate]: must be in [0, 1], "
                             f"got {r['fault_rate']}")
        if not 0 <= r["byzantine_frac"] <= 1:
            raise ValueError(f"{where}[byzantine_frac]: must be in [0, 1], "
                             f"got {r['byzantine_frac']}")
        if r["heads_rejected"] < 0 or r["waves_degraded"] < 0:
            raise ValueError(f"{where}: fault counters must be >= 0")
        if r["exchange_every"] < 1:
            raise ValueError(f"{where}[exchange_every]: must be >= 1, "
                             f"got {r['exchange_every']}")
        if not 0 < r["participation_fraction"] <= 1:
            raise ValueError(f"{where}[participation_fraction]: must be in "
                             f"(0, 1], got {r['participation_fraction']}")
        if r["resident_clients"] > r["population"]:
            raise ValueError(f"{where}: resident_clients "
                             f"{r['resident_clients']} exceeds population "
                             f"{r['population']}")
    to = payload.get("telemetry_overhead")
    if to is not None:
        where = "telemetry_overhead"
        if not isinstance(to, dict):
            raise ValueError(f"{where}: expected dict")
        need(to, "clients", int, where)
        for k in ("on_client_rounds_per_s", "off_client_rounds_per_s",
                  "overhead_pct"):
            need(to, k, (int, float), where)
        if to["on_client_rounds_per_s"] <= 0 \
                or to["off_client_rounds_per_s"] <= 0:
            raise ValueError(f"{where}: throughputs must be positive")
    for key, p in payload.get("profiles", {}).items():
        where = f"profiles[{key!r}]"
        if not isinstance(p, dict):
            raise ValueError(f"{where}: expected dict")
        for k in ("train_us_per_round", "policy_us_per_round",
                  "eval_us_per_epoch"):
            need(p, k, (int, float), where)
        need(p, "sub_rounds_per_epoch", int, where)
        need(p, "phase_split", dict, where)
        for k in ("train", "policy", "eval"):
            need(p["phase_split"], k, (int, float), f"{where}[phase_split]")


def _record(C, label, het, r, speedup):
    return {
        "clients": C, "engine": label,
        "hetero": het,
        "cohorts": r["cohorts"],
        "devices": r["devices"],
        "exchange_every": r["exchange_every"],
        "exchange_rounds": r["exchange_rounds"],
        "pool_bytes_gathered": r["pool_bytes_gathered"],
        "population": r["population"],
        "participation_fraction": r["participation_fraction"],
        "resident_clients": r["resident_clients"],
        "resident_state_bytes": r["resident_state_bytes"],
        "round_ms": r["round_ms"],
        "client_rounds_per_s": r["client_rounds_per_s"],
        "dispatches_per_epoch": r["dispatches_per_epoch"],
        "dispatch_path": r["dispatch_path"],
        # graceful-degradation columns: full-population rows run faultless
        "fault_rate": r.get("fault_rate", 0.0),
        "byzantine_frac": r.get("byzantine_frac", 0.0),
        "heads_rejected": r.get("heads_rejected", 0),
        "waves_degraded": r.get("waves_degraded", 0),
        "mean_val": r.get("mean_val"),
        "speedup_vs_sequential":
            None if speedup != speedup else speedup}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="2,8,32,128")
    ap.add_argument("--engines", default="sequential,batched")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--R", type=int, default=20)
    ap.add_argument("--nf", type=int, default=4)
    ap.add_argument("--batches", type=int, default=3,
                    help="train sub-rounds per epoch")
    ap.add_argument("--population", action="store_true",
                    help="use generated N-hospital data instead of random "
                         "tensors")
    ap.add_argument("--profile", action="store_true",
                    help="also report the batched engine's train/policy/"
                         "eval phase split per client count")
    ap.add_argument("--mesh", action="store_true",
                    help="add a batched+mesh row: the fused epoch "
                         "client-sharded over all local devices")
    ap.add_argument("--hetero", action="store_true",
                    help="also sweep a mixed-nf population (feature counts "
                         "cycling nf-1/nf/nf+1): the cohorted fast path vs "
                         "the sequential oracle, rows tagged hetero=true")
    ap.add_argument("--force-devices", type=int, default=None,
                    help="split the host CPU into N virtual devices "
                         "(applied before jax init; see --mesh)")
    ap.add_argument("--exchange-every", default="1",
                    help="comma list of bounded-staleness cadences k: "
                         "exchange heads every k-th sub-round "
                         "(RoundSchedule.exchange_every); sequential rows "
                         "run only at k=1, the speedup baseline")
    ap.add_argument("--population-size", type=int, default=None,
                    help="also bench a sampled-participation row: a lazy "
                         "N-client tensor population trained through "
                         "ParticipatingFederation (see --fraction / "
                         "--participation / --waves)")
    ap.add_argument("--fraction", type=float, default=0.001,
                    help="participation fraction per wave for "
                         "--population-size rows")
    ap.add_argument("--participation", default="stratified",
                    choices=sorted(_PARTICIPATIONS),
                    help="sampling policy for --population-size rows")
    ap.add_argument("--waves", type=int, default=2,
                    help="participation waves for --population-size rows")
    ap.add_argument("--fault-rate", default="",
                    help="comma list of per-wave client dropout "
                         "probabilities; each adds a fault-injected "
                         "sampled-participation row (requires "
                         "--population-size) — the graceful-degradation "
                         "curve of MSE and rounds/s vs fault rate")
    ap.add_argument("--byzantine-frac", type=float, default=0.0,
                    help="per-wave probability a sampled client publishes "
                         "corrupted (NaN) heads in --fault-rate rows "
                         "(quarantined by the pool admission guard)")
    ap.add_argument("--telemetry", action="store_true",
                    help="measure the in-graph telemetry carry's overhead: "
                         "fused-epoch throughput with the metrics carry ON "
                         "vs OFF at the largest client count (min-of-3 "
                         "each); writes payload['telemetry_overhead'] — "
                         "CI gates overhead_pct < 3")
    ap.add_argument("--max-seq-clients", type=int, default=None,
                    help="skip the sequential oracle above this client "
                         "count (its per-client Python loop dominates the "
                         "wall clock at large C; batched rows then report "
                         "speedup=null)")
    ap.add_argument("--out", default=str(_REPO_ROOT / "BENCH_fl_scale.json"),
                    help="machine-readable results path (empty to disable)")
    args = ap.parse_args()
    counts = [int(x) for x in args.clients.split(",")]
    engines = args.engines.split(",")
    ks = [int(x) for x in args.exchange_every.split(",")]
    if any(k < 1 for k in ks):
        raise SystemExit("--exchange-every entries must be >= 1")
    fault_rates = [float(x) for x in args.fault_rate.split(",") if x]
    if fault_rates and not args.population_size:
        raise SystemExit("--fault-rate rows ride the participation path; "
                         "pass --population-size too")
    if not all(0 <= f <= 1 for f in fault_rates) \
            or not 0 <= args.byzantine_frac <= 1:
        raise SystemExit("--fault-rate / --byzantine-frac entries must be "
                         "probabilities in [0, 1]")
    cfg = HFLConfig(mode="always", epochs=args.epochs, R=args.R)
    n = args.batches * args.R

    runs = [(e, None, False) for e in engines]
    if args.mesh:
        mesh = make_mesh()
        if mesh_devices(mesh) == 1:
            # a 1-device mesh would just re-measure the single-device path
            # under a misleading label — skip it rather than record it
            print("[mesh] 1 local device: skipping batched+mesh rows (the "
                  "engine would fall back to the single-device path; use "
                  "--force-devices N to split the host CPU)",
                  file=sys.stderr)
        else:
            runs.append(("batched+mesh", mesh, False))
    if args.hetero:
        # the cohorted fast path vs the sequential oracle on mixed nf —
        # same engines, hetero-tagged rows, speedup computed within the
        # hetero pair (oracle heterogeneity was the old ceiling; the gap
        # between these rows IS the cohort engine's contribution)
        runs += [(e, None, True) for e in engines]

    records = []
    profiles = {}
    print("clients,engine,hetero,exchange_every,devices,cohorts,round_ms,"
          "client_rounds_per_s,dispatches_per_epoch,exchange_rounds,"
          "pool_bytes_gathered,population,participation_fraction,"
          "resident_clients,speedup_vs_sequential")
    for C in counts:
        rows = {}
        for k in ks:
            for label, mesh_, het in runs:
                if label == "sequential":
                    if k != 1:       # the oracle baseline runs at k=1 only
                        continue
                    if args.max_seq_clients is not None \
                            and C > args.max_seq_clients:
                        print(f"[seq] skipping C={C}: above "
                              f"--max-seq-clients={args.max_seq_clients}",
                              file=sys.stderr)
                        continue
                if mesh_ is not None and C % mesh_devices(mesh_):
                    print(f"[mesh] skipping C={C}: not divisible by "
                          f"{mesh_devices(mesh_)} devices", file=sys.stderr)
                    continue
                engine = "batched" if mesh_ is not None else label
                rows[(label, het, k)] = bench(engine, C, cfg, args.nf, n,
                                              args.population, mesh_, het,
                                              k)
        for k in ks:
            for label, _, het in runs:
                if (label, het, k) not in rows:
                    continue
                r = rows[(label, het, k)]
                base = rows.get(("sequential", het, 1))
                speedup = (r["client_rounds_per_s"]
                           / base["client_rounds_per_s"]
                           if base else float("nan"))
                print(f"{C},{label},{int(het)},{k},{r['devices']},"
                      f"{r['cohorts']},{r['round_ms']:.2f},"
                      f"{r['client_rounds_per_s']:.1f},"
                      f"{r['dispatches_per_epoch']:.1f},"
                      f"{r['exchange_rounds']},{r['pool_bytes_gathered']},"
                      f"{r['population']},{r['participation_fraction']},"
                      f"{r['resident_clients']},"
                      f"{speedup:.2f}", flush=True)
                records.append(_record(C, label, het, r, speedup))
        if args.profile:
            p = profile_phases(C, cfg, args.nf, n, args.population)
            profiles[str(C)] = p
            s = p["phase_split"]
            print(f"[profile] C={C}: train {p['train_us_per_round']:.0f}us"
                  f"/round, policy {p['policy_us_per_round']:.0f}us/round, "
                  f"eval {p['eval_us_per_epoch']:.0f}us/epoch -> "
                  f"split train {100 * s['train']:.0f}% / "
                  f"policy {100 * s['policy']:.0f}% / "
                  f"eval {100 * s['eval']:.0f}%", file=sys.stderr)
    if args.population_size:
        # sampled-participation rows: population >> resident working set;
        # engine label comes from dispatch_stats ("participating+batched")
        for k in ks:
            r = bench_sampled(args, cfg, n, k)
            label = f"participating+{args.participation}"
            print(f"{r['resident_clients']},{label},0,{k},{r['devices']},"
                  f"{r['cohorts']},{r['round_ms']:.2f},"
                  f"{r['client_rounds_per_s']:.1f},"
                  f"{r['dispatches_per_epoch']:.1f},"
                  f"{r['exchange_rounds']},{r['pool_bytes_gathered']},"
                  f"{r['population']},{r['participation_fraction']},"
                  f"{r['resident_clients']},nan", flush=True)
            records.append(_record(r["resident_clients"], label, False, r,
                                   float("nan")))
        # graceful-degradation curve: one fault-injected row per rate at
        # the first cadence (MSE + rounds/s vs fault rate; same seed, so
        # the schedules are comparable across rates)
        from repro.core.faults import FaultPlan
        for rate in fault_rates:
            plan = FaultPlan(dropout=rate, byzantine=args.byzantine_frac,
                             corruption="nan", seed=0)
            r = bench_sampled(args, cfg, n, ks[0], faults=plan)
            label = f"participating+fault{rate:g}"
            print(f"{r['resident_clients']},{label},0,{ks[0]},"
                  f"{r['devices']},{r['cohorts']},{r['round_ms']:.2f},"
                  f"{r['client_rounds_per_s']:.1f},"
                  f"{r['dispatches_per_epoch']:.1f},"
                  f"{r['exchange_rounds']},{r['pool_bytes_gathered']},"
                  f"{r['population']},{r['participation_fraction']},"
                  f"{r['resident_clients']},nan", flush=True)
            print(f"[faults] rate={rate:g} byz={args.byzantine_frac:g}: "
                  f"mean_val={r['mean_val']}, "
                  f"heads_rejected={r['heads_rejected']}, "
                  f"waves_degraded={r['waves_degraded']}",
                  file=sys.stderr)
            records.append(_record(r["resident_clients"], label, False, r,
                                   float("nan")))
    tele_overhead = None
    if args.telemetry:
        tele_overhead = bench_telemetry_overhead(
            max(counts), cfg, args.nf, n, args.population)
        print(f"[telemetry] C={tele_overhead['clients']}: "
              f"carry on {tele_overhead['on_client_rounds_per_s']:.1f} "
              f"vs off {tele_overhead['off_client_rounds_per_s']:.1f} "
              f"client-rounds/s -> overhead "
              f"{tele_overhead['overhead_pct']:.2f}%", file=sys.stderr)
    if args.out:
        payload = {
            "benchmark": "fl_scale",
            "unix_time": int(time.time()),
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "config": {"epochs": args.epochs, "R": args.R, "nf": args.nf,
                       "batches": args.batches, "mode": cfg.mode,
                       "population": bool(args.population),
                       "mesh": bool(args.mesh),
                       "hetero": bool(args.hetero),
                       "clients": counts, "engines": engines,
                       "exchange_every": ks,
                       "population_size": args.population_size,
                       "fraction": args.fraction if args.population_size
                       else None,
                       "participation": args.participation
                       if args.population_size else None,
                       "waves": args.waves if args.population_size
                       else None,
                       "fault_rate": fault_rates,
                       "byzantine_frac": args.byzantine_frac},
            "results": records,
        }
        if profiles:
            payload["profiles"] = profiles
        if tele_overhead is not None:
            payload["telemetry_overhead"] = tele_overhead
        validate_payload(payload)
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
