"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU-meaningful —
the derived column reports the workload's arithmetic so the roofline can be
checked; per-kernel correctness lives in tests/test_kernels.py)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_flash_attention():
    from repro.kernels.flash_attention.ref import attention_ref

    B, S, H, KV, D = 1, 1024, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
    ref = jax.jit(attention_ref)
    us = _time(ref, q, k, v)
    flops = 4 * B * H * S * S * D / 2
    return ("flash_attention_ref_1k", us, f"{flops:.3e}flops")


def bench_linear_scan():
    from repro.kernels.rg_lru.ref import linear_scan_ref

    B, S, d = 2, 2048, 256
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, d)))
    b = jax.random.normal(k2, (B, S, d))
    us = _time(jax.jit(linear_scan_ref), a, b)
    return ("rg_lru_scan_ref_2k", us, f"{B * S * d * 3:.3e}flops")


def bench_pool_scoring():
    """The paper's selection hot loop: vmap scoring vs the fused kernel
    (interpret mode; on TPU the kernel is one launch instead of ns chains)."""
    from repro.core.networks import head_schema
    from repro.core.hfl import pool_errors
    from repro.sharding import spec as S

    ns, R, w = 64, 50, 3
    pool = [S.materialize(head_schema(w), jax.random.PRNGKey(i))
            for i in range(ns)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pool)
    xd = jax.random.normal(jax.random.PRNGKey(9), (R, w))
    y = jax.random.normal(jax.random.PRNGKey(8), (R,))
    us = _time(pool_errors, stacked, xd, y)
    n_mlp = ns * R
    return ("pool_scoring_vmap_ns64", us, f"{n_mlp}mlp_fwd")


def bench_hfl_round():
    """One full federated round (selection + blend) at paper scale."""
    from repro.core.networks import head_schema
    from repro.core.hfl import blend, pool_errors
    from repro.sharding import spec as S

    ns, nf, R, w = 10, 5, 50, 3
    pool = [S.materialize(head_schema(w), jax.random.PRNGKey(i))
            for i in range(ns)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pool)
    heads = jax.tree_util.tree_map(lambda p: p[:nf], stacked)
    xd = jax.random.normal(jax.random.PRNGKey(9), (R, nf, w))
    y = jax.random.normal(jax.random.PRNGKey(8), (R,))

    def round_fn(heads, stacked, xd, y):
        sels = []
        for i in range(nf):
            errs = pool_errors(stacked, xd[:, i], y)
            j = jnp.argmin(errs)
            sels.append(jax.tree_util.tree_map(lambda p: p[j], stacked))
        sel = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sels)
        return blend(heads, sel, 0.2)

    us = _time(jax.jit(round_fn), heads, stacked, xd, y)
    return ("hfl_federated_round", us, f"ns{ns}_nf{nf}")


def run():
    rows = [bench_flash_attention(), bench_linear_scan(),
            bench_pool_scoring(), bench_hfl_round()]
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
