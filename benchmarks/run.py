"""Benchmark entrypoint (deliverable d): ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table (5/6/7) + kernel micro-benches + the roofline
summary (the roofline lowers on a 512-device host mesh, so it runs as a
subprocess — jax locks the device count at first init).

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, then
the paper-table summaries.  Env:
  REPRO_BENCH_FULL=1     full 50-epoch / 5-seed paper protocol
  REPRO_BENCH_LABELS=4   restrict paper tables to one label task
  REPRO_BENCH_SKIP_ROOFLINE=1 / REPRO_BENCH_SKIP_TABLES=1
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def main() -> None:
    t0 = time.time()
    print("name,us_per_call,derived", flush=True)

    # --- kernel micro-benches ---------------------------------------------
    from benchmarks import kernel_bench
    for name, us, derived in kernel_bench.run():
        print(f"{name},{us:.1f},{derived}", flush=True)

    # --- paper tables (5/6/7) ----------------------------------------------
    if not int(os.environ.get("REPRO_BENCH_SKIP_TABLES", "0")):
        from benchmarks import paper_tables
        labels_env = os.environ.get("REPRO_BENCH_LABELS")
        labels = (tuple(int(x) for x in labels_env.split(","))
                  if labels_env else paper_tables.LABELS)
        results = paper_tables.run_all(labels)
        for t, res in results.items():
            for row in res["rows"]:
                sysnames = [k for k in row
                            if isinstance(row[k], dict) and "test" in row[k]]
                tests = {s: round(row[s]["test"], 2) for s in sysnames}
                tgt = row.get("target", res.get("target", ""))
                print(f"table{t}_{tgt}_{row['label']},"
                      f"{res['elapsed_s'] * 1e6 / max(1, len(res['rows'])):.0f},"
                      f"best={row['best']}|{tests}", flush=True)

    # --- roofline (subprocess: needs 512 forced host devices) --------------
    if not int(os.environ.get("REPRO_BENCH_SKIP_ROOFLINE", "0")):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.roofline", "--skip-existing"],
            cwd=ROOT, env=env, capture_output=True, text=True)
        sys.stdout.write(proc.stdout)
        if proc.returncode != 0:
            sys.stderr.write(proc.stderr[-4000:])
            raise SystemExit("roofline failed")

    print(f"benchmarks_total,{(time.time() - t0) * 1e6:.0f},wall", flush=True)


if __name__ == "__main__":
    main()
