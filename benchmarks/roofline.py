"""Roofline analysis of the pool-scoring kernels (and the legacy LLM zoo).

Default mode ``pool_mlp`` profiles the CURRENT hot path of the HFL system:
the fused Eq.-7 pool sweep in ``repro.kernels.pool_mlp.ops`` — the kernel
every engine (batched, cohorted, client-sharded) dispatches once per
exchange round per scoring client.  For each entry point

    pool_mlp_errors           (R, w) probe vs (ns,) pool      -> (ns,)
    pool_mlp_errors_features  (nf, R, w) multi-feature sweep  -> (nf, ns)
    pool_mlp_errors_shard     one device's ns/D pool chunk    -> (nf, chunk)

we lower the jitted op at a sweep of pool sizes and report FLOPs, bytes
accessed and arithmetic intensity from XLA's ``cost_analysis``, falling
back to ANALYTIC counts from the Table-4 head geometry
(w -> 16 -> 256 -> 64 -> 16 -> 1) whenever the compiled module reports no
flops — interpret-mode Pallas lowerings on CPU typically don't.  A timed
execution adds achieved FLOP/s, and ``--peak-flops`` / ``--hbm-bw`` place
each op against a roofline (defaults: TPU v5e, 197 TFLOP/s bf16 and
819 GB/s HBM — the kernel's tuned target; the ridge point tells you which
side of the roof each pool size sits on regardless of the host that ran
the lowering).

Results go to stdout as CSV and, with ``--out``, to a JSON file under
``experiments/roofline/``.  CI smoke-runs ``--smoke`` (tiny pool sweep,
analytic + lowering paths both exercised).

``--mode llm`` keeps the seed repo's LLM-zoo roofline (depth-variant
extrapolation over the production mesh) runnable; only that mode forces
the 512-virtual-device host split, and it does so BEFORE jax initializes,
which is why the mode flag is read straight from argv.
"""
import argparse
import json
import os
import sys
import time
from pathlib import Path

_REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO_ROOT / "src"))


def _mode_from_argv() -> str:
    """``--mode`` must be known before jax first initializes (the llm mode
    lowers on a 512-virtual-device host split, locked at first init), so it
    is read straight from argv; argparse re-parses it later."""
    for i, arg in enumerate(sys.argv):
        if arg == "--mode" and i + 1 < len(sys.argv):
            return sys.argv[i + 1]
        if arg.startswith("--mode="):
            return arg.split("=", 1)[1]
    return "pool_mlp"


if _mode_from_argv() == "llm":
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import jax
import numpy as np

OUT_DIR = _REPO_ROOT / "experiments" / "roofline"

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_MEM_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
               "temp_size_in_bytes")

# Table-4 global-head MLP: dense (w,) feature vector -> scalar preliminary
# prediction (repro.core.networks.head_schema)
_HEAD_DIMS = (16, 256, 64, 16, 1)


def _head_dims(w: int):
    return (w,) + _HEAD_DIMS


def _compiled_cost(compiled) -> dict:
    """cost_analysis across jax versions: dict, list-of-dict, or absent."""
    try:
        c = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 — backends without an analysis
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c or {})


def analytic_flops(ns: int, nf: int, R: int, w: int) -> float:
    """Eq.-7 sweep FLOPs: every (feature, pool row, probe sample) triple
    runs the head MLP forward (2ab per dense layer) plus the squared-error
    reduction — the count the kernel's grid walks by construction."""
    dims = _head_dims(w)
    mlp = sum(2 * a * b + b for a, b in zip(dims[:-1], dims[1:]))
    return float(nf) * ns * (R * (mlp + 3))     # +3: err, square, accumulate


def analytic_bytes(ns: int, nf: int, R: int, w: int) -> float:
    """Unique-traffic floor: pool weights + probes read once, errors
    written once (f32)."""
    dims = _head_dims(w)
    weights = ns * sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
    return 4.0 * (weights + nf * R * w + R + nf * ns)


def _pool(ns: int, w: int, rng) -> dict:
    dims = _head_dims(w)
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = rng.normal(size=(ns, a, b)).astype(np.float32)
        out[f"b{i}"] = rng.normal(size=(ns, b)).astype(np.float32)
    return out


def measure_pool_op(op: str, ns: int, nf: int, R: int, w: int,
                    repeats: int = 10) -> dict:
    """Lower + time one pool_mlp entry point at one pool size.  Returns
    cost-analysis FLOPs/bytes (``source: xla``) or the analytic model
    (``source: analytic``) when the lowering reports no flops, plus
    arithmetic intensity, achieved FLOP/s, and the lowered memory
    footprint."""
    from repro.kernels.pool_mlp import ops

    rng = np.random.default_rng(0)
    pool = _pool(ns, w, rng)
    y = rng.normal(size=R).astype(np.float32)
    xd = rng.normal(size=(R, w)).astype(np.float32)
    xdf = rng.normal(size=(nf, R, w)).astype(np.float32)
    if op == "pool_mlp_errors":
        fn, args, nf_eff = ops.pool_mlp_errors, (pool, xd, y), 1
    elif op == "pool_mlp_errors_features":
        fn, args, nf_eff = ops.pool_mlp_errors_features, (pool, xdf, y), nf
    elif op == "pool_mlp_errors_shard":
        # one device's chunk of a larger flattened pool, with a validity
        # mask as the cohort/mesh engines pass it
        valid = np.ones(ns, bool)
        fn = jax.jit(lambda p, x, yy, v: ops.pool_mlp_errors_shard(
            p, x, yy, v))
        args, nf_eff = (pool, xdf, y, valid), nf
    else:
        raise SystemExit(f"unknown pool op {op!r}")

    compiled = jax.jit(fn).lower(*args).compile() \
        if op != "pool_mlp_errors_shard" else fn.lower(*args).compile()
    cost = _compiled_cost(compiled)
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    source = "xla"
    if flops <= 0:
        flops, source = analytic_flops(ns, nf_eff, R, w), "analytic"
    if bytes_ <= 0:
        bytes_ = analytic_bytes(ns, nf_eff, R, w)
    jax.block_until_ready(compiled(*args))      # warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = compiled(*args)
    jax.block_until_ready(out)
    wall = (time.perf_counter() - t0) / repeats
    mem = compiled.memory_analysis()
    return {
        "op": op, "ns": ns, "nf": nf_eff, "R": R, "w": w,
        "flops": flops, "bytes": bytes_, "source": source,
        "intensity": flops / bytes_ if bytes_ else 0.0,
        "wall_s": wall,
        "achieved_flops": flops / wall if wall else 0.0,
        "memory_analysis": {f: int(getattr(mem, f, 0) or 0)
                            for f in _MEM_FIELDS},
    }


def main_pool_mlp(args) -> int:
    sizes = [int(x) for x in args.ns.split(",")]
    ops_list = args.ops.split(",")
    ridge = args.peak_flops / args.hbm_bw
    rows = []
    print("op,ns,nf,R,w,source,flops,bytes,intensity,achieved_gflops,"
          "bound", flush=True)
    for op in ops_list:
        for ns in sizes:
            r = measure_pool_op(op, ns, args.nf, args.R, args.w,
                                repeats=args.repeats)
            # which side of the ridge point this sweep sits on, for the
            # TARGET accelerator (the host that lowered it is irrelevant)
            r["bound"] = ("compute" if r["intensity"] >= ridge
                          else "memory")
            r["roof_s"] = max(r["flops"] / args.peak_flops,
                              r["bytes"] / args.hbm_bw)
            rows.append(r)
            print(f"{op},{ns},{r['nf']},{r['R']},{r['w']},{r['source']},"
                  f"{r['flops']:.3e},{r['bytes']:.3e},"
                  f"{r['intensity']:.2f},{r['achieved_flops'] / 1e9:.2f},"
                  f"{r['bound']}", flush=True)
    if args.out:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        out = OUT_DIR / args.out
        out.write_text(json.dumps({
            "mode": "pool_mlp", "backend": jax.default_backend(),
            "peak_flops": args.peak_flops, "hbm_bw": args.hbm_bw,
            "ridge_intensity": ridge, "rows": rows}, indent=1) + "\n")
        print(f"wrote {out}", file=sys.stderr)
    return 0


# ---------------------------------------------------------------------------
# Legacy mode: the seed repo's LLM-zoo roofline (depth-variant
# extrapolation on the 256-chip production mesh).  Unchanged method — see
# EXPERIMENTS.md §Roofline/Method; imports stay inside the functions so the
# default pool_mlp mode never touches the zoo (or its 512-device forcing).
# ---------------------------------------------------------------------------

def _depth_variants(cfg):
    import dataclasses
    base = dataclasses.replace(
        cfg, segments=tuple(dataclasses.replace(s, repeats=1)
                            for s in cfg.segments))
    variants = []
    for i in range(len(cfg.segments)):
        segs = [dataclasses.replace(s, repeats=2 if j == i else 1)
                for j, s in enumerate(cfg.segments)]
        variants.append(dataclasses.replace(cfg, segments=tuple(segs)))
    return base, variants


def _measure(cfg, shape_name: str, mesh, moe_a2a: bool = False):
    """Lower one config x shape on `mesh`; return dict of raw costs."""
    import jax.numpy as jnp  # noqa: F401
    from jax.sharding import PartitionSpec as P

    from repro.configs import INPUT_SHAPES
    from repro.launch import steps
    from repro.launch.dryrun import _first_cost, collective_bytes, named
    from repro.sharding import spec as S

    shape = INPUT_SHAPES[shape_name]
    opt = steps.default_optimizer()
    needs_mesh = ((moe_a2a and cfg.moe is not None) or
                  (cfg.attn is not None and cfg.attn.n_heads_padded))
    moe_mesh = mesh if needs_mesh else None
    with mesh:
        if shape.kind == "train":
            fn = steps.make_train_step(cfg, opt, unroll=True,
                                       moe_mesh=moe_mesh)
            state = steps.abstract_state(cfg, opt)
            st_specs = named(steps.state_pspecs(cfg, opt, mesh), mesh)
            batch = steps.batch_spec(cfg, shape)
            b_specs = named(steps.batch_pspecs(cfg, shape, mesh), mesh)
            lowered = jax.jit(fn, in_shardings=(st_specs, b_specs),
                              out_shardings=(st_specs, None)).lower(state,
                                                                    batch)
        elif shape.kind == "prefill":
            fn = steps.make_prefill_step(cfg, unroll=True, moe_mesh=moe_mesh)
            p_specs, schema = steps.param_pspecs(cfg, mesh)
            lowered = jax.jit(
                fn, in_shardings=(named(p_specs, mesh),
                                  named(steps.batch_pspecs(cfg, shape, mesh),
                                        mesh)),
                out_shardings=None).lower(S.abstract(schema),
                                          steps.batch_spec(cfg, shape))
        else:
            fn = steps.make_serve_step(cfg, shape.seq_len, unroll=True)
            p_specs, schema = steps.param_pspecs(cfg, mesh)
            kvq = bool(int(os.environ.get("REPRO_KV_QUANT", "0")))
            cache, tokens, pos = steps.decode_inputs_spec(cfg, shape,
                                                          kv_quant=kvq)
            c_specs = named(steps.cache_pspecs(cfg, shape, mesh,
                                               kv_quant=kvq), mesh)
            scalar = jax.NamedSharding(mesh, P())
            lowered = jax.jit(
                fn, in_shardings=(named(p_specs, mesh), c_specs, scalar,
                                  scalar),
                out_shardings=(None, c_specs)).lower(
                    S.abstract(schema), cache, tokens, pos)
        compiled = lowered.compile()
    cost = _first_cost(compiled)
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total", 0)),
        "mem": {f: int(getattr(mem, f, 0) or 0) for f in _MEM_FIELDS},
    }


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6 N D (training) with N = active params (MoE: routed
    top-k active only); decode: 2 N_active per token x batch."""
    from repro.configs import INPUT_SHAPES
    from repro.models.model import model_schema
    from repro.sharding import spec as S

    flat, _ = jax.tree_util.tree_flatten_with_path(model_schema(cfg),
                                                   is_leaf=S.is_spec)
    total = active = 0
    for path, sp in flat:
        n = sp.size
        total += n
        if sp.logical and "experts" in sp.logical:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        active += n
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return 6.0 * active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * active * shape.seq_len * shape.global_batch
    return 2.0 * active * shape.global_batch           # one token


def roofline_pair(arch: str, shape_name: str, mesh,
                  moe_a2a: bool = False) -> dict:
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch import steps

    cfg = steps.effective_config(get_config(arch), INPUT_SHAPES[shape_name])
    base_cfg, variants = _depth_variants(cfg)
    t0 = time.time()
    base = _measure(base_cfg, shape_name, mesh, moe_a2a)
    totals = dict(flops=base["flops"], bytes=base["bytes"],
                  coll=base["coll"])
    units = []
    for seg, vcfg in zip(cfg.segments, variants):
        v = _measure(vcfg, shape_name, mesh, moe_a2a)
        unit = {k: max(0.0, v[k] - base[k])
                for k in ("flops", "bytes", "coll")}
        units.append(unit)
        for k in totals:
            totals[k] += (seg.repeats - 1) * unit[k]
    n_chips = mesh.devices.size
    compute_s = totals["flops"] / PEAK_FLOPS          # per-device program
    memory_s = totals["bytes"] / HBM_BW
    coll_s = totals["coll"] / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape_name)
    hlo_global = totals["flops"] * n_chips
    return {
        "arch": arch, "shape": shape_name, "mesh": "16x16",
        "chips": n_chips, "moe_a2a": moe_a2a,
        "per_device": totals,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "memory_analysis_base": base["mem"],
        "elapsed_s": round(time.time() - t0, 1),
    }


def main_llm(args) -> int:
    from repro.configs import INPUT_SHAPES, list_archs
    from repro.launch.mesh import make_production_mesh

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    suffix = "__a2a" if args.moe_a2a else ""
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "model_flops,useful_ratio", flush=True)
    fails = []
    for arch in archs:
        for shape in shapes:
            out = OUT_DIR / f"{arch}__{shape}{suffix}.json"
            if args.skip_existing and out.exists():
                r = json.loads(out.read_text())
            else:
                try:
                    r = roofline_pair(arch, shape, mesh, args.moe_a2a)
                    out.write_text(json.dumps(r, indent=1))
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL,{arch},{shape},{e}", flush=True)
                    import traceback
                    traceback.print_exc()
                    fails.append((arch, shape))
                    continue
            print(f"{arch},{shape},{r['compute_s']:.3e},"
                  f"{r['memory_s']:.3e},{r['collective_s']:.3e},"
                  f"{r['dominant']},{r['model_flops']:.3e},"
                  f"{r['useful_ratio']:.3f}", flush=True)
    return 1 if fails else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="pool_mlp",
                    choices=("pool_mlp", "llm"),
                    help="pool_mlp: roofline of the Eq.-7 pool-scoring "
                         "kernels (the HFL hot path); llm: the seed "
                         "LLM-zoo roofline on the production mesh")
    # pool_mlp mode
    ap.add_argument("--ops", default="pool_mlp_errors,"
                                     "pool_mlp_errors_features,"
                                     "pool_mlp_errors_shard")
    ap.add_argument("--ns", default="8,64,512",
                    help="comma list of pool sizes to sweep")
    ap.add_argument("--nf", type=int, default=4)
    ap.add_argument("--R", type=int, default=20)
    ap.add_argument("--w", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--peak-flops", type=float, default=PEAK_FLOPS,
                    help="target accelerator peak FLOP/s for the roofline "
                         "(default: TPU v5e bf16)")
    ap.add_argument("--hbm-bw", type=float, default=HBM_BW,
                    help="target accelerator HBM bandwidth, bytes/s")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (one op, ns=8,16, 2 repeats)")
    ap.add_argument("--out", default=None,
                    help="JSON filename under experiments/roofline/ "
                         "(pool_mlp mode)")
    # llm mode
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="use the explicit all-to-all MoE dispatch "
                         "(optimized variant; writes *__a2a.json)")
    args = ap.parse_args()
    if args.smoke:
        args.ops = "pool_mlp_errors,pool_mlp_errors_features"
        args.ns, args.repeats = "8,16", 2
    if args.mode == "llm":
        sys.exit(main_llm(args))
    sys.exit(main_pool_mlp(args))


if __name__ == "__main__":
    main()
