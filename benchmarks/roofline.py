import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ must precede any jax import: roofline lowers on the 256-chip single-pod
# production mesh (run as its own process; benchmarks.run subprocesses this).
"""Roofline analysis (deliverable g).

Method.  XLA's cost_analysis counts a lax.scan body ONCE, not per trip
(verified empirically — see EXPERIMENTS.md §Roofline/Method), so the raw
dry-run numbers undercount deep models.  We therefore lower DEPTH VARIANTS of
every config: a base with every segment at repeats=1, plus one variant per
segment at repeats=2.  The per-pattern-unit cost is the difference; totals
extrapolate exactly (optimizer update, per-layer collectives and remat all
live inside the subtracted unit):

    total(X) = X(base) + sum_seg (repeats_seg - 1) * [X(seg@2) - X(base)]

Terms (TPU v5e): compute = FLOPs / (chips * 197e12); memory = bytes /
(chips * 819e9); collective = collective_bytes / (chips * 50e9).
cost_analysis is per-device (SPMD module), so `chips` divides only
MODEL_FLOPS, not the per-device numbers.
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, Segment
from repro.launch import steps
from repro.launch.dryrun import collective_bytes, named, _first_cost
from repro.launch.mesh import make_production_mesh
from repro.sharding import spec as S

OUT_DIR = Path(__file__).resolve().parent.parent / "experiments" / "roofline"

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_MEM_FIELDS = ("argument_size_in_bytes", "output_size_in_bytes",
               "temp_size_in_bytes")


def _depth_variants(cfg: ModelConfig):
    base = dataclasses.replace(
        cfg, segments=tuple(dataclasses.replace(s, repeats=1)
                            for s in cfg.segments))
    variants = []
    for i in range(len(cfg.segments)):
        segs = [dataclasses.replace(s, repeats=2 if j == i else 1)
                for j, s in enumerate(cfg.segments)]
        variants.append(dataclasses.replace(cfg, segments=tuple(segs)))
    return base, variants


def _measure(cfg: ModelConfig, shape_name: str, mesh, moe_a2a: bool = False):
    """Lower one config x shape on `mesh`; return dict of raw costs."""
    shape = INPUT_SHAPES[shape_name]
    opt = steps.default_optimizer()
    # pass the mesh into the model when a mesh-aware path is active:
    # all-to-all MoE dispatch (--moe-a2a) or padded-head sharding constraints
    needs_mesh = ((moe_a2a and cfg.moe is not None) or
                  (cfg.attn is not None and cfg.attn.n_heads_padded))
    moe_mesh = mesh if needs_mesh else None
    with mesh:
        if shape.kind == "train":
            fn = steps.make_train_step(cfg, opt, unroll=True,
                                       moe_mesh=moe_mesh)
            state = steps.abstract_state(cfg, opt)
            st_specs = named(steps.state_pspecs(cfg, opt, mesh), mesh)
            batch = steps.batch_spec(cfg, shape)
            b_specs = named(steps.batch_pspecs(cfg, shape, mesh), mesh)
            lowered = jax.jit(fn, in_shardings=(st_specs, b_specs),
                              out_shardings=(st_specs, None)).lower(state, batch)
        elif shape.kind == "prefill":
            fn = steps.make_prefill_step(cfg, unroll=True, moe_mesh=moe_mesh)
            p_specs, schema = steps.param_pspecs(cfg, mesh)
            lowered = jax.jit(
                fn, in_shardings=(named(p_specs, mesh),
                                  named(steps.batch_pspecs(cfg, shape, mesh),
                                        mesh)),
                out_shardings=None).lower(S.abstract(schema),
                                          steps.batch_spec(cfg, shape))
        else:
            fn = steps.make_serve_step(cfg, shape.seq_len, unroll=True)
            p_specs, schema = steps.param_pspecs(cfg, mesh)
            kvq = bool(int(os.environ.get("REPRO_KV_QUANT", "0")))
            cache, tokens, pos = steps.decode_inputs_spec(cfg, shape,
                                                          kv_quant=kvq)
            c_specs = named(steps.cache_pspecs(cfg, shape, mesh,
                                               kv_quant=kvq), mesh)
            scalar = jax.NamedSharding(mesh, P())
            lowered = jax.jit(
                fn, in_shardings=(named(p_specs, mesh), c_specs, scalar,
                                  scalar),
                out_shardings=(None, c_specs)).lower(
                    S.abstract(schema), cache, tokens, pos)
        compiled = lowered.compile()
    cost = _first_cost(compiled)
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total", 0)),
        "mem": {f: int(getattr(mem, f, 0) or 0) for f in _MEM_FIELDS},
    }


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS = 6 N D (training) with N = active params (MoE: routed
    top-k active only); decode: 2 N_active per token x batch."""
    from repro.models.model import model_schema
    flat, _ = jax.tree_util.tree_flatten_with_path(model_schema(cfg),
                                                   is_leaf=S.is_spec)
    total = active = 0
    for path, sp in flat:
        n = sp.size
        total += n
        # routed experts: only top_k of n_experts active per token
        if sp.logical and "experts" in sp.logical:
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        active += n
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return 6.0 * active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * active * shape.seq_len * shape.global_batch
    return 2.0 * active * shape.global_batch           # one token


def roofline_pair(arch: str, shape_name: str, mesh,
                  moe_a2a: bool = False) -> dict:
    cfg = steps.effective_config(get_config(arch), INPUT_SHAPES[shape_name])
    base_cfg, variants = _depth_variants(cfg)
    t0 = time.time()
    base = _measure(base_cfg, shape_name, mesh, moe_a2a)
    totals = dict(flops=base["flops"], bytes=base["bytes"], coll=base["coll"])
    units = []
    for seg, vcfg in zip(cfg.segments, variants):
        v = _measure(vcfg, shape_name, mesh, moe_a2a)
        unit = {k: max(0.0, v[k] - base[k]) for k in ("flops", "bytes", "coll")}
        units.append(unit)
        for k in totals:
            totals[k] += (seg.repeats - 1) * unit[k]
    n_chips = mesh.devices.size
    compute_s = totals["flops"] / PEAK_FLOPS          # per-device program
    memory_s = totals["bytes"] / HBM_BW
    coll_s = totals["coll"] / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape_name)
    hlo_global = totals["flops"] * n_chips
    res = {
        "arch": arch, "shape": shape_name, "mesh": "16x16", "chips": n_chips,
        "moe_a2a": moe_a2a,
        "per_device": totals,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "memory_analysis_base": base["mem"],
        "elapsed_s": round(time.time() - t0, 1),
    }
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="use the explicit all-to-all MoE dispatch "
                         "(optimized variant; writes *__a2a.json)")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    suffix = "__a2a" if args.moe_a2a else ""
    print("arch,shape,compute_s,memory_s,collective_s,dominant,"
          "model_flops,useful_ratio", flush=True)
    fails = []
    for arch in archs:
        for shape in shapes:
            out = OUT_DIR / f"{arch}__{shape}{suffix}.json"
            if args.skip_existing and out.exists():
                r = json.loads(out.read_text())
            else:
                try:
                    r = roofline_pair(arch, shape, mesh, args.moe_a2a)
                    out.write_text(json.dumps(r, indent=1))
                except Exception as e:  # noqa: BLE001
                    print(f"FAIL,{arch},{shape},{e}", flush=True)
                    import traceback
                    traceback.print_exc()
                    fails.append((arch, shape))
                    continue
            print(f"{arch},{shape},{r['compute_s']:.3e},{r['memory_s']:.3e},"
                  f"{r['collective_s']:.3e},{r['dominant']},"
                  f"{r['model_flops']:.3e},{r['useful_ratio']:.3f}", flush=True)
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
