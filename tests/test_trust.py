"""Red-team battery for the trust layer (core/trust.py).

Pins the three trust plugins the way tests/test_faults.py pins the fault
subsystem: the admission guard's blind spot (a sign-flipped head is finite
and norm-preserving, so it PASSES admission), the watermark/reputation
layer that catches it anyway, the DP accountant's analytic epsilon, and
the secure-aggregation masking invariants — each on the sequential oracle,
the fused batched engine, the mixed-nf cohort path, and (subprocess) a
forced 4-virtual-device mesh.  ``trust=None`` and a disabled ``TrustPlan``
must stay engine-local bit-identical to the pre-trust graph.

The hypothesis property tests are gated on the library being installed
(the CI container does not ship it); a seeded sweep covers the same
masking invariants unconditionally.
"""
import json
import math
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as FT
from repro.core import trust as TR
from repro.core.experiment import tensor_population
from repro.core.federation import Federation
from repro.core.hfl import HFLConfig
from repro.core.participation import (ParticipatingFederation,
                                      UniformParticipation)
from repro.core.policies import policy_from_spec

ROOT = Path(__file__).resolve().parent.parent

try:                                    # satellite: property tests are
    import hypothesis                   # gated — the container may not
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _cfg(**kw):
    kw.setdefault("epochs", 3)
    kw.setdefault("R", 10)
    kw.setdefault("mode", "always")     # guarantee exchange rounds happen
    kw.setdefault("seed", 0)
    return HFLConfig(**kw)


def _clients(cfg, n=4, nf=(3,), seed=0):
    return tensor_population(n, cfg, seed=seed, nf_choices=nf,
                             n_train=20, n_eval=10).build(range(n))


def _fit(trust, engine, nf=(3,), cfg=None):
    cfg = cfg or _cfg()
    fed = Federation(_clients(cfg, 4, nf), cfg, engine=engine, trust=trust)
    return fed, fed.fit()


def _hist_identical(h1, h2):
    return all(h1[n]["val"] == h2[n]["val"]
               and h1[n]["selections"] == h2[n]["selections"] for n in h1)


def _vals(h):
    return np.array([h[n]["val"] for n in sorted(h)])


# ---------------------------------------------------------------------------
# Plan validation + spec round-trips
# ---------------------------------------------------------------------------

def test_plan_rejects_secure_agg_plus_watermark():
    with pytest.raises(ValueError, match="cannot be combined"):
        TR.TrustPlan(secure_agg=TR.MaskedSecureAggregation(),
                     watermark=TR.HeadWatermark())


def test_plan_rejects_wrong_slot_types():
    with pytest.raises(TypeError, match="secure_agg"):
        TR.TrustPlan(secure_agg=TR.DPNoise())
    with pytest.raises(TypeError, match="dp"):
        TR.TrustPlan(dp=TR.HeadWatermark())
    with pytest.raises(TypeError, match="watermark"):
        TR.TrustPlan(watermark=TR.MaskedSecureAggregation())


@pytest.mark.parametrize("bad", [
    lambda: TR.DPNoise(clip=0.0),
    lambda: TR.DPNoise(sigma=0.0),
    lambda: TR.DPNoise(delta=1.0),
    lambda: TR.MaskedSecureAggregation(alpha=0.0),
    lambda: TR.MaskedSecureAggregation(alpha=1.5),
    lambda: TR.MaskedSecureAggregation(mask_scale=-1.0),
])
def test_plugin_field_validation(bad):
    with pytest.raises(ValueError):
        bad()


def test_plan_enabled_property():
    assert not TR.TrustPlan().enabled
    assert TR.TrustPlan(dp=TR.DPNoise()).enabled
    assert TR.TrustPlan(watermark=TR.HeadWatermark()).enabled
    assert TR.TrustPlan(secure_agg=TR.MaskedSecureAggregation()).enabled


@pytest.mark.parametrize("plan", [
    TR.TrustPlan(),
    TR.TrustPlan(watermark=TR.HeadWatermark(strength=0.4, tolerance=3)),
    TR.TrustPlan(dp=TR.DPNoise(clip=2.0, sigma=1.5, delta=1e-6, seed=9)),
    TR.TrustPlan(secure_agg=TR.MaskedSecureAggregation(alpha=0.3, seed=4),
                 dp=TR.DPNoise()),
    TR.TrustPlan(dp=TR.DPNoise(),
                 watermark=TR.HeadWatermark(threshold=0.25)),
])
def test_spec_round_trip_through_json(plan):
    wire = json.loads(json.dumps(plan.spec()))
    assert policy_from_spec(wire) == plan


def test_federation_rejects_non_plan():
    cfg = _cfg()
    with pytest.raises(TypeError, match="TrustPlan"):
        Federation(_clients(cfg), cfg, trust=TR.DPNoise())


# ---------------------------------------------------------------------------
# The admission guard's blind spot: sign-flip passes, watermark catches it
# ---------------------------------------------------------------------------

def test_signflip_passes_admission_guard():
    """The red-team premise: a sign-flipped head tree is finite and has
    EXACTLY the norm of the honest head, so the fault layer's admission
    guard (tests/test_faults.py's norm/finiteness gate) admits it.  Only
    the watermark can tell — a flipped head projects at -strength onto
    the owner's signature direction."""
    cfg = _cfg()
    cl = _clients(cfg, 1)[0]
    heads = jax.tree_util.tree_map(np.asarray, cl.params["heads"])
    inj = FT.FaultInjector(FT.FaultPlan(byzantine=1.0,
                                        corruption="signflip", seed=0))
    flipped = inj.corrupt_heads(heads, wave=0, index=0)
    bound = FT.FaultPlan().norm_bound
    assert FT.heads_admissible(heads, bound)
    assert FT.heads_admissible(flipped, bound)          # the blind spot
    nan = FT.FaultInjector(FT.FaultPlan(byzantine=1.0, corruption="nan",
                                        seed=0)).corrupt_heads(heads, 0, 0)
    assert not FT.heads_admissible(nan, bound)          # what it DOES catch

    wm = TR.HeadWatermark()
    sig = TR.signature(wm, cl.name, heads)
    marked, healed = TR.wm_embed(jax.tree_util.tree_map(jnp.asarray,
                                                        heads), sig, wm)
    assert healed and TR.wm_verify_host(marked, sig, wm)
    re_flipped = jax.tree_util.tree_map(lambda x: -np.asarray(x), marked)
    assert not TR.wm_verify_host(re_flipped, sig, wm)   # watermark catches
    _, ok2, proj2 = TR.wm_apply(
        jax.tree_util.tree_map(jnp.asarray, re_flipped), sig,
        strength=wm.strength, threshold=wm.threshold)
    assert not bool(np.any(ok2))
    np.testing.assert_allclose(np.asarray(proj2), -wm.strength, atol=1e-4)


def test_signature_is_unit_norm_and_deterministic():
    cfg = _cfg()
    cl = _clients(cfg, 1)[0]
    wm = TR.HeadWatermark(seed=5)
    s1 = TR.signature(wm, cl.name, cl.params["heads"])
    s2 = TR.signature(wm, cl.name, cl.params["heads"])
    sq = sum(float(np.sum(np.square(l)))
             for l in jax.tree_util.tree_leaves(s1))
    assert abs(sq - 1.0) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(a, b)
    s3 = TR.signature(wm, "someone-else", cl.params["heads"])
    dot = sum(float(np.sum(np.asarray(a) * np.asarray(b)))
              for a, b in zip(jax.tree_util.tree_leaves(s1),
                              jax.tree_util.tree_leaves(s3)))
    assert abs(dot) < 0.5               # distinct clients, distinct axes


def test_pad_rows_preserves_unit_norm():
    cfg = _cfg()
    cl = _clients(cfg, 1, nf=(2,))[0]
    wm = TR.HeadWatermark()
    sig = TR.signature(wm, cl.name, cl.params["heads"])
    padded = TR.pad_rows(sig, 4)
    for leaf in jax.tree_util.tree_leaves(padded):
        assert leaf.shape[0] == 4
    sq = sum(float(np.sum(np.square(l)))
             for l in jax.tree_util.tree_leaves(padded))
    assert abs(sq - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# trust=None / disabled plan: byte-identical pre-trust graph (engine-local)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "batched"])
def test_disabled_plan_bit_identical_to_none(engine):
    _, h0 = _fit(None, engine)
    _, h1 = _fit(TR.TrustPlan(), engine)
    assert _hist_identical(h0, h1)


@pytest.mark.parametrize("engine", ["sequential", "batched"])
def test_disabled_plan_bit_identical_on_cohorts(engine):
    """Mixed-nf population: the batched engine routes through the cohort
    subsystem; the disabled plan must not perturb its padded graph."""
    _, h0 = _fit(None, engine, nf=(2, 3))
    _, h1 = _fit(TR.TrustPlan(), engine, nf=(2, 3))
    assert _hist_identical(h0, h1)


# ---------------------------------------------------------------------------
# Watermark: engine parity + honest clients stay clean
# ---------------------------------------------------------------------------

def test_watermark_engine_parity():
    """The oracle and the fused engine must agree on the auditable state:
    per-client failure counters are EXACT; vals agree to float tolerance
    (watermark arithmetic joins the fused graph and re-associates)."""
    wm = TR.TrustPlan(watermark=TR.HeadWatermark())
    fs, hs = _fit(wm, "sequential")
    fb, hb = _fit(wm, "batched")
    assert fs._wm_failures == fb._wm_failures
    np.testing.assert_allclose(_vals(hs), _vals(hb), rtol=0, atol=1e-4)


def test_watermark_engine_parity_on_cohorts():
    wm = TR.TrustPlan(watermark=TR.HeadWatermark())
    fs, hs = _fit(wm, "sequential", nf=(2, 3))
    fb, hb = _fit(wm, "batched", nf=(2, 3))
    assert fs._wm_failures == fb._wm_failures
    np.testing.assert_allclose(_vals(hs), _vals(hb), rtol=0, atol=1e-4)


def test_honest_clients_never_fail_at_default_strength():
    """The default strength is calibrated so training drift between
    publications never eats the verification budget — an honest federation
    must report zero watermark failures (false quarantines are the one
    thing the reputation layer cannot be allowed to do)."""
    fed, _ = _fit(TR.TrustPlan(watermark=TR.HeadWatermark()), "batched")
    assert fed.dispatch_stats["watermark_failures"] == 0


# ---------------------------------------------------------------------------
# DP: analytic accountant + engine-exact release counters
# ---------------------------------------------------------------------------

def test_epsilon_matches_analytic_bound():
    dp = TR.DPNoise(clip=1.0, sigma=0.7, delta=1e-5)
    rho1 = 1.0 / (2.0 * 0.7 ** 2)
    for k in (1, 5, 40):
        expect = k * rho1 + 2.0 * math.sqrt(k * rho1 * math.log(1e5))
        assert dp.epsilon(k) == pytest.approx(expect, rel=1e-12)
    assert dp.epsilon(0) == 0.0
    assert dp.epsilon(10) > dp.epsilon(5) > dp.epsilon(1)
    quieter = TR.DPNoise(clip=1.0, sigma=2.0, delta=1e-5)
    assert quieter.epsilon(5) < dp.epsilon(5)


def test_accountant_round_trip_and_max_epsilon():
    dp = TR.DPNoise(sigma=0.9)
    acct = TR.DPAccountant(dp)
    acct.record("a", 3)
    acct.record("b", 1)
    acct.record("a")
    assert acct.counts == {"a": 4, "b": 1}
    assert acct.epsilon("a") == dp.epsilon(4)
    assert acct.max_epsilon == dp.epsilon(4)
    back = TR.DPAccountant.from_json(dp, json.loads(json.dumps(
        acct.to_json())))
    assert back.counts == acct.counts
    assert back.max_epsilon == acct.max_epsilon


def test_dp_counters_exact_across_engines():
    """Noise streams are engine-specific by design (like stochastic
    selection policies), but the ACCOUNTING must be engine-exact: same
    per-client release counts, same epsilon, same clip events."""
    dp = TR.TrustPlan(dp=TR.DPNoise(clip=10.0, sigma=0.8))
    fs, _ = _fit(dp, "sequential")
    fb, _ = _fit(dp, "batched")
    assert fs._dp_counts == fb._dp_counts
    assert sum(fs._dp_counts.values()) > 0
    assert fs.dispatch_stats["epsilon_spent"] == \
        fb.dispatch_stats["epsilon_spent"] > 0
    assert fs.dispatch_stats["clip_events"] == \
        fb.dispatch_stats["clip_events"]
    # dispatch_stats epsilon IS the analytic per-client worst case
    worst = max(fs._dp_counts.values())
    assert fs.dispatch_stats["epsilon_spent"] == \
        pytest.approx(dp.dp.epsilon(worst))


def test_clip_events_fire_only_under_tight_clip():
    """Gaussian-mechanism noise scales with the clip bound, so the loose
    arm must also shrink sigma — else its own noise re-inflates later
    releases past any bound."""
    tight, _ = _fit(TR.TrustPlan(dp=TR.DPNoise(clip=0.1, sigma=0.5)),
                    "batched")
    loose, _ = _fit(TR.TrustPlan(dp=TR.DPNoise(clip=1e6, sigma=1e-6)),
                    "batched")
    assert tight.dispatch_stats["clip_events"] > 0
    assert loose.dispatch_stats["clip_events"] == 0


# ---------------------------------------------------------------------------
# Secure aggregation: pairwise cancellation + dropout recovery
# ---------------------------------------------------------------------------

def _template(nf=3):
    return {"w": np.zeros((nf, 4, 2), np.float32),
            "b": np.zeros((nf, 5), np.float32)}


def _check_masking_invariants(sa, wave, n_rounds, ids, active, rng):
    """The whole secure-aggregation contract on one geometry: per-round
    net masks cancel over the client axis, and the masked sum of the
    SURVIVORS plus the host-reconstructed correction for the dropped
    equals the plain sum of the survivors' raw payloads."""
    tmpl = _template()
    masks = TR.net_masks(sa, wave, n_rounds, ids, tmpl)
    for leaf in jax.tree_util.tree_leaves(masks):
        resid = np.abs(leaf.sum(axis=1)).max() if leaf.size else 0.0
        assert resid <= 1e-6 * max(sa.mask_scale, 1.0)

    heads = jax.tree_util.tree_map(
        lambda l: rng.normal(size=(len(ids),) + np.shape(l))
        .astype(np.float32), tmpl)
    corr = TR.mask_correction(masks, active)
    for r in range(n_rounds):
        surv = np.asarray(active, bool)
        masked_sum = jax.tree_util.tree_map(
            lambda h, m, c: (h + m[r])[surv].sum(axis=0) + c[r],
            heads, masks, corr)
        plain_sum = jax.tree_util.tree_map(
            lambda h: h[surv].sum(axis=0), heads)
        for a, b in zip(jax.tree_util.tree_leaves(masked_sum),
                        jax.tree_util.tree_leaves(plain_sum)):
            np.testing.assert_allclose(a, b, rtol=0,
                                       atol=2e-5 * max(sa.mask_scale, 1.0))


def test_masked_sums_equal_plain_sums_with_dropout():
    sa = TR.MaskedSecureAggregation(mask_scale=1.0)
    rng = np.random.default_rng(0)
    _check_masking_invariants(sa, wave=2, n_rounds=3, ids=[0, 3, 4, 7],
                              active=[True, False, True, True], rng=rng)
    # everyone drops but one: the correction carries the entire masking
    _check_masking_invariants(sa, wave=5, n_rounds=1, ids=[1, 2, 5],
                              active=[False, False, True], rng=rng)


def test_masking_invariants_seeded_sweep():
    """Unconditional stand-in for the hypothesis property test: a seeded
    sweep over wave / client-set / dropout geometries."""
    rng = np.random.default_rng(7)
    for trial in range(12):
        C = int(rng.integers(2, 7))
        ids = sorted(rng.choice(64, size=C, replace=False).tolist())
        active = rng.random(C) > 0.4
        if not active.any():
            active[int(rng.integers(C))] = True
        sa = TR.MaskedSecureAggregation(
            mask_scale=float(rng.choice([1e-3, 1.0, 10.0])),
            seed=int(rng.integers(1 << 16)))
        _check_masking_invariants(sa, wave=int(rng.integers(32)),
                                  n_rounds=int(rng.integers(1, 4)),
                                  ids=ids, active=active.tolist(), rng=rng)


def test_pair_mask_requires_ordered_ids():
    sa = TR.MaskedSecureAggregation()
    with pytest.raises(ValueError, match="i < j"):
        TR.pair_mask(sa, 0, 0, 3, 3, _template())


def test_secure_agg_engine_parity():
    """Masked mean-transfer: oracle and fused engine agree to float
    tolerance (one shared jitted secure_round, two callers)."""
    sa = TR.TrustPlan(secure_agg=TR.MaskedSecureAggregation())
    _, hs = _fit(sa, "sequential")
    _, hb = _fit(sa, "batched")
    np.testing.assert_allclose(_vals(hs), _vals(hb), rtol=0, atol=1e-6)


def test_secure_agg_engine_parity_on_cohorts():
    sa = TR.TrustPlan(secure_agg=TR.MaskedSecureAggregation())
    _, hs = _fit(sa, "sequential", nf=(2, 3))
    _, hb = _fit(sa, "batched", nf=(2, 3))
    np.testing.assert_allclose(_vals(hs), _vals(hb), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Hypothesis property tests (gated on the library being installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 63),                       # wave
           st.integers(1, 3),                        # rounds
           st.lists(st.integers(0, 63), min_size=2, max_size=6,
                    unique=True),                    # global client ids
           st.data())
    def test_property_masked_sums_match_plain(wave, n_rounds, ids, data):
        ids = sorted(ids)
        active = data.draw(st.lists(st.booleans(), min_size=len(ids),
                                    max_size=len(ids)))
        if not any(active):
            active[0] = True
        sa = TR.MaskedSecureAggregation(
            mask_scale=data.draw(st.sampled_from([1e-3, 1.0, 10.0])),
            seed=data.draw(st.integers(0, 1 << 16)))
        _check_masking_invariants(sa, wave, n_rounds, ids, active,
                                  np.random.default_rng(wave))

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 200), st.floats(0.2, 5.0),
           st.sampled_from([1e-5, 1e-6, 1e-8]))
    def test_property_epsilon_bound_sane(releases, sigma, delta):
        dp = TR.DPNoise(clip=1.0, sigma=sigma, delta=delta)
        eps = dp.epsilon(releases)
        rho = releases / (2.0 * sigma ** 2)
        assert eps == pytest.approx(
            rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta)))
        assert eps > dp.epsilon(releases - 1) or releases == 1


# ---------------------------------------------------------------------------
# Red team: sign-flip publishers quarantined by reputation, honest spared
# ---------------------------------------------------------------------------

def _red_team(nf_choices=(3,), waves=8, n=8, seed=7):
    cfg = _cfg(seed=0)
    pop = tensor_population(n, cfg, seed=0, nf_choices=nf_choices,
                            n_train=20, n_eval=10)
    pf = ParticipatingFederation(
        pop, cfg,
        participation=UniformParticipation(fraction=0.5, min_clients=2),
        engine="batched",
        faults=FT.FaultPlan(byzantine=0.3, corruption="signflip",
                            seed=seed),
        trust=TR.TrustPlan(watermark=TR.HeadWatermark()))
    pf.fit(waves=waves)
    return pf


def _assert_quarantine(pf):
    byz = {pf.population.name_of(i)
           for w in pf.fault_log for i in w.byzantine}
    quarantined = set(pf.reputation.quarantined)
    assert quarantined, "no sign-flip publisher was quarantined"
    assert quarantined <= byz, (
        f"honest client quarantined: {quarantined - byz}")
    # honest clients never accumulate strikes, let alone quarantine
    for name, k in pf.reputation.strikes.items():
        assert name in byz, f"honest client {name} struck {k}x"
    stats = pf.dispatch_stats
    assert stats["quarantined"] == sorted(quarantined)
    assert stats["quarantined_drops"] > 0   # they were re-sampled + dropped
    assert stats["watermark_failures"] > 0


def test_red_team_signflip_quarantined_batched():
    """The headline red-team scenario: byzantine clients publish
    sign-flipped heads that sail through the admission guard
    (test_signflip_passes_admission_guard) but fail watermark
    verification every wave they are seen; the reputation book strikes
    them once per failed wave and quarantines at ``tolerance`` strikes,
    after which sampling never re-admits them."""
    _assert_quarantine(_red_team())


def test_red_team_signflip_quarantined_on_cohorts():
    """Same adversary on a mixed-nf population: the cohort engine's padded
    signature stacks must catch it just the same."""
    _assert_quarantine(_red_team(nf_choices=(2, 3)))


def test_red_team_selections_identical_without_adversary():
    """Control arm: with the watermark on but NO adversary, a faultless
    red-team run must match the plain watermark run exactly — the trust
    layer only ever bites where there is an attack."""
    cfg = _cfg(seed=0)
    mk = lambda: tensor_population(8, cfg, seed=0, nf_choices=(3,),
                                   n_train=20, n_eval=10)
    wm = TR.TrustPlan(watermark=TR.HeadWatermark())
    kw = dict(participation=UniformParticipation(fraction=0.5,
                                                 min_clients=2),
              engine="batched", trust=wm)
    a = ParticipatingFederation(mk(), cfg, **kw)
    b = ParticipatingFederation(
        mk(), cfg, faults=FT.FaultPlan(byzantine=0.0,
                                       corruption="signflip"), **kw)
    ha, hb = a.fit(waves=4), b.fit(waves=4)
    assert not a.reputation.quarantined and not b.reputation.quarantined
    assert a.dispatch_stats["watermark_failures"] == \
        b.dispatch_stats["watermark_failures"] == 0
    for w1, w2 in zip(a.wave_log, b.wave_log):
        assert w1["active"] == w2["active"]


# ---------------------------------------------------------------------------
# Forced 4-virtual-device mesh: the full battery, one subprocess
# ---------------------------------------------------------------------------

_MESH_SUBPROCESS = r"""
import json
import jax
assert jax.device_count() == 4, jax.devices()
import numpy as np
from repro.core import faults as FT
from repro.core import trust as TR
from repro.core.experiment import tensor_population
from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import HFLConfig
from repro.core.mesh_federation import make_mesh
from repro.core.participation import (ParticipatingFederation,
                                      UniformParticipation)

cfg = HFLConfig(epochs=2, R=10, mode="always", seed=3)
res = {}

def full(trust, nf=(3,)):
    fed = Federation(tensor_population(8, cfg, seed=1, nf_choices=nf,
                                       n_train=20, n_eval=10)
                     .build(range(8)),
                     cfg, schedule=RoundSchedule(2, 10), engine="batched",
                     mesh=make_mesh(), trust=trust)
    return fed, fed.fit()

# 1) disabled-plan / None bit-identity on the sharded engine
_, h0 = full(None)
_, h1 = full(TR.TrustPlan())
res["mesh_parity"] = all(
    h0[n]["val"] == h1[n]["val"]
    and h0[n]["selections"] == h1[n]["selections"] for n in h0)

# 2) watermark: failure counters exactly match the single-device engine
wm = TR.TrustPlan(watermark=TR.HeadWatermark())
fm, _ = full(wm)
f1 = Federation(tensor_population(8, cfg, seed=1, nf_choices=(3,),
                                  n_train=20, n_eval=10).build(range(8)),
                cfg, schedule=RoundSchedule(2, 10), engine="batched",
                trust=wm)
f1.fit()
res["wm_counters_match"] = fm._wm_failures == f1._wm_failures

# 3) dp: epsilon accrues on the mesh, counters engine-exact
dp = TR.TrustPlan(dp=TR.DPNoise(clip=10.0, sigma=0.8))
fd, _ = full(dp)
res["dp_eps_positive"] = fd.dispatch_stats["epsilon_spent"] > 0
res["dp_counts_uniform"] = len(set(fd._dp_counts.values())) == 1

# 4) secure agg on the mesh vs the sequential oracle: float tolerance
sa = TR.TrustPlan(secure_agg=TR.MaskedSecureAggregation())
fsm, hsm = full(sa)
fss = Federation(tensor_population(8, cfg, seed=1, nf_choices=(3,),
                                   n_train=20, n_eval=10).build(range(8)),
                 cfg, schedule=RoundSchedule(2, 10), engine="sequential",
                 trust=sa)
hss = fss.fit()
v1 = np.array([hsm[n]["val"] for n in sorted(hsm)])
v2 = np.array([hss[n]["val"] for n in sorted(hss)])
res["secure_maxdv"] = float(np.abs(v1 - v2).max())
res["secure_close"] = bool(np.allclose(v1, v2, rtol=0, atol=1e-5))

# 5) mixed-nf cohort path under the mesh runs with the watermark on
full(wm, nf=(2, 3))
res["cohort_mesh_ok"] = True

# 6) red team on the mesh: sign-flip publishers quarantined at 4-multiple
#    wave geometry, honest clients strike-free
pop = tensor_population(16, cfg, seed=0, nf_choices=(3,),
                        n_train=20, n_eval=10)
pf = ParticipatingFederation(
    pop, cfg,
    participation=UniformParticipation(fraction=0.5, min_clients=8),
    engine="batched", mesh=make_mesh(),
    faults=FT.FaultPlan(byzantine=0.3, corruption="signflip", seed=7),
    trust=TR.TrustPlan(watermark=TR.HeadWatermark()))
pf.fit(waves=8)
byz = {pf.population.name_of(i) for w in pf.fault_log for i in w.byzantine}
res["mesh_quarantined"] = sorted(pf.reputation.quarantined)
res["mesh_quarantine_nonempty"] = bool(pf.reputation.quarantined)
res["mesh_quarantine_subset_byz"] = set(pf.reputation.quarantined) <= byz
res["mesh_honest_strike_free"] = all(
    n in byz for n in pf.reputation.strikes)
res["mesh_geometry_multiple"] = all(
    len(w["active"]) % 4 == 0 for w in pf.wave_log)
print("RESULT " + json.dumps(res))
"""


def _run_forced_devices(script: str, n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    return json.loads(line[-1][len("RESULT "):])


def test_trust_on_forced_4_device_mesh():
    """Acceptance: the whole trust battery on a forced 4-virtual-device
    mesh — disabled-plan bit-identity, watermark counter parity with the
    single-device engine, DP epsilon accrual, secure-agg oracle agreement,
    the cohort path, and the sign-flip red team quarantined at 4-multiple
    wave geometry."""
    res = _run_forced_devices(_MESH_SUBPROCESS, 4)
    assert res["mesh_parity"] is True
    assert res["wm_counters_match"] is True
    assert res["dp_eps_positive"] is True
    assert res["dp_counts_uniform"] is True
    assert res["secure_close"] is True, res["secure_maxdv"]
    assert res["cohort_mesh_ok"] is True
    assert res["mesh_quarantine_nonempty"] is True, res
    assert res["mesh_quarantine_subset_byz"] is True, res
    assert res["mesh_honest_strike_free"] is True, res
    assert res["mesh_geometry_multiple"] is True
