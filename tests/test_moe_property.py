"""Property tests for the MoE dispatch: with dropless capacity, the sorted
capacity-bucket dispatch must equal the dense mixture sum_k w_k E_k(x)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip offline
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models.layers.common import activation
from repro.models.layers.moe import _router, moe_apply, moe_schema
from repro.sharding import spec as S


def dense_mixture_oracle(params, x, cfg: MoEConfig, act: str):
    """Compute EVERY expert on every token; combine with router weights."""
    B, Sq, d = x.shape
    xt = x.reshape(-1, d)
    scores, weights, ids = _router(params, xt, cfg)
    f = activation(act)
    g = f(jnp.einsum("td,edf->etf", xt, params["wg"].astype(x.dtype)))
    u = jnp.einsum("td,edf->etf", xt, params["wu"].astype(x.dtype))
    all_out = jnp.einsum("etf,efd->etd", g * u,
                         params["wd"].astype(x.dtype))     # (E, T, d)
    T = xt.shape[0]
    out = jnp.zeros((T, d), x.dtype)
    for k in range(cfg.top_k):
        sel = jnp.take_along_axis(
            all_out, ids[None, :, k, None].astype(jnp.int32), axis=0)[0]
        out = out + weights[:, k, None].astype(x.dtype) * sel
    return out.reshape(B, Sq, d)


@settings(max_examples=12, deadline=None)
@given(
    n_experts=st.sampled_from([2, 4, 8]),
    top_k=st.integers(1, 3),
    seq=st.sampled_from([4, 8, 16]),
    score=st.sampled_from(["softmax", "sigmoid"]),
    seed=st.integers(0, 10**6),
)
def test_dispatch_equals_dense_mixture(n_experts, top_k, seq, score, seed):
    top_k = min(top_k, n_experts)
    cfg = MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=16,
                    capacity_factor=float(n_experts),  # dropless
                    router_score=score, aux_loss_weight=0.0)
    d = 8
    params = S.materialize(moe_schema(d, cfg, "silu"),
                           jax.random.PRNGKey(seed % 97))
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, seq, d))
    out, aux = moe_apply(params, x, cfg, "silu")
    ref = dense_mixture_oracle(params, x, cfg, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_bounds_served_tokens():
    """With capacity C, at most E*C token-slots exist, so at most E*C tokens
    can receive ANY output — every fully-dropped token's output is exactly
    zero (drops remove contributions, never fabricate them)."""
    cfg_tight = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                          capacity_factor=0.01, min_capacity=1,
                          aux_loss_weight=0.0)
    d = 8
    params = S.materialize(moe_schema(d, cfg_tight, "silu"),
                           jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d))
    out_tight, _ = moe_apply(params, x, cfg_tight, "silu")
    nonzero_rows = int(jnp.sum(jnp.any(out_tight[0] != 0, axis=-1)))
    assert nonzero_rows <= cfg_tight.n_experts * 1  # E * C slots
