"""Sharding-layer tests: logical->PartitionSpec mapping, divisibility rules,
schema utilities, input specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip offline
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, smoke_config
from repro.launch import steps
from repro.models import model as M
from repro.sharding import rules as R
from repro.sharding import spec as S


SIZES = {"data": 16, "model": 16, "pod": 2}


def test_divisible_axis_is_sharded():
    p = S.logical_to_pspec((1024, 256), ("embed", "ffn"), R.PARAM_RULES, SIZES)
    assert p == P(None, "model")


def test_non_divisible_axis_is_replicated():
    # kv_heads = 8 not divisible by model=16 -> replicate
    p = S.logical_to_pspec((2048, 8, 64), ("embed", "kv_heads", None),
                           R.PARAM_RULES, SIZES)
    assert p == P()


def test_mesh_axis_used_once():
    # both vocab and ffn map to model; second one must be dropped
    p = S.logical_to_pspec((512, 512), ("vocab", "ffn"), R.PARAM_RULES, SIZES)
    assert p == P("model")


def test_multi_axis_fsdp_sharding():
    p = S.logical_to_pspec((256, 7168, 2048), ("experts", "embed", None),
                           R.PARAM_RULES_FSDP, SIZES)
    assert p[0] == ("data", "model")


def test_stack_prepends_dim():
    schema = {"w": S.ParamSpec((4, 8), ("embed", "ffn"))}
    st2 = S.stack(schema, 5, axis_name="layers")
    assert st2["w"].shape == (5, 4, 8)
    assert st2["w"].logical[0] == "layers"


def test_abstract_matches_materialize():
    schema = M.model_schema(smoke_config("qwen3-0.6b"))
    abst = S.abstract(schema)
    real = S.materialize(schema, jax.random.PRNGKey(0))
    for a, r in zip(jax.tree_util.tree_leaves(abst),
                    jax.tree_util.tree_leaves(real)):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_materialize_deterministic_per_path():
    schema = M.model_schema(smoke_config("qwen3-0.6b"))
    p1 = S.materialize(schema, jax.random.PRNGKey(0))
    p2 = S.materialize(schema, jax.random.PRNGKey(0))
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=40, deadline=None)
@given(dim=st.integers(1, 4096), axis=st.sampled_from(["vocab", "ffn", "heads",
                                                       "experts", None]))
def test_pspec_never_breaks_divisibility(dim, axis):
    p = S.logical_to_pspec((dim,), (axis,), R.PARAM_RULES, SIZES)
    if len(p) and p[0] is not None:
        assert dim % SIZES["model"] == 0


def test_batch_spec_shapes_per_kind():
    cfg = get_config("qwen3-0.6b")
    b = steps.batch_spec(cfg, INPUT_SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    b2 = steps.batch_spec(cfg, INPUT_SHAPES["train_4k"], n_clients=2)
    assert b2["tokens"].shape == (2, 128, 4096)
    cache, tok, pos = steps.decode_inputs_spec(cfg, INPUT_SHAPES["decode_32k"])
    assert tok.shape == (128, 1)
    k = cache["seg0"]["l0"]["k"]
    assert k.shape == (28, 128, 32768, 8, 128)


def test_long_ctx_window_override():
    cfg = get_config("granite-3-8b")
    eff = steps.effective_config(cfg, INPUT_SHAPES["long_500k"])
    assert eff.attn.window == cfg.long_ctx_window
    # native sub-quadratic archs untouched
    rg = get_config("recurrentgemma-2b")
    assert steps.effective_config(rg, INPUT_SHAPES["long_500k"]) is not rg or True
    cache, _, _ = steps.decode_inputs_spec(eff, INPUT_SHAPES["long_500k"])
    k = cache["seg0"]["l0"]["k"]
    assert k.shape[2] == cfg.long_ctx_window      # ring cache, not 524288


def test_param_count_magnitudes():
    """Sanity: full configs land in the right parameter-count ballpark."""
    counts = {a: S.count_params(M.model_schema(get_config(a)))
              for a in ("qwen3-0.6b", "granite-3-8b", "deepseek-v3-671b",
                        "xlstm-350m")}
    assert 0.4e9 < counts["qwen3-0.6b"] < 0.9e9
    assert 7e9 < counts["granite-3-8b"] < 10e9
    assert 600e9 < counts["deepseek-v3-671b"] < 750e9
    assert 0.2e9 < counts["xlstm-350m"] < 0.55e9
