"""Participation subsystem: sampled partial participation over the
host-resident ClientStore (repro.core.participation).

Pins the ISSUE-7 acceptance surface: seeded replayability (same seed ⇒
identical participation schedule, bit-identical histories across
save/restore), sampled-subset selections identical to the sequential
oracle on that same subset — on the batched AND (forced-4-device
subprocess) mesh engines, at exchange cadence k ∈ {1, 2} — and the
bounded device working set (resident bytes scale with the sample, never
the population)."""
import json
import os
import subprocess
import sys
import tempfile
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import cohorts
from repro.core import mesh_federation as MF
from repro.core.experiment import lazy_hetero_population, tensor_population
from repro.core.federation import RoundSchedule
from repro.core.hfl import HFLConfig
from repro.core.participation import (ClientPopulation, ClientStore,
                                      ParticipatingFederation,
                                      StratifiedParticipation,
                                      UniformParticipation,
                                      WeightedParticipation, host_tree)
from repro.core.policies import policy_from_spec
from repro.data import synthetic as syn

ROOT = Path(__file__).resolve().parent.parent


def _cfg(**kw):
    kw.setdefault("epochs", 3)
    kw.setdefault("R", 10)
    kw.setdefault("mode", "always")
    kw.setdefault("seed", 3)
    return HFLConfig(**kw)


def _pop(cfg, n=12, nf_choices=(2, 3), seed=1):
    return tensor_population(n, cfg, seed=seed, nf_choices=nf_choices,
                             n_train=20, n_eval=10)


def _fit(engine="batched", *, waves=3, k=1, participation=None, mesh=None,
         sm=None, n=12, cfg=None, pop=None):
    cfg = cfg or _cfg(epochs=waves)
    pop = pop or _pop(cfg, n=n)
    pf = ParticipatingFederation(
        pop, cfg,
        participation=participation
        or UniformParticipation(fraction=0.5, min_clients=4),
        schedule=RoundSchedule(waves, cfg.R, exchange_every=k),
        engine=engine, mesh=mesh, sample_multiple=sm)
    pf.fit()
    return pf


def _schedule(pf):
    return [w["active"] for w in pf.wave_log]


# ---------------------------------------------------------------------------
# ParticipationPolicy units
# ---------------------------------------------------------------------------

def test_n_active_rounding():
    p = UniformParticipation(fraction=0.1, min_clients=2)
    assert p.n_active(100) == 10
    assert p.n_active(10) == 2          # min_clients floor
    assert p.n_active(1) == 1           # capped at N
    assert p.n_active(100, multiple_of=4) == 12   # 10 rounds UP to 12
    assert p.n_active(10, multiple_of=4) == 4
    assert p.n_active(6, multiple_of=4) == 4      # largest multiple <= N
    with pytest.raises(ValueError, match="shard"):
        p.n_active(3, multiple_of=4)


def test_policy_validation():
    with pytest.raises(ValueError, match="fraction"):
        UniformParticipation(fraction=0.0)
    with pytest.raises(ValueError, match="fraction"):
        UniformParticipation(fraction=1.5)
    with pytest.raises(ValueError, match="min_clients"):
        UniformParticipation(min_clients=0)


def test_uniform_sample_is_sorted_unique_and_seeded():
    pop = _pop(_cfg(), n=40)
    p = UniformParticipation(fraction=0.25, min_clients=2)
    idx = p.sample(pop, np.random.default_rng(7))
    assert len(idx) == 10 and len(set(idx.tolist())) == 10
    assert idx.tolist() == sorted(idx.tolist())
    assert (idx < 40).all() and (idx >= 0).all()
    # deterministic in the rng state
    again = p.sample(pop, np.random.default_rng(7))
    np.testing.assert_array_equal(idx, again)


def test_weighted_requires_and_uses_sizes():
    cfg = _cfg()
    p = WeightedParticipation(fraction=0.2, min_clients=2)
    with pytest.raises(ValueError, match="sizes"):
        p.sample(_pop(cfg, n=20), np.random.default_rng(0))
    pop = tensor_population(20, cfg, nf_choices=(2,), n_train=20,
                            n_eval=10, weighted_sizes=True)
    rng = np.random.default_rng(0)
    counts = np.zeros(20)
    for _ in range(200):
        counts[p.sample(pop, rng)] += 1
    heavy, light = np.argmax(pop.sizes), np.argmin(pop.sizes)
    assert counts[heavy] > counts[light]    # weights actually bias draws


def test_stratified_counts_and_membership():
    cfg = _cfg()
    pop = _pop(cfg, n=30, nf_choices=(2, 3, 4))    # three 10-client strata
    p = StratifiedParticipation(fraction=0.3, min_clients=3)
    idx = p.sample(pop, np.random.default_rng(1))
    assert len(idx) == 9
    strata = cohorts.nf_strata(pop.nfs)
    per = {nf: np.isin(idx, ix).sum() for nf, ix in strata.items()}
    assert per == {2: 3, 3: 3, 4: 3}    # largest-remainder, equal strata
    # mesh rounding: every stratum count becomes a multiple of 4
    idx4 = p.sample(pop, np.random.default_rng(1), multiple_of=4)
    per4 = {nf: int(np.isin(idx4, ix).sum()) for nf, ix in strata.items()}
    assert all(c % 4 == 0 for c in per4.values()) and sum(per4.values()) > 0


def test_stratified_counts_are_wave_static():
    """Per-stratum counts depend on the population alone — the geometry of
    every wave's CohortPlan repeats, so wave 2+ hits the compile cache."""
    cfg = _cfg()
    pop = _pop(cfg, n=30, nf_choices=(2, 3, 4))
    p = StratifiedParticipation(fraction=0.3, min_clients=3)
    rng = np.random.default_rng(5)
    strata = cohorts.nf_strata(pop.nfs)
    per_wave = [sorted(int(np.isin(p.sample(pop, rng), ix).sum())
                       for ix in strata.values()) for _ in range(5)]
    assert all(w == per_wave[0] for w in per_wave)


def test_participation_spec_roundtrip():
    for p in (UniformParticipation(fraction=0.25, min_clients=3),
              WeightedParticipation(fraction=0.5),
              StratifiedParticipation(min_clients=8)):
        q = policy_from_spec(json.loads(json.dumps(p.spec())))
        assert q == p


def test_nf_strata_orders_and_partitions():
    strata = cohorts.nf_strata([5, 2, 3, 2, 5, 2])
    assert list(strata) == [2, 3, 5]
    assert strata[2].tolist() == [1, 3, 5]
    assert sorted(np.concatenate(list(strata.values())).tolist()) \
        == list(range(6))


# ---------------------------------------------------------------------------
# ClientStore / ClientPopulation
# ---------------------------------------------------------------------------

def test_client_store_roundtrip_is_bit_exact():
    store = ClientStore()
    tree = {"w": jax.numpy.arange(6, dtype=jax.numpy.float32).reshape(2, 3),
            "b": (jax.numpy.float32(0.25), np.arange(4, dtype=np.int32))}
    store.put("c0", params=tree, opt_state=tree, best_params=tree,
              best_val=1.5, val_history=[2.0, 1.5])
    st = store.get("c0")
    for leaf, orig in zip(jax.tree_util.tree_leaves(st["params"]),
                          jax.tree_util.tree_leaves(tree)):
        assert isinstance(leaf, np.ndarray) or np.isscalar(leaf)
        np.testing.assert_array_equal(leaf, np.asarray(orig))
        assert np.asarray(leaf).dtype == np.asarray(orig).dtype
    assert "c0" in store and len(store) == 1
    assert store.nbytes() == 3 * (6 * 4 + 4 + 4 * 4)


def test_population_validation():
    with pytest.raises(ValueError, match="nfs"):
        ClientPopulation(size=3, nfs=[2, 2], build=lambda ix: [])
    with pytest.raises(ValueError, match="sizes"):
        ClientPopulation(size=2, nfs=[2, 2], build=lambda ix: [],
                         sizes=[1.0, 0.0])


def test_build_is_deterministic_per_index():
    """Rebuilding an index in a later wave must yield the same data and the
    same fresh init — the ClientStore contract."""
    cfg = _cfg()
    for pop in (_pop(cfg, n=8),
                lazy_hetero_population(8, cfg, seed=2, n_patients=6,
                                       n_events=150, nf_choices=(2, 3),
                                       split_caps=(30, 10, 10))):
        a, = pop.build([5])
        b, = pop.build([5])
        assert a.name == b.name == pop.name_of(5)
        for x, y in zip(jax.tree_util.tree_leaves((a.params, a.train)),
                        jax.tree_util.tree_leaves((b.params, b.train))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_lazy_synthetic_is_index_addressable():
    """make_hospital_at(h) never materializes hospitals != h and is stable
    whatever else was generated before."""
    a = syn.make_hospital_at(0, 7, nf=3, n_patients=4, n_events=100)
    syn.make_hospital_at(0, 3, nf=2, n_patients=4, n_events=100)
    b = syn.make_hospital_at(0, 7, nf=3, n_patients=4, n_events=100)
    assert a.name == b.name == "h000007"
    assert len(a.feature_names) == 3
    np.testing.assert_array_equal(a.streams[0].values, b.streams[0].values)
    sizes = syn.population_sizes_at(0, [7, 9], nfs=[3, 3])
    assert sizes[0] == syn.population_spec_at(0, 7, 3)["n_patients"]


# ---------------------------------------------------------------------------
# Seeded replayability
# ---------------------------------------------------------------------------

def test_same_seed_same_schedule():
    a, b = _fit(), _fit()
    assert _schedule(a) == _schedule(b)
    assert a.selections == b.selections
    ha = {n: a.store.get(n)["val_history"] for n in a.store.names()}
    hb = {n: b.store.get(n)["val_history"] for n in b.store.names()}
    assert ha == hb


def test_different_seed_different_schedule():
    a = _fit(cfg=_cfg(seed=3))
    b = _fit(cfg=_cfg(seed=4))
    assert _schedule(a) != _schedule(b)


def test_save_restore_replays_bit_identically():
    """Resume mid-schedule ⇒ the exact waves, selections, histories and
    params an uninterrupted run would have produced."""
    full = _fit(waves=4)
    with tempfile.TemporaryDirectory() as d:
        cfg = _cfg(epochs=4)
        pop = _pop(cfg)
        pf = ParticipatingFederation(
            pop, cfg,
            participation=UniformParticipation(fraction=0.5, min_clients=4),
            schedule=RoundSchedule(4, cfg.R))
        pf.fit(waves=2)
        pf.save(d)
        res = ParticipatingFederation.restore(d, pop)
        assert res.wave == 2
        res.fit()
    # the wave log round-trips through the manifest, so the restored run's
    # full schedule (saved waves + resumed waves) is the uninterrupted one
    assert _schedule(res) == _schedule(full)
    assert res.selections == full.selections
    assert res.n_rounds == full.n_rounds
    for n in full.store.names():
        assert res.store.get(n)["val_history"] \
            == full.store.get(n)["val_history"]
        for x, y in zip(
                jax.tree_util.tree_leaves(res.store.get(n)["params"]),
                jax.tree_util.tree_leaves(full.store.get(n)["params"])):
            np.testing.assert_array_equal(x, y)
    # pool carry round-trips too
    assert set(res.pool_entries) == set(full.pool_entries)
    assert res.pool_ages == full.pool_ages


def test_restore_rejects_mismatched_population():
    cfg = _cfg()
    pop = _pop(cfg)
    pf = ParticipatingFederation(pop, cfg)
    pf.fit(waves=1)
    with tempfile.TemporaryDirectory() as d:
        pf.save(d)
        with pytest.raises(ValueError, match="population mismatch"):
            ParticipatingFederation.restore(
                d, _pop(cfg, n=16))
        with pytest.raises(ValueError, match="population mismatch"):
            ParticipatingFederation.restore(
                d, _pop(cfg, nf_choices=(3, 2)))


def test_restore_pins_sample_multiple():
    """A run that rounded its samples to D keeps doing so after a meshless
    restore — the schedule replays regardless of restore-time devices."""
    cfg = _cfg()
    pop = _pop(cfg)
    pf = ParticipatingFederation(pop, cfg, sample_multiple=4,
                                 schedule=RoundSchedule(3, cfg.R))
    pf.fit(waves=1)
    with tempfile.TemporaryDirectory() as d:
        pf.save(d)
        res = ParticipatingFederation.restore(d, pop)
    assert res.sample_multiple == 4
    assert res._wave_multiple() == 4


# ---------------------------------------------------------------------------
# Oracle parity: sampled-subset selections == sequential oracle on that
# same subset (the inner sequential engine IS the oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2])
def test_sampled_selections_match_sequential_oracle(k):
    b = _fit("batched", k=k)
    s = _fit("sequential", k=k)
    assert _schedule(b) == _schedule(s)
    assert b.selections == s.selections
    assert b.n_rounds == s.n_rounds
    assert sum(len(v) for v in b.selections.values()) > 0


@pytest.mark.parametrize("k", [1, 2])
def test_hetero_synthetic_oracle_parity(k):
    """Mixed-nf synthetic-physiology population through the cohort engine:
    still the oracle's selections, wave after wave."""
    cfg = _cfg(epochs=3)
    runs = []
    for engine in ("batched", "sequential"):
        pop = lazy_hetero_population(12, cfg, seed=2, n_patients=6,
                                     n_events=150, nf_choices=(2, 3),
                                     split_caps=(30, 10, 10))
        pf = ParticipatingFederation(
            pop, cfg,
            participation=StratifiedParticipation(fraction=0.4,
                                                  min_clients=4),
            schedule=RoundSchedule(3, cfg.R, exchange_every=k),
            engine=engine)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            pf.fit()
        runs.append(pf)
    b, s = runs
    assert _schedule(b) == _schedule(s)
    assert b.selections == s.selections
    assert b.n_rounds == s.n_rounds


def test_mesh_participation_in_process():
    """Over whatever devices the host exposes (1 in plain tier-1, 4 under
    the CI step): mesh run == 1-device batched == sequential oracle on the
    same schedule (sample_multiple pinned to the device count)."""
    D = jax.device_count()
    mesh = MF.make_mesh()
    n = 16
    part = StratifiedParticipation(fraction=0.5, min_clients=2 * D)
    m = _fit("batched", mesh=mesh, participation=part, n=n)
    b = _fit("batched", sm=D, participation=part, n=n)
    s = _fit("sequential", sm=D, participation=part, n=n)
    assert _schedule(m) == _schedule(b) == _schedule(s)
    assert m.selections == b.selections == s.selections
    assert m.n_rounds == s.n_rounds
    assert m.dispatch_stats["devices"] == (D if D > 1 else 1)


# ---------------------------------------------------------------------------
# Bounded working set + pool carry
# ---------------------------------------------------------------------------

def test_resident_working_set_is_bounded_by_sample():
    cfg = _cfg(epochs=2)
    pop = _pop(cfg, n=40, nf_choices=(2,))
    pf = ParticipatingFederation(
        pop, cfg,
        participation=UniformParticipation(fraction=0.1, min_clients=4),
        schedule=RoundSchedule(2, cfg.R))
    pf.fit()
    st = pf.dispatch_stats
    assert st["population"] == 40
    assert st["resident_clients"] == 4
    assert st["participation"] == "UniformParticipation"
    assert st["participation_fraction"] == 0.1
    # resident bytes = 4 clients of state, NOT 40: a full-population fit of
    # the same geometry would be 10x
    per_client = st["resident_state_bytes"] / 4
    assert st["resident_state_bytes"] < 0.2 * per_client * 40
    # the store only holds clients that were actually sampled
    touched = {i for w in pf.wave_log for i in w["active"]}
    assert len(pf.store) == len(touched) <= 8
    assert st["store_clients"] == len(touched)
    assert st["gather_bytes"] == st["scatter_bytes"] \
        == sum(w["state_bytes"] for w in pf.wave_log)


def test_pool_carries_across_waves():
    """A client's published head (and its age) persists between the waves
    it sits out — the always-resident structure."""
    pf = _fit(waves=4)
    touched = {pf.population.name_of(i)
               for w in pf.wave_log for i in w["active"]}
    assert {u for (u, _) in pf.pool_entries} == touched
    assert all(isinstance(a, int) and a >= 0
               for a in pf.pool_ages.values())
    # host-resident: every carried entry is numpy, not a device array
    for e in pf.pool_entries.values():
        assert all(isinstance(leaf, np.ndarray)
                   for leaf in jax.tree_util.tree_leaves(e))
    # results() reports every touched client exactly once
    res = pf.results()
    assert set(res) == touched
    assert all(res[n]["rounds"] == pf.n_rounds[n] for n in res)


def test_full_participation_wave_matches_plain_federation():
    """fraction=1 degenerates to the ordinary Federation: same selections,
    same histories — participation is a strict generalization."""
    from repro.core.federation import Federation
    cfg = _cfg(epochs=2)
    pop = _pop(cfg, n=8, nf_choices=(2,))
    pf = ParticipatingFederation(
        pop, cfg, participation=UniformParticipation(fraction=1.0),
        schedule=RoundSchedule(2, cfg.R))
    pf.fit()
    clients = pop.build(range(8))
    fed = Federation(clients, cfg, engine="batched",
                     schedule=RoundSchedule(2, cfg.R))
    hist = fed.fit()
    assert pf.selections == {n: hist[n]["selections"] for n in hist}
    assert {n: pf.store.get(n)["val_history"] for n in pf.store.names()} \
        == {n: hist[n]["val"] for n in hist}


def test_host_tree_is_numpy_and_bit_exact():
    t = {"a": jax.numpy.linspace(0, 1, 7), "b": np.float32(3.5)}
    h = host_tree(t)
    assert isinstance(h["a"], np.ndarray)
    np.testing.assert_array_equal(h["a"], np.asarray(t["a"]))
    assert h["a"].dtype == np.asarray(t["a"]).dtype


def test_participation_multiple():
    assert MF.participation_multiple(None) == 1
    assert MF.participation_multiple(MF.make_mesh()) == jax.device_count()


# ---------------------------------------------------------------------------
# Acceptance pin: forced 4-device mesh — sampled-participation selections
# identical to the sequential oracle on the same subsets, k in {1, 2}
# ---------------------------------------------------------------------------

_SUBPROCESS = r"""
import json
import jax
assert jax.device_count() == 4, jax.devices()
import numpy as np
from repro.core.experiment import tensor_population
from repro.core.federation import RoundSchedule
from repro.core.hfl import HFLConfig
from repro.core.mesh_federation import make_mesh
from repro.core.participation import (ParticipatingFederation,
                                      StratifiedParticipation)

def run(engine, mesh=None, k=1, sm=None):
    cfg = HFLConfig(epochs=2, R=10, mode="always", seed=3)
    pop = tensor_population(24, cfg, seed=1, nf_choices=(2, 3),
                            n_train=40, n_eval=20)
    pf = ParticipatingFederation(
        pop, cfg,
        participation=StratifiedParticipation(fraction=0.5, min_clients=8),
        schedule=RoundSchedule(2, 10, exchange_every=k),
        engine=engine, mesh=mesh, sample_multiple=sm)
    pf.fit()
    return pf

res = {}
mesh = make_mesh()
for k in (1, 2):
    m = run("batched", mesh=mesh, k=k)
    s = run("sequential", k=k, sm=4)
    res[f"schedule_identical_k{k}"] = (
        [w["active"] for w in m.wave_log]
        == [w["active"] for w in s.wave_log])
    res[f"sel_identical_k{k}"] = m.selections == s.selections
    res[f"rounds_identical_k{k}"] = m.n_rounds == s.n_rounds
    res[f"devices_k{k}"] = m.dispatch_stats["devices"]
    res[f"resident_k{k}"] = m.dispatch_stats["resident_clients"]
print("RESULT " + json.dumps(res))
"""


def _run_forced_devices(script: str, n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    return json.loads(line[-1][len("RESULT "):])


def test_sampled_participation_on_forced_4_device_mesh():
    """ISSUE 7 acceptance: a stratified sample sharded over a genuine
    4-device mesh selects exactly what the sequential oracle selects on
    the same subsets, at cadence k=1 and k=2."""
    res = _run_forced_devices(_SUBPROCESS, 4)
    for k in (1, 2):
        assert res[f"schedule_identical_k{k}"] is True
        assert res[f"sel_identical_k{k}"] is True
        assert res[f"rounds_identical_k{k}"] is True
        assert res[f"devices_k{k}"] == 4
        assert res[f"resident_k{k}"] == 16
