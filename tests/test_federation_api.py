"""The composable Federation API: legacy mode-string vs explicit
policy-object parity on BOTH engines, mid-training save/restore to
bit-identical histories, callbacks, and the new policy variants end-to-end."""
import numpy as np
import jax
import pytest

from repro.core.federation import (Callback, Federation, MetricsCapture,
                                   RoundSchedule, SaveBestCallback,
                                   VerboseLogger)
from repro.core.hfl import (FederatedClient, HFLConfig,
                            run_federated_training)
from repro.core.policies import (AlphaBlend, AlwaysSwitch, ArgminSelection,
                                 FederationPolicies, LastWriteWins,
                                 MaxStaleness, PerFeatureAlpha,
                                 SoftmaxSelection)

ENGINES = ("sequential", "batched")


def _mk_clients(cfg, C=3, nf=2, n=40, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(30), mk(30),
                                   jax.random.PRNGKey(i)))
    return out


# ---------------------------------------------------------------------------
# Legacy mode strings == explicit policy objects, on both engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("mode", ("hfl", "always", "random", "no"))
def test_mode_string_equals_policy_objects(mode, engine):
    cfg = HFLConfig(mode=mode, epochs=5, R=20, patience=2)
    h_mode = Federation(_mk_clients(cfg), cfg, engine=engine).fit()
    h_pol = Federation(_mk_clients(cfg), cfg,
                       policies=FederationPolicies.from_config(cfg),
                       engine=engine).fit()
    for name in h_mode:
        assert h_mode[name]["selections"] == h_pol[name]["selections"]
        assert h_mode[name]["rounds"] == h_pol[name]["rounds"]
        assert h_mode[name]["val"] == h_pol[name]["val"]


@pytest.mark.parametrize("engine", ENGINES)
def test_legacy_shim_equals_federation_api(engine):
    """run_federated_training(clients, cfg) is a pure pass-through."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    h_shim = run_federated_training(_mk_clients(cfg), cfg, engine=engine)
    h_fed = Federation(_mk_clients(cfg), cfg, engine=engine).fit()
    assert h_shim == h_fed


def test_policy_runs_match_across_engines():
    """Deterministic policy bundles (incl. the NEW staleness + per-feature
    alpha variants) reproduce the sequential oracle's selections exactly on
    the batched engine."""
    cfg = HFLConfig(mode="always", epochs=4, R=20)
    pol = FederationPolicies(AlwaysSwitch(), ArgminSelection(),
                             PerFeatureAlpha((0.1, 0.4)), MaxStaleness(2))
    h_seq = Federation(_mk_clients(cfg), cfg, policies=pol,
                       engine="sequential").fit()
    h_bat = Federation(_mk_clients(cfg), cfg, policies=pol,
                       engine="batched").fit()
    assert any(h_seq[n]["rounds"] > 0 for n in h_seq)
    for name in h_seq:
        assert h_seq[name]["selections"] == h_bat[name]["selections"]
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"]
        np.testing.assert_allclose(h_seq[name]["val"], h_bat[name]["val"],
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("engine", ENGINES)
def test_softmax_selection_trains(engine):
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    pol = FederationPolicies(AlwaysSwitch(), SoftmaxSelection(0.5),
                             AlphaBlend(0.2), LastWriteWins())
    h = Federation(_mk_clients(cfg), cfg, policies=pol, engine=engine).fit()
    for v in h.values():
        assert v["rounds"] > 0 and np.isfinite(v["test"])
        assert all(len(s) == 2 for s in v["selections"])


def test_unknown_engine_rejected():
    cfg = HFLConfig(epochs=1, R=20)
    with pytest.raises(ValueError, match="unknown engine"):
        Federation(_mk_clients(cfg), cfg, engine="warp")


# ---------------------------------------------------------------------------
# Resumable state: save/restore mid-training is bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_save_restore_bit_identical_resume(tmp_path, engine):
    cfg = HFLConfig(mode="hfl", epochs=8, R=20, patience=2)
    h_straight = Federation(_mk_clients(cfg), cfg, engine=engine).fit()

    fed = Federation(_mk_clients(cfg), cfg, engine=engine)
    fed.fit(epochs=4)
    fed.save(tmp_path / "ck")
    restored = Federation.restore(tmp_path / "ck", _mk_clients(cfg))
    assert restored.epoch == 4 and restored.engine == engine
    h_resumed = restored.fit()          # the remaining 4 epochs

    for name in h_straight:
        assert h_straight[name]["val"] == h_resumed[name]["val"]
        assert h_straight[name]["selections"] == \
            h_resumed[name]["selections"]
        assert h_straight[name]["rounds"] == h_resumed[name]["rounds"]
        assert h_straight[name]["best_val"] == h_resumed[name]["best_val"]
    assert any(h_straight[n]["rounds"] > 0 for n in h_straight)


def test_save_restore_random_mode_preserves_rng_stream(tmp_path):
    """The host selection rng stream continues bit-identically across a
    checkpoint (mode=random consumes it every round)."""
    cfg = HFLConfig(mode="random", epochs=6, R=20)
    h_straight = Federation(_mk_clients(cfg), cfg).fit()
    fed = Federation(_mk_clients(cfg), cfg)
    fed.fit(epochs=3)
    fed.save(tmp_path / "ck")
    h_resumed = Federation.restore(tmp_path / "ck", _mk_clients(cfg)).fit()
    for name in h_straight:
        assert h_straight[name]["selections"] == \
            h_resumed[name]["selections"]


def test_save_best_callback_seeds_best_across_restarts(tmp_path):
    """A SaveBestCallback pointed at an existing checkpoint adopts its best
    metric instead of clobbering it with the first (possibly worse) epoch."""
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    sb = SaveBestCallback(tmp_path / "b")
    Federation(_mk_clients(cfg), cfg, callbacks=[sb]).fit()
    assert np.isfinite(sb.best)
    sb2 = SaveBestCallback(tmp_path / "b")
    sb2.on_fit_start(None)
    assert sb2.best == sb.best


@pytest.mark.parametrize("engine", ENGINES)
def test_save_mid_epoch_is_rejected(tmp_path, engine):
    """on_round fires mid-epoch, where a save would checkpoint unlogged
    selections and an un-advanced epoch counter — must raise, not corrupt."""
    class MidEpochSaver(Callback):
        def __init__(self):
            self.raised = 0

        def on_round(self, fed, epoch, rnd):
            with pytest.raises(RuntimeError, match="epoch boundary"):
                fed.save(tmp_path / "mid")
            self.raised += 1

    cfg = HFLConfig(mode="always", epochs=1, R=20)
    saver = MidEpochSaver()
    Federation(_mk_clients(cfg), cfg, engine=engine,
               callbacks=[saver]).fit()
    assert saver.raised > 0
    assert not (tmp_path / "mid").exists()


def test_checkpoint_survives_interrupted_resave(tmp_path):
    """The manifest is the commit point: a crash that only managed to write
    a newer state file leaves the previously committed pair restorable."""
    cfg = HFLConfig(mode="always", epochs=4, R=20)
    fed = Federation(_mk_clients(cfg), cfg)
    fed.fit(epochs=2)
    fed.save(tmp_path / "ck")
    # simulate an interrupt after the state write, before the manifest swap
    (tmp_path / "ck" / "state_00000099.msgpack").write_bytes(b"torn")
    restored = Federation.restore(tmp_path / "ck", _mk_clients(cfg))
    assert restored.epoch == 2
    # a completed re-save prunes superseded state files
    restored.fit(epochs=1)
    restored.save(tmp_path / "ck")
    states = sorted(p.name for p in (tmp_path / "ck").glob("state_*"))
    assert states == ["state_00000003.msgpack"]


def test_restore_rejects_mismatched_clients(tmp_path):
    cfg = HFLConfig(epochs=2, R=20)
    fed = Federation(_mk_clients(cfg), cfg)
    fed.save(tmp_path / "ck")
    wrong = _mk_clients(cfg, C=2)
    with pytest.raises(ValueError, match="do not match"):
        Federation.restore(tmp_path / "ck", wrong)


def test_restore_rebuilds_policies_from_spec(tmp_path):
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    pol = FederationPolicies(AlwaysSwitch(), SoftmaxSelection(0.7),
                             PerFeatureAlpha((0.1, 0.2)), MaxStaleness(3))
    fed = Federation(_mk_clients(cfg), cfg, policies=pol)
    fed.save(tmp_path / "ck")
    restored = Federation.restore(tmp_path / "ck", _mk_clients(cfg))
    assert restored.policies == pol


# ---------------------------------------------------------------------------
# Callbacks
# ---------------------------------------------------------------------------

class _Recorder(Callback):
    def __init__(self):
        self.events = []

    def on_fit_start(self, fed):
        self.events.append("start")

    def on_round(self, fed, epoch, round_idx):
        self.events.append(("round", epoch, round_idx))

    def on_epoch_end(self, fed, epoch, val, active):
        self.events.append(("epoch", epoch))

    def on_fit_end(self, fed, results):
        self.events.append("end")


@pytest.mark.parametrize("engine", ENGINES)
def test_callback_hooks_fire_in_order(engine):
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    rec = _Recorder()
    Federation(_mk_clients(cfg, n=40), cfg, engine=engine,
               callbacks=[rec]).fit()
    assert rec.events[0] == "start" and rec.events[-1] == "end"
    # 40 samples / R=20 -> 2 sub-rounds per epoch, 2 epochs
    assert rec.events.count(("round", 0, 0)) == 1
    assert [e for e in rec.events if e[0] == "round"] == \
        [("round", 0, 0), ("round", 0, 1), ("round", 1, 0), ("round", 1, 1)]
    assert [e for e in rec.events if e[0] == "epoch"] == \
        [("epoch", 0), ("epoch", 1)]


@pytest.mark.parametrize("engine", ENGINES)
def test_metrics_capture_and_verbose(engine, capsys):
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    metrics = MetricsCapture()
    Federation(_mk_clients(cfg), cfg, engine=engine,
               callbacks=[metrics, VerboseLogger()]).fit()
    assert len(metrics.epochs) == 2
    assert set(metrics.epochs[0]["val"]) == {"c0", "c1", "c2"}
    assert all(metrics.epochs[0]["active"].values())
    out = capsys.readouterr().out
    assert "epoch   0" in out and "c0=" in out


@pytest.mark.parametrize("engine", ENGINES)
def test_save_best_callback_checkpoints_improvements(tmp_path, engine):
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    sb = SaveBestCallback(tmp_path / "best")
    Federation(_mk_clients(cfg), cfg, engine=engine, callbacks=[sb]).fit()
    assert sb.n_saves >= 1
    restored = Federation.restore(tmp_path / "best", _mk_clients(cfg))
    assert 1 <= restored.epoch <= 3
    # a mid-fit checkpoint must carry trained state, not init state: the
    # saved epoch's history must be present and resumable
    assert all(len(c.val_history) == restored.epoch
               for c in restored.clients)
    h = restored.fit()               # completes the remaining schedule
    assert all(len(v["val"]) == 3 for v in h.values())


# ---------------------------------------------------------------------------
# RoundSchedule
# ---------------------------------------------------------------------------

def test_custom_schedule_R_drives_both_engines_identically():
    """A RoundSchedule with R different from cfg.R must govern BOTH
    executors' sub-round slicing (selections stay engine-identical)."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    sched = RoundSchedule(epochs=3, R=10)
    h_seq = Federation(_mk_clients(cfg), cfg, schedule=sched,
                       engine="sequential").fit()
    h_bat = Federation(_mk_clients(cfg), cfg, schedule=sched,
                       engine="batched").fit()
    for name in h_seq:
        # 40 samples / R=10 -> 4 sub-rounds x 3 epochs, not 2 x 3
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"] == 12
        assert h_seq[name]["selections"] == h_bat[name]["selections"]


def test_round_schedule_slices():
    s = RoundSchedule(epochs=3, R=20)
    assert list(s.slices(40)) == [slice(0, 20), slice(20, 40)]
    assert list(s.slices(59)) == [slice(0, 20), slice(20, 40)]
    assert list(s.slices(19)) == []
    assert s.sub_rounds(40) == 2 and s.sub_rounds(19) == 0


def test_round_schedule_drops_trailing_partial_batch():
    """slices() yields FULL R-batches only: the trailing n % R events are
    dropped from every epoch, and leftover() reports exactly how many."""
    s = RoundSchedule(epochs=1, R=20)
    assert list(s.slices(59)) == [slice(0, 20), slice(20, 40)]  # 19 dropped
    assert s.leftover(59) == 19
    assert s.leftover(40) == 0
    assert s.leftover(19) == 19        # too short for even one sub-round
    covered = sum(sl.stop - sl.start for sl in s.slices(59))
    assert covered + s.leftover(59) == 59


@pytest.mark.parametrize("engine", ENGINES)
def test_fit_warns_when_schedule_drops_events(engine):
    """Ragged train lengths must not lose data SILENTLY: fit announces the
    per-client dropped-event counts with a UserWarning."""
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    with pytest.warns(UserWarning, match="drops the trailing partial"):
        Federation(_mk_clients(cfg, n=45), cfg, engine=engine).fit()


@pytest.mark.parametrize("engine", ENGINES)
def test_fit_does_not_warn_on_exact_multiples(engine):
    import warnings as _warnings

    cfg = HFLConfig(mode="always", epochs=1, R=20)
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", UserWarning)
        Federation(_mk_clients(cfg, n=40), cfg, engine=engine).fit()


def test_fit_partial_epochs_accumulates():
    cfg = HFLConfig(mode="always", epochs=6, R=20)
    fed = Federation(_mk_clients(cfg), cfg)
    fed.fit(epochs=2)
    assert fed.epoch == 2
    h = fed.fit()                        # completes the schedule
    assert fed.epoch == 6
    for v in h.values():
        assert len(v["val"]) == 6
