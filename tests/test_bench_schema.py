"""Schema pins for the bench artifacts: ``fl_scale_bench.validate_payload``
(BENCH_fl_scale.json) and ``privacy_bench.validate_payload``
(BENCH_privacy.json) must accept a well-formed payload and reject each
malformed mutation with a pointed error.  Tier-1, so the schemas cannot
drift silently; CI additionally smoke-runs the real benches through the
same validators."""
import copy
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "benchmarks"))

from fl_scale_bench import validate_payload  # noqa: E402
from privacy_bench import validate_payload as validate_privacy  # noqa: E402


def _payload():
    """A minimal well-formed payload (the shape main() writes)."""
    row = {
        "clients": 8, "engine": "batched", "hetero": False, "cohorts": 1,
        "devices": 4, "exchange_every": 2, "exchange_rounds": 2,
        "pool_bytes_gathered": 123456, "round_ms": 1.5,
        "client_rounds_per_s": 100.0, "dispatches_per_epoch": 1.0,
        "dispatch_path": "fused", "speedup_vs_sequential": 2.5,
        "population": 8, "participation_fraction": 1.0,
        "resident_clients": 8, "resident_state_bytes": 262144,
        "fault_rate": 0.0, "byzantine_frac": 0.0,
        "heads_rejected": 0, "waves_degraded": 0, "mean_val": None,
    }
    seq = dict(row, engine="sequential", devices=1, exchange_every=1,
               pool_bytes_gathered=0, speedup_vs_sequential=1.0)
    sampled = dict(row, clients=30, engine="participating+stratified",
                   population=100000, participation_fraction=0.0003,
                   resident_clients=30, resident_state_bytes=58900000,
                   speedup_vs_sequential=None)
    faulted = dict(sampled, engine="participating+fault0.2",
                   fault_rate=0.2, byzantine_frac=0.1,
                   heads_rejected=7, waves_degraded=2, mean_val=0.93)
    return {
        "benchmark": "fl_scale",
        "unix_time": 1700000000,
        "backend": "cpu",
        "device_count": 4,
        "platform": "linux",
        "config": {"epochs": 2, "R": 20, "nf": 4, "batches": 3,
                   "mode": "always", "population": False, "mesh": True,
                   "hetero": False, "clients": [8],
                   "engines": ["sequential", "batched"],
                   "exchange_every": [1, 2],
                   "population_size": 100000, "fraction": 0.0003,
                   "participation": "stratified", "waves": 2,
                   "fault_rate": [0.0, 0.2], "byzantine_frac": 0.1},
        "results": [seq, row, sampled, faulted],
        "profiles": {"8": {"train_us_per_round": 10.0,
                           "policy_us_per_round": 20.0,
                           "eval_us_per_epoch": 5.0,
                           "sub_rounds_per_epoch": 3,
                           "phase_split": {"train": 0.3, "policy": 0.65,
                                           "eval": 0.05}}},
    }


def test_accepts_well_formed_payload():
    validate_payload(_payload())


def test_accepts_null_speedup():
    """Sequential skipped at large C (--max-seq-clients): speedup is null."""
    p = _payload()
    p["results"][1]["speedup_vs_sequential"] = None
    validate_payload(p)


def test_round_trips_through_json():
    p = json.loads(json.dumps(_payload()))
    validate_payload(p)


@pytest.mark.parametrize("key", ("exchange_every", "exchange_rounds",
                                 "pool_bytes_gathered", "clients", "engine",
                                 "devices", "hetero", "cohorts", "round_ms",
                                 "client_rounds_per_s", "dispatch_path",
                                 "population", "participation_fraction",
                                 "resident_clients",
                                 "resident_state_bytes", "fault_rate",
                                 "heads_rejected", "waves_degraded"))
def test_rejects_row_with_missing_key(key):
    p = _payload()
    del p["results"][1][key]
    with pytest.raises(ValueError, match=key):
        validate_payload(p)


def test_rejects_bad_fault_fields():
    p = _payload()
    p["results"][3]["fault_rate"] = 1.5
    with pytest.raises(ValueError, match="fault_rate"):
        validate_payload(p)
    p = _payload()
    p["results"][3]["byzantine_frac"] = -0.1
    with pytest.raises(ValueError, match="byzantine_frac"):
        validate_payload(p)
    p = _payload()
    p["results"][3]["heads_rejected"] = -1
    with pytest.raises(ValueError, match="counters"):
        validate_payload(p)
    p = _payload()
    p["results"][3]["heads_rejected"] = 7.5      # non-int counter
    with pytest.raises(ValueError, match="heads_rejected"):
        validate_payload(p)
    p = _payload()
    p["results"][3]["mean_val"] = "low"
    with pytest.raises(ValueError, match="mean_val"):
        validate_payload(p)
    p = _payload()
    del p["config"]["fault_rate"]
    with pytest.raises(ValueError, match="fault_rate"):
        validate_payload(p)


@pytest.mark.parametrize("key,bad", (
    ("exchange_every", "2"),           # stringified int
    ("exchange_rounds", 2.5),          # non-int count
    ("pool_bytes_gathered", None),     # null bytes counter
    ("round_ms", "fast"),
    ("speedup_vs_sequential", "2x"),
))
def test_rejects_row_with_wrong_type(key, bad):
    p = _payload()
    p["results"][1][key] = bad
    with pytest.raises(ValueError, match=key):
        validate_payload(p)


def test_rejects_non_positive_cadence():
    p = _payload()
    p["results"][1]["exchange_every"] = 0
    with pytest.raises(ValueError, match="exchange_every"):
        validate_payload(p)


def test_rejects_bad_participation_fields():
    p = _payload()
    p["results"][2]["participation_fraction"] = 0.0
    with pytest.raises(ValueError, match="participation_fraction"):
        validate_payload(p)
    p = _payload()
    p["results"][2]["participation_fraction"] = 1.5
    with pytest.raises(ValueError, match="participation_fraction"):
        validate_payload(p)
    p = _payload()
    p["results"][2]["resident_clients"] = p["results"][2]["population"] + 1
    with pytest.raises(ValueError, match="resident_clients"):
        validate_payload(p)
    p = _payload()
    del p["config"]["population_size"]
    with pytest.raises(ValueError, match="population_size"):
        validate_payload(p)


def test_rejects_config_without_cadence_list():
    p = _payload()
    del p["config"]["exchange_every"]
    with pytest.raises(ValueError, match="exchange_every"):
        validate_payload(p)
    p = _payload()
    p["config"]["exchange_every"] = [1, "2"]
    with pytest.raises(ValueError, match="positive ints"):
        validate_payload(p)
    p = _payload()
    p["config"]["exchange_every"] = [0]
    with pytest.raises(ValueError, match="positive ints"):
        validate_payload(p)


def test_rejects_empty_results_and_bad_benchmark():
    p = _payload()
    p["results"] = []
    with pytest.raises(ValueError, match="empty"):
        validate_payload(p)
    p = _payload()
    p["benchmark"] = "other"
    with pytest.raises(ValueError):
        validate_payload(p)


def test_current_bench_file_validates_if_present():
    """The committed BENCH_fl_scale.json must always satisfy the schema."""
    path = ROOT / "BENCH_fl_scale.json"
    if not path.exists():
        pytest.skip("no committed bench file")
    validate_payload(json.loads(path.read_text()))


def test_rejects_malformed_profile():
    p = _payload()
    del p["profiles"]["8"]["phase_split"]["policy"]
    with pytest.raises(ValueError, match="policy"):
        validate_payload(p)


@pytest.mark.parametrize("tag", (
    "sequential", "batched", "batched+mesh", "participating+uniform",
    "participating+weighted", "participating+stratified",
    "participating+fault0.2", "participating+fault0",
))
def test_accepts_known_engine_tags(tag):
    p = _payload()
    p["results"][1]["engine"] = tag
    validate_payload(p)


@pytest.mark.parametrize("tag", (
    "batchd",                       # typo'd engine
    "mesh",                         # not a row tag
    "batched+mesh+extra",
    "participating",                # policy suffix missing
    "participating+fancy",          # unknown policy
    "participating+fault",          # rate missing
    "participating+faultx",         # non-numeric rate
    "participating+fault1.5",       # rate out of [0, 1]
    "",
))
def test_rejects_unknown_engine_tags(tag):
    """An unknown engine row tag is a schema violation: downstream
    dashboards key on the closed tag set, so a drifting label must fail
    validation instead of silently forking the series."""
    p = _payload()
    p["results"][1]["engine"] = tag
    with pytest.raises(ValueError, match="engine"):
        validate_payload(p)


# ---------------------------------------------------------------------------
# BENCH_privacy.json (benchmarks/privacy_bench.py)
# ---------------------------------------------------------------------------

def _privacy_payload():
    """A minimal well-formed payload (the shape privacy_bench writes)."""
    off = {"dp": False, "sigma": 0.0, "clip": None, "epsilon": 0.0,
           "releases": 0, "clip_events": 0, "attack_auc": 0.73,
           "mean_val": 0.99}
    on = {"dp": True, "sigma": 1.0, "clip": 5.0, "epsilon": 50.3,
          "releases": 160, "clip_events": 160, "attack_auc": 0.51,
          "mean_val": 0.99}
    return {
        "benchmark": "privacy",
        "unix_time": 1700000000,
        "backend": "cpu",
        "device_count": 1,
        "platform": "linux",
        "config": {"clients": 4, "epochs": 40, "R": 8, "nf": 3,
                   "n_train": 8, "n_eval": 40, "seed": 0, "lr": 0.05,
                   "engine": "batched", "clip": 5.0, "delta": 1e-5,
                   "sigmas": [0.3, 1.0, 2.0]},
        "results": [off, on],
    }


def test_privacy_accepts_well_formed_payload():
    validate_privacy(_privacy_payload())


def test_privacy_round_trips_through_json():
    validate_privacy(json.loads(json.dumps(_privacy_payload())))


@pytest.mark.parametrize("key", ("dp", "sigma", "clip", "epsilon",
                                 "releases", "clip_events", "attack_auc",
                                 "mean_val"))
def test_privacy_rejects_row_with_missing_key(key):
    p = _privacy_payload()
    del p["results"][1][key]
    with pytest.raises(ValueError, match=key):
        validate_privacy(p)


@pytest.mark.parametrize("key", ("clients", "epochs", "lr", "clip",
                                 "delta", "engine", "sigmas"))
def test_privacy_rejects_config_with_missing_key(key):
    p = _privacy_payload()
    del p["config"][key]
    with pytest.raises(ValueError, match=key):
        validate_privacy(p)


def test_privacy_rejects_bad_rows():
    p = _privacy_payload()
    p["results"][1]["attack_auc"] = 1.2       # AUC outside [0, 1]
    with pytest.raises(ValueError, match="attack_auc"):
        validate_privacy(p)
    p = _privacy_payload()
    p["results"][1]["releases"] = 160.5       # non-int counter
    with pytest.raises(ValueError, match="releases"):
        validate_privacy(p)
    p = _privacy_payload()
    p["results"][1]["epsilon"] = 0.0          # DP-on must spend epsilon
    with pytest.raises(ValueError, match="epsilon"):
        validate_privacy(p)
    p = _privacy_payload()
    p["results"][0]["epsilon"] = 1.0          # DP-off must NOT
    with pytest.raises(ValueError, match="epsilon"):
        validate_privacy(p)
    p = _privacy_payload()
    p["results"][1]["clip"] = None            # DP-on needs a clip bound
    with pytest.raises(ValueError, match="sigma/clip"):
        validate_privacy(p)
    p = _privacy_payload()
    p["config"]["sigmas"] = [1.0, -0.5]
    with pytest.raises(ValueError, match="sigmas"):
        validate_privacy(p)


def test_privacy_rejects_empty_results_and_wrong_benchmark():
    p = _privacy_payload()
    p["results"] = []
    with pytest.raises(ValueError, match="empty"):
        validate_privacy(p)
    p = _privacy_payload()
    p["benchmark"] = "fl_scale"
    with pytest.raises(ValueError, match="benchmark"):
        validate_privacy(p)


def test_current_privacy_bench_file_validates_if_present():
    """The committed BENCH_privacy.json must always satisfy the schema —
    and actually show the headline curve: the no-DP attack lands
    meaningfully above chance, every DP-on row collapses toward 0.5."""
    path = ROOT / "BENCH_privacy.json"
    if not path.exists():
        pytest.skip("no committed bench file")
    payload = json.loads(path.read_text())
    validate_privacy(payload)
    rows = payload["results"]
    assert any(not r["dp"] for r in rows) and any(r["dp"] for r in rows)
    for r in rows:
        if not r["dp"]:
            assert r["attack_auc"] >= 0.6
        else:
            assert abs(r["attack_auc"] - 0.5) <= 0.15
            assert r["epsilon"] > 0
