"""NS > 1 and truly heterogeneous feature spaces (paper §4.2: heads from any
user/feature can be selected by any other — they all map (w,) -> scalar)."""
import jax
import numpy as np
import pytest

from repro.core.hfl import (FederatedClient, HeadPool, HFLConfig,
                            federated_round, run_federated_training)


def _client(name, nf, seed, mode="always", n=120, R=20):
    rng = np.random.default_rng(seed)
    cfg = HFLConfig(mode=mode, epochs=2, R=R)
    mk = lambda m: (rng.normal(size=(m, nf, 3)).astype(np.float32),
                    rng.normal(size=(m, nf, 3)).astype(np.float32),
                    rng.normal(size=m).astype(np.float32))
    return FederatedClient(name, nf, cfg, mk(n), mk(30), mk(30),
                           jax.random.PRNGKey(seed))


def test_three_clients_different_feature_counts():
    """Clients with nf=3, 4, 5 share one pool; ns = sum of others' nf."""
    clients = [_client("a", 3, 0), _client("b", 4, 1), _client("c", 5, 2)]
    pool = HeadPool()
    for c in clients:
        pool.publish(c.name, c.params["heads"], c.nf)
    stacked, keys = pool.stacked_for("a")
    assert len(keys) == 4 + 5            # b's and c's heads
    stacked, keys = pool.stacked_for("c")
    assert len(keys) == 3 + 4
    # a full selection round works across heterogeneous sources
    rng = np.random.default_rng(0)
    for c in clients:
        xs, xd, y = c.train
        c._recent = (xd[:20], y[:20])
        chosen = federated_round(c, pool, rng)
        assert chosen is not None and len(chosen) == c.nf


def test_full_training_three_heterogeneous_clients():
    clients = [_client("a", 3, 0, mode="hfl"), _client("b", 4, 1, mode="hfl"),
               _client("c", 2, 2, mode="hfl")]
    cfg = HFLConfig(mode="hfl", epochs=4, R=20)
    hist = run_federated_training(clients, cfg)
    assert set(hist) == {"a", "b", "c"}
    for h in hist.values():
        assert len(h["val"]) == 4
        assert np.isfinite(h["test"])


def test_selection_crosses_feature_boundaries():
    """A head trained on one user's feature j can win selection for a
    different user's feature i — the heterogeneous-transfer property."""
    import jax.numpy as jnp
    from repro.core import networks as N
    from repro.core.hfl import pool_errors
    from repro.sharding import spec as S

    w = 3
    heads = [S.materialize(N.head_schema(w), jax.random.PRNGKey(i))
             for i in range(6)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *heads)
    xd = jax.random.normal(jax.random.PRNGKey(7), (50, w))
    y = N.head_apply(heads[4], xd)  # target behaves like source head 4
    errs = pool_errors(stacked, xd, y)
    assert int(jnp.argmin(errs)) == 4
