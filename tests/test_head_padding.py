"""Inert-head padding (§Perf iter D1): padded and unpadded attention must be
bit-for-bit equivalent in outputs AND gradients."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch import steps
from repro.models import model as M
from repro.sharding import spec as S


def _pair(arch, pad_q, pad_kv):
    cfg = smoke_config(arch)
    cfg_pad = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, n_heads_padded=pad_q,
                                      n_kv_heads_padded=pad_kv))
    return cfg, cfg_pad


@pytest.mark.parametrize("arch,pq,pkv", [
    ("musicgen-medium", 6, 6),     # MHA 4/4 -> 6/6
    ("recurrentgemma-2b", 6, None),  # MQA 4/1 -> 6/1
])
def test_padded_forward_and_grad_equal(arch, pq, pkv):
    cfg, cfg_pad = _pair(arch, pq, pkv)
    params = S.materialize(M.model_schema(cfg), jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(key, (2, cfg.n_codebooks, 16), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens}

    def loss(c):
        def f(p):
            return M.lm_loss(p, c, batch, dtype=jnp.float32)[0]
        return f

    l0, g0 = jax.value_and_grad(loss(cfg))(params)
    l1, g1 = jax.value_and_grad(loss(cfg_pad))(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_padded_decode_matches_prefill():
    cfg, cfg_pad = _pair("musicgen-medium", 6, 6)
    params = S.materialize(M.model_schema(cfg_pad), jax.random.PRNGKey(0))
    B, T = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(5),
                                (B, cfg.n_codebooks, T), 0, cfg.vocab_size)
    h, _ = M.forward(params, cfg_pad, {"tokens": tokens}, dtype=jnp.float32,
                     remat=False)
    full = M.output_logits(params, cfg_pad, h)
    cache = M.init_cache(cfg_pad, B, T, jnp.float32)
    serve = jax.jit(steps.make_serve_step(cfg_pad, T, dtype=jnp.float32))
    outs = []
    for t in range(T):
        logits, cache = serve(params, cache, tokens[..., t:t + 1],
                              jnp.int32(t))
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1).reshape(full.shape)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
