"""Sharded-vs-replicated Eq.-7 selection: the client-sharded engine's
per-device argmin + merge must equal ``jnp.argmin`` on the full error
matrix — including exact score ties and fully-stale (all-``inf``) pools.

THE PINNED TIE-BREAK RULE: ties resolve to the LOWEST flat pool index —
``jnp.argmin``'s first occurrence.  The sharded reduce preserves it by
construction: each device's local argmin is the first occurrence within
its contiguous chunk, chunk offsets grow with device index, and
``merge_sharded_argmin`` takes the smallest global index among the chunks
achieving the global minimum.  A fully-``inf`` row (every candidate
excluded or stale) reduces to index 0 on both paths; the engine masks
those selections to -1 via ``any_valid`` before they are ever logged, so
the index is never observable — but the reduce must still agree, because
it runs unconditionally inside the scan.

These tests exercise the reduce as pure functions (chunking a host matrix
exactly the way ``_policy_round_body`` slices the flattened pool), so they
pin the semantics on every device count without needing a mesh; the
subprocess tests in test_mesh_federation.py pin the same rule end-to-end
on genuine 4- and 8-device meshes.  Hypothesis broadens the sweep when
installed; the seeded sweeps below always run.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.federation import merge_sharded_argmin, shard_argmin


def _sharded_select(errs: np.ndarray, D: int) -> np.ndarray:
    """Reference driver: split (nf, ns) column-wise into D contiguous
    chunks — exactly `_policy_round_body`'s dynamic slices — reduce each
    with shard_argmin, merge with merge_sharded_argmin."""
    nf, ns = errs.shape
    assert ns % D == 0
    chunk = ns // D
    vals, gidx = [], []
    for d in range(D):
        lv, gi = shard_argmin(jnp.asarray(errs[:, d * chunk:(d + 1) * chunk]),
                              d * chunk)
        vals.append(lv)
        gidx.append(gi)
    return np.asarray(merge_sharded_argmin(jnp.stack(vals), jnp.stack(gidx),
                                           ns))


def _assert_matches_replicated(errs: np.ndarray, D: int):
    expect = np.argmin(errs, axis=1)
    got = _sharded_select(errs, D)
    np.testing.assert_array_equal(got, expect, err_msg=f"D={D}")


@pytest.mark.parametrize("D", (1, 2, 4, 8))
def test_random_matrices_match_replicated_argmin(D):
    rng = np.random.default_rng(0)
    for nf, ns in ((1, 8), (2, 16), (3, 24), (4, 64)):
        for _ in range(20):
            errs = rng.normal(size=(nf, ns)).astype(np.float32)
            _assert_matches_replicated(errs, D)


@pytest.mark.parametrize("D", (2, 4))
def test_exact_ties_resolve_to_lowest_flat_index(D):
    """Duplicated minima — within a chunk, straddling chunk boundaries, and
    on every position — must select the lowest flat index, like argmin."""
    rng = np.random.default_rng(1)
    nf, ns = 2, 16
    for _ in range(50):
        errs = rng.normal(size=(nf, ns)).astype(np.float32)
        # plant an exact duplicate of the row minimum at 2 extra positions
        for f in range(nf):
            j = int(np.argmin(errs[f]))
            dup = rng.choice(ns, size=2, replace=False)
            errs[f, dup] = errs[f, j]
        _assert_matches_replicated(errs, D)
    # exhaustive two-way ties across every position pair
    for a in range(ns):
        for b in range(a + 1, ns):
            errs = np.ones((1, ns), np.float32)
            errs[0, [a, b]] = -1.0
            got = _sharded_select(errs, D)
            assert got[0] == a


@pytest.mark.parametrize("D", (1, 2, 4))
def test_fully_stale_pool_reduces_to_index_zero(D):
    """An all-inf row (everything excluded/stale) is degenerate on both
    paths: jnp.argmin gives 0, and the merge must too (inf == inf, so the
    achieves-mask is all-True and the min global index is 0).  The engine
    never logs this index — any_valid masks the selection to -1."""
    errs = np.full((3, 8), np.inf, np.float32)
    _assert_matches_replicated(errs, D)
    # one finite entry among inf: that entry wins on every device count
    errs[1, 5] = 0.0
    got = _sharded_select(errs, D)
    assert got[1] == 5 and got[0] == 0 and got[2] == 0


def test_constant_rows_tie_everywhere():
    errs = np.zeros((2, 12), np.float32)
    for D in (1, 2, 3, 4, 6):
        np.testing.assert_array_equal(_sharded_select(errs, D), [0, 0])


def test_chunk_scoring_equals_full_sweep_slice():
    """The kernel-level guarantee the sharded path leans on: scoring a
    contiguous pool chunk is BITWISE the corresponding column slice of the
    full Eq.-7 sweep (row independence)."""
    from repro.core import networks as N
    from repro.kernels.pool_mlp import ops
    from repro.sharding import spec as S
    import jax

    rng = np.random.default_rng(2)
    nf, C, R, w = 2, 4, 10, 5
    ns = C * nf
    heads = [S.materialize(N.hfl_schema(nf, w), jax.random.PRNGKey(i))["heads"]
             for i in range(C)]
    # flatten per-client (nf, ...) head trees into one (ns, ...) pool tree
    pool = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *heads)
    xd = jnp.asarray(rng.normal(size=(nf, R, w)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=R).astype(np.float32))
    full = np.asarray(ops.pool_mlp_errors_features(pool, xd, y))
    for D in (2, 4):
        chunk = ns // D
        for d in range(D):
            piece = jax.tree_util.tree_map(
                lambda p: p[d * chunk:(d + 1) * chunk], pool)
            got = np.asarray(ops.pool_mlp_errors_shard(piece, xd, y))
            np.testing.assert_array_equal(
                got, full[:, d * chunk:(d + 1) * chunk])
    # masked variant: invalid rows come back +inf, valid rows bit-equal
    valid = np.ones(ns, bool)
    valid[3] = valid[6] = False
    masked_full = np.asarray(ops.pool_mlp_errors_features_masked(
        pool, xd, y, jnp.asarray(valid)))
    for d in range(2):
        chunk = ns // 2
        piece = jax.tree_util.tree_map(
            lambda p: p[d * chunk:(d + 1) * chunk], pool)
        got = np.asarray(ops.pool_mlp_errors_shard(
            piece, xd, y, jnp.asarray(valid[d * chunk:(d + 1) * chunk])))
        np.testing.assert_array_equal(
            got, masked_full[:, d * chunk:(d + 1) * chunk])


# ---------------------------------------------------------------------------
# Hypothesis: the same property over generated matrices (skip offline)
# ---------------------------------------------------------------------------

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def _err_matrices(draw):
        nf = draw(st.integers(1, 3))
        chunk = draw(st.integers(1, 6))
        D = draw(st.sampled_from([1, 2, 4]))
        ns = chunk * D
        vals = draw(st.lists(
            st.floats(-10, 10, allow_nan=False, width=32)
            | st.just(float("inf")),
            min_size=nf * ns, max_size=nf * ns))
        errs = np.asarray(vals, np.float32).reshape(nf, ns)
        # force ties: copy each row's min to a drawn set of positions
        for f in range(nf):
            if np.isfinite(errs[f]).any():
                j = int(np.nanargmin(errs[f]))
                n_dup = draw(st.integers(0, ns - 1))
                dups = draw(st.permutations(range(ns)))[:n_dup]
                errs[f, list(dups)] = errs[f, j]
        return errs, D

    @settings(max_examples=200, deadline=None)
    @given(_err_matrices())
    def test_property_sharded_equals_replicated(case):
        errs, D = case
        _assert_matches_replicated(errs, D)
