"""int8 KV-cache quantization (§Perf iter B4): halved cache bytes, bounded
quality loss vs the bf16 cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch import steps
from repro.models import model as M
from repro.sharding import spec as S


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b"])
def test_quantized_decode_close_to_fp(arch):
    cfg = smoke_config(arch)
    params = S.materialize(M.model_schema(cfg), jax.random.PRNGKey(0))
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0,
                                cfg.vocab_size)
    serve = jax.jit(steps.make_serve_step(cfg, T, dtype=jnp.float32))

    def run(kv_quant):
        cache = M.init_cache(cfg, B, T, jnp.float32, kv_quant=kv_quant)
        outs = []
        c = cache
        for t in range(T):
            logits, c = serve(params, c, tokens[..., t:t + 1], jnp.int32(t))
            outs.append(logits)
        return jnp.concatenate(outs, axis=1)

    fp = run(False)
    q8 = run(True)
    # bounded degradation: logits close, same argmax for ~all positions
    diff = jnp.max(jnp.abs(fp - q8))
    assert float(diff) < 0.35, float(diff)
    agree = jnp.mean((jnp.argmax(fp, -1) == jnp.argmax(q8, -1))
                     .astype(jnp.float32))
    assert float(agree) >= 0.9, float(agree)


def test_quant_cache_halves_bytes():
    cfg = smoke_config("qwen3-0.6b")
    sch_fp = M.cache_schema(cfg, 4, 64, jnp.bfloat16)
    sch_q8 = M.cache_schema(cfg, 4, 64, jnp.bfloat16, kv_quant=True)

    def nbytes(sch):
        return sum(s.size * jnp.dtype(s.dtype).itemsize
                   for s in jax.tree_util.tree_leaves(sch, is_leaf=S.is_spec))

    ratio = nbytes(sch_q8) / nbytes(sch_fp)
    assert ratio < 0.6, ratio      # int8 entries + small fp16 scales


def test_quant_roundtrip_accuracy():
    from repro.models.layers.attention import _quantize_kv
    t = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 64)) * 3.0
    q, s = _quantize_kv(t)
    deq = q.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    rel = float(jnp.max(jnp.abs(deq - t)) / jnp.max(jnp.abs(t)))
    assert rel < 0.01
