"""Per-architecture smoke tests (reduced same-family variants: <=2 layers,
d_model<=512, <=4 experts): one forward/train step + one decode step on CPU,
asserting output shapes and no NaNs.  The FULL configs are exercised only via
the dry-run (deliverable e)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, smoke_config, get_config
from repro.launch import steps
from repro.models import model as M

B, SQ = 2, 32
ARCHS = list_archs()


def _batch(cfg, key):
    if cfg.n_codebooks > 1:
        batch = {"tokens": jax.random.randint(
            key, (B, cfg.n_codebooks, SQ), 0, cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(key, (B, SQ), 0, cfg.vocab_size)}
    if cfg.vlm:
        batch["image_embeds"] = jax.random.normal(key, (B, 8, M.VISION_DIM))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(SQ), (3, B, SQ)).astype(jnp.int32)
    return batch


def test_all_archs_have_configs():
    assert len(ARCHS) == 10
    families = {get_config(a).family for a in ARCHS}
    assert families == {"dense", "moe", "hybrid", "ssm", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "qwen3-0.6b": (28, 1024, 151_936), "deepseek-v3-671b": (61, 7168, 129_280),
        "olmoe-1b-7b": (16, 2048, 50_304), "recurrentgemma-2b": (26, 2560, 256_000),
        "gemma2-9b": (42, 3584, 256_000), "granite-3-2b": (40, 2048, 49_155),
        "granite-3-8b": (40, 4096, 49_155), "qwen2-vl-7b": (28, 3584, 152_064),
        "musicgen-medium": (48, 1536, 2048), "xlstm-350m": (24, 1024, 50_304),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.vocab_size) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    opt = steps.default_optimizer(1e-3)
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    ts = jax.jit(steps.make_train_step(cfg, opt))
    state2, metrics = ts(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(state2["step"]) == 1
    # params actually changed
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(state2["params"])))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(arch):
    cfg = smoke_config(arch)
    opt = steps.default_optimizer(1e-3)
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, B, 16, jnp.bfloat16)
    serve = jax.jit(steps.make_serve_step(cfg, 16))
    tok = _batch(cfg, jax.random.PRNGKey(1))["tokens"][..., :1]
    logits, cache2 = serve(state["params"], cache, tok, jnp.int32(0))
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, 1, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma2-9b", "deepseek-v3-671b",
                                  "recurrentgemma-2b", "xlstm-350m",
                                  "musicgen-medium"])
def test_decode_matches_prefill(arch):
    """Token-by-token decode with the KV/recurrent cache must reproduce the
    full-sequence forward logits (fp32, no kernels)."""
    import dataclasses
    cfg = smoke_config(arch)
    if cfg.moe is not None:
        # dropless capacity so decode and prefill see identical expert routing
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    schema = M.model_schema(cfg)
    from repro.sharding import spec as S
    params = S.materialize(schema, jax.random.PRNGKey(0))
    T = 12
    key = jax.random.PRNGKey(5)
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(key, (B, cfg.n_codebooks, T), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    h, _ = M.forward(params, cfg, batch, dtype=jnp.float32, remat=False)
    full_logits = M.output_logits(params, cfg, h)

    cache = M.init_cache(cfg, B, T, jnp.float32)
    serve = jax.jit(steps.make_serve_step(cfg, T, dtype=jnp.float32))
    outs = []
    for t in range(T):
        tok_t = tokens[..., t:t + 1]
        logits, cache = serve(params, cache, tok_t, jnp.int32(t))
        outs.append(logits)
    dec_logits = jnp.concatenate(outs, axis=1)
    if cfg.n_codebooks > 1:
        dec_logits = dec_logits.reshape(full_logits.shape)
    import numpy as np
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_cache_is_ring():
    """Decoding past the window must keep only the last `window` tokens."""
    cfg = smoke_config("gemma2-9b")  # has local window 64
    from repro.sharding import spec as S
    params = S.materialize(M.model_schema(cfg), jax.random.PRNGKey(0))
    W = cfg.local_window
    cache = M.init_cache(cfg, 1, W, jnp.float32)
    # local layer cache length is min(window, cache_len) = W
    k_shapes = jax.tree_util.tree_map(lambda x: x.shape, cache)
    l0 = cache["seg0"]["l0"]["k"]
    assert l0.shape[2] == W
