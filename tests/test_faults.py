"""Fault-tolerance layer (repro.core.faults + the engines' admission guard
+ the participation layer's dropout-tolerant waves and self-healing store).

Pins the ISSUE-8 acceptance surface: with a disabled FaultPlan the engines
are bit-identical to their no-plan selves on the batched, cohort, and mesh
paths; with injected dropout + byzantine heads training completes, no
poisoned head is ever admitted to the pool (dispatch_stats counters + pool
finiteness), the fault schedule is a pure function of (seed, wave, index)
so it replays across engines and save/restore; and the ClientStore detects
single-byte corruption by checksum and rebuilds from the deterministic
per-index builder."""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import faults as FT
from repro.core.experiment import tensor_population
from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import HeadPool, HFLConfig
from repro.core.participation import (ClientStore, ParticipatingFederation,
                                      StoreCorruption, UniformParticipation,
                                      entry_checksum)
from repro.core.policies import policy_from_spec

ROOT = Path(__file__).resolve().parent.parent


def _cfg(**kw):
    kw.setdefault("epochs", 3)
    kw.setdefault("R", 10)
    kw.setdefault("mode", "hfl")
    kw.setdefault("seed", 0)
    return HFLConfig(**kw)


def _pop(cfg, n=8, nf_choices=(3,), seed=0):
    return tensor_population(n, cfg, seed=seed, nf_choices=nf_choices,
                             n_train=20, n_eval=10)


# ---------------------------------------------------------------------------
# FaultPlan units
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="dropout"):
        FT.FaultPlan(dropout=1.5)
    with pytest.raises(ValueError, match="byzantine"):
        FT.FaultPlan(byzantine=-0.1)
    with pytest.raises(ValueError, match="corruption"):
        FT.FaultPlan(corruption="gremlins")
    with pytest.raises(ValueError, match="norm_bound"):
        FT.FaultPlan(norm_bound=0.0)


def test_fault_plan_enabled_and_spec_roundtrip():
    assert not FT.FaultPlan().enabled            # all-zero plan is inert
    plan = FT.FaultPlan(dropout=0.2, byzantine=0.1, corruption="inf",
                        norm_bound=50.0, seed=9)
    assert plan.enabled
    spec = plan.spec()
    again = policy_from_spec(json.loads(json.dumps(spec)))
    assert again == plan


def test_wave_faults_json_roundtrip():
    wf = FT.WaveFaults(wave=3, dropped=(1, 4), stragglers=(2,),
                       byzantine=(7,))
    assert FT.WaveFaults.from_json(json.loads(json.dumps(wf.to_json()))) \
        == wf
    assert wf.degraded
    assert not FT.WaveFaults(wave=0, stragglers=(1,)).degraded


# ---------------------------------------------------------------------------
# reround_wave geometry
# ---------------------------------------------------------------------------

def test_reround_keeps_survivors_in_sample_order():
    kept, dropped = FT.reround_wave([3, 1, 7, 5], [1, 5])
    assert kept == [3, 7] and dropped == [1, 5]


def test_reround_revives_to_one_multiple():
    # all four drawn dropped on a 4-multiple: everyone revives
    kept, dropped = FT.reround_wave([0, 1, 2, 3], [0, 1, 2, 3], multiple=4)
    assert kept == [0, 1, 2, 3] and dropped == []
    # a wave never goes empty even with multiple=1
    kept, dropped = FT.reround_wave([5, 9], [5, 9])
    assert kept == [5] and dropped == [9]


def test_reround_trims_to_multiple():
    # 8 sampled, 2 dropped -> 6 survivors, trimmed to 4 (highest indices)
    kept, dropped = FT.reround_wave(list(range(8)), [0, 1], multiple=4)
    assert kept == [2, 3, 4, 5] and dropped == [0, 1, 6, 7]
    assert len(kept) % 4 == 0


# ---------------------------------------------------------------------------
# FaultInjector determinism + corruption modes
# ---------------------------------------------------------------------------

def test_injector_draws_are_index_addressable():
    """The same (seed, wave, index) faults identically no matter what other
    indices are in the wave — the property that makes schedules replay
    across engines and device counts.  (dropout=0 so geometry re-rounding
    cannot reclassify anyone between the two calls.)"""
    inj = FT.FaultInjector(FT.FaultPlan(straggler=0.4, byzantine=0.4,
                                        seed=2))
    cls = lambda wf, i: ("strag" if i in wf.stragglers else
                         "byz" if i in wf.byzantine else "ok")
    a = inj.wave_faults(5, list(range(12)))
    b = inj.wave_faults(5, [3, 4, 5])
    assert [cls(a, i) for i in (3, 4, 5)] == [cls(b, i) for i in (3, 4, 5)]
    assert inj.wave_faults(5, list(range(12))) == a   # stateless replay
    assert inj.wave_faults(6, list(range(12))) != a   # wave-keyed draws


def test_corruption_modes():
    heads = {"w": np.ones((2, 3), np.float32), "b": np.full((2,), 2.0,
                                                            np.float32)}
    for mode, check in (
            ("nan", lambda a: np.isnan(a).all()),
            ("inf", lambda a: np.isposinf(a).all()),
            ("explode", lambda a: (np.abs(a) > 1e9).all()),
            ("signflip", lambda a: (a < 0).all())):
        inj = FT.FaultInjector(FT.FaultPlan(byzantine=1.0, corruption=mode))
        bad = inj.corrupt_heads(heads, wave=0, index=3)
        for leaf in jax.tree_util.tree_leaves(bad):
            assert check(np.asarray(leaf)), mode
            assert leaf.dtype == np.float32
        # deterministic: the same (wave, index) corrupts identically
        again = inj.corrupt_heads(heads, wave=0, index=3)
        np.testing.assert_array_equal(bad["w"], again["w"])


def test_heads_admissible():
    ok = {"w": np.ones((2, 2), np.float32)}
    assert FT.heads_admissible(ok, 1e6)
    assert not FT.heads_admissible({"w": np.full((2, 2), np.nan,
                                                 np.float32)}, 1e6)
    assert not FT.heads_admissible({"w": np.full((2, 2), np.inf,
                                                 np.float32)}, 1e6)
    assert not FT.heads_admissible({"w": np.full((2, 2), 1e9,
                                                 np.float32)}, 1e6)
    # documented limitation: a sign-flip preserves the norm and passes
    assert FT.heads_admissible({"w": -np.ones((2, 2), np.float32)}, 1e6)


def test_pool_fresh_mask_hides_quarantined_rows():
    pool = HeadPool()
    heads = {"w": np.zeros((2, 1, 1), np.float32)}
    pool.publish("a", heads, 2)
    pool.publish("b", heads, 2, age=FT.QUARANTINE_AGE)
    mask = pool.fresh_mask("z")                 # unbounded, exclude no one
    keys = sorted(k for k in pool.entries)
    assert mask.tolist() == [k[0] != "b" for k in keys]
    # clean republication revives
    pool.publish("b", heads, 2)
    assert pool.fresh_mask("z").all()


# ---------------------------------------------------------------------------
# Disabled plan == no plan: bit-identity parity pins (batched + cohort)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nf_choices", [(3,), (2, 4)],
                         ids=["batched", "cohort"])
def test_disabled_plan_is_bit_identical(nf_choices):
    def run(faults):
        cfg = _cfg()
        clients = _pop(cfg, n=6, nf_choices=nf_choices).build(range(6))
        fed = Federation(clients, cfg, schedule=RoundSchedule(3, 10),
                         engine="batched", faults=faults)
        return fed.fit(), fed

    h0, f0 = run(None)
    h1, f1 = run(FT.FaultPlan())                  # all-zero plan
    for n in h0:
        assert h0[n]["val"] == h1[n]["val"]
        assert h0[n]["selections"] == h1[n]["selections"]
    assert f1.dispatch_stats["heads_rejected"] == 0
    assert f1.dispatch_stats["stragglers"] == 0


# ---------------------------------------------------------------------------
# Byzantine quarantine: no poisoned head is ever admitted
# ---------------------------------------------------------------------------

def _pool_is_finite(pf):
    for k, e in pf.pool_entries.items():
        for leaf in jax.tree_util.tree_leaves(e):
            if not np.all(np.isfinite(np.asarray(leaf))):
                return False
    return True


@pytest.mark.parametrize("corruption", ["nan", "explode"])
@pytest.mark.parametrize("engine", ["batched", "sequential"])
def test_byzantine_heads_quarantined(engine, corruption):
    cfg = _cfg(mode="always")
    plan = FT.FaultPlan(byzantine=0.5, corruption=corruption, seed=3)
    pf = ParticipatingFederation(
        _pop(cfg), cfg,
        participation=UniformParticipation(fraction=0.5, min_clients=4),
        schedule=RoundSchedule(3, 10), engine=engine, faults=plan)
    pf.fit()
    st = pf.dispatch_stats
    assert st["heads_rejected"] > 0
    assert any(w.byzantine for w in pf.fault_log)
    assert _pool_is_finite(pf)
    # quarantined seed rows sit at the sentinel age, zeroed
    byz_names = {pf.population.name_of(i)
                 for w in pf.fault_log for i in w.byzantine}
    assert byz_names
    if corruption == "nan":
        # a NaN client's own history goes NaN (sacrificial by design) but
        # the shared pool never serves its head
        assert any(not np.all(np.isfinite(pf.store.get(n)["val_history"]))
                   for n in byz_names if n in pf.store)


def test_byzantine_rejections_agree_across_engines():
    cfg = _cfg(mode="always")
    plan = FT.FaultPlan(byzantine=0.5, corruption="nan", seed=3)

    def run(engine):
        pf = ParticipatingFederation(
            _pop(cfg), cfg,
            participation=UniformParticipation(fraction=0.5, min_clients=4),
            schedule=RoundSchedule(3, 10), engine=engine, faults=plan)
        pf.fit()
        return pf

    b, s = run("batched"), run("sequential")
    assert [w.to_json() for w in b.fault_log] \
        == [w.to_json() for w in s.fault_log]
    assert b.dispatch_stats["heads_rejected"] \
        == s.dispatch_stats["heads_rejected"] > 0


# ---------------------------------------------------------------------------
# Dropout-tolerant waves + stragglers
# ---------------------------------------------------------------------------

def test_dropout_waves_complete_and_count():
    cfg = _cfg(epochs=6)
    plan = FT.FaultPlan(dropout=0.4, seed=1)
    pf = ParticipatingFederation(
        _pop(cfg, n=12), cfg,
        participation=UniformParticipation(fraction=0.5, min_clients=6),
        schedule=RoundSchedule(6, 10), faults=plan)
    pf.fit()
    st = pf.dispatch_stats
    assert st["waves"] == 6                       # every wave completed
    assert st["clients_dropped"] > 0
    assert st["waves_degraded"] > 0
    assert st["waves_degraded"] \
        == sum(1 for w in pf.fault_log if w.degraded)
    # degraded waves ran with the re-rounded active set
    for row, wf in zip(pf.wave_log, pf.fault_log):
        assert set(row["active"]).isdisjoint(wf.dropped)


def test_stragglers_train_but_never_exchange():
    cfg = _cfg(mode="always")
    plan = FT.FaultPlan(straggler=1.0, seed=0)
    pf = ParticipatingFederation(
        _pop(cfg), cfg,
        participation=UniformParticipation(fraction=0.5, min_clients=4),
        schedule=RoundSchedule(2, 10), faults=plan)
    pf.fit()
    st = pf.dispatch_stats
    assert st["stragglers"] > 0
    # nobody exchanged: every resident client's round count is zero, yet
    # training happened (val histories advanced)
    assert all(v == 0 for v in pf.n_rounds.values())
    assert all(len(pf.store.get(n)["val_history"]) > 0
               for n in pf.store.names())


# ---------------------------------------------------------------------------
# Seeded schedule save/restores bit-identically
# ---------------------------------------------------------------------------

def test_fault_schedule_save_restore_bit_identical():
    cfg = _cfg(epochs=6)
    mk = lambda: _pop(cfg, n=10)
    plan = FT.FaultPlan(dropout=0.3, straggler=0.2, byzantine=0.3,
                        corruption="nan", seed=5)

    def build(pop):
        return ParticipatingFederation(
            pop, cfg,
            participation=UniformParticipation(fraction=0.4, min_clients=4),
            schedule=RoundSchedule(6, 10), faults=plan)

    a = build(mk())
    a.fit(waves=3)
    with tempfile.TemporaryDirectory() as d:
        a.save(d)
        b = ParticipatingFederation.restore(d, mk())
        assert b.faults == plan
        assert [w.to_json() for w in b.fault_log] \
            == [w.to_json() for w in a.fault_log]
        ha = a.fit(waves=3)
        hb = b.fit(waves=3)
    same = lambda x, y: np.array_equal(np.asarray(x, np.float64),
                                       np.asarray(y, np.float64),
                                       equal_nan=True)
    for n in ha:
        assert same(ha[n]["val"], hb[n]["val"]), n
        assert ha[n]["selections"] == hb[n]["selections"], n
    assert [w.to_json() for w in a.fault_log] \
        == [w.to_json() for w in b.fault_log]


# ---------------------------------------------------------------------------
# ClientStore checksums + self-healing rebuild
# ---------------------------------------------------------------------------

def _put_dummy(store, name, val=1.0):
    tree = {"w": np.full((3, 2), val, np.float32)}
    store.put(name, params=tree, opt_state=tree, best_params=tree,
              best_val=val, val_history=[val])


def test_store_checksum_roundtrip_and_single_byte_corruption():
    store = ClientStore()
    _put_dummy(store, "a")
    assert store.get("a")["best_val"] == 1.0      # clean round-trip
    # flip ONE byte of one leaf in place — every byte position must flip
    # the checksum (crc32 covers the full buffer)
    leaf = store._states["a"]["params"]["w"]
    raw = leaf.view(np.uint8).reshape(-1)
    for pos in (0, len(raw) // 2, len(raw) - 1):
        raw[pos] ^= 0xFF
        with pytest.raises(StoreCorruption, match="checksum"):
            store.get("a")
        raw[pos] ^= 0xFF                          # restore
        store.get("a")                            # clean again


def test_entry_checksum_covers_scalars():
    store = ClientStore()
    _put_dummy(store, "a")
    entry = store._states["a"]
    crc = entry_checksum(entry)
    entry["best_val"] = 2.0
    assert entry_checksum(entry) != crc
    entry["best_val"] = 1.0
    entry["val_history"] = [1.0, 1.0]
    assert entry_checksum(entry) != crc


def test_store_discard_and_rebuild_parity():
    """After a corrupt entry is discarded, the population's deterministic
    builder reproduces the client bit-exactly — the rebuild path."""
    cfg = _cfg()
    pop = _pop(cfg, n=4)
    a = pop.build([2])[0]
    b = pop.build([2])[0]
    for la, lb in zip(jax.tree_util.tree_leaves(a.params),
                      jax.tree_util.tree_leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    tr_a, tr_b = a.train, b.train
    for ta, tb in zip(tr_a, tr_b):
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_corrupted_store_entry_heals_during_fit():
    cfg = _cfg(epochs=2)
    pop = _pop(cfg, n=4)
    pf = ParticipatingFederation(
        pop, cfg,
        participation=UniformParticipation(fraction=1.0, min_clients=4),
        schedule=RoundSchedule(2, 10))
    pf.fit(waves=1)
    # corrupt one stored entry between waves (host memory fault) —
    # swap in a copy with one byte flipped, leaving the recorded crc stale
    victim = pf.store.names()[0]
    st = pf.store._states[victim]
    leaves, treedef = jax.tree_util.tree_flatten(st["params"])
    bad = np.array(leaves[0], copy=True)
    bad.view(np.uint8).reshape(-1)[0] ^= 0xFF
    st["params"] = jax.tree_util.tree_unflatten(
        treedef, [bad] + leaves[1:])
    pf.fit(waves=1)                               # completes, self-heals
    assert pf.dispatch_stats["store_rebuilds"] == 1
    assert victim in pf.store                     # re-put after the wave
    pf.store.get(victim)                          # and verifies clean


# ---------------------------------------------------------------------------
# Acceptance: forced 4-device mesh — 20% dropout + 10% byzantine completes,
# counters fire, restore replays bit-identically, disabled plan is parity
# ---------------------------------------------------------------------------

_SUBPROCESS = r"""
import json
import tempfile
import jax
assert jax.device_count() == 4, jax.devices()
import numpy as np
from repro.core import faults as FT
from repro.core.experiment import tensor_population
from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import HFLConfig
from repro.core.mesh_federation import make_mesh
from repro.core.participation import (ParticipatingFederation,
                                      UniformParticipation)

cfg = HFLConfig(epochs=4, R=10, mode="always", seed=3)
mkpop = lambda: tensor_population(16, cfg, seed=1, nf_choices=(3,),
                                  n_train=20, n_eval=10)
res = {}

# 1) disabled-plan parity on the mesh engine
def full(faults):
    fed = Federation(mkpop().build(range(16)), cfg,
                     schedule=RoundSchedule(2, 10), engine="batched",
                     mesh=make_mesh(), faults=faults)
    return fed.fit()
h0, h1 = full(None), full(FT.FaultPlan())
res["mesh_parity"] = all(
    h0[n]["val"] == h1[n]["val"]
    and h0[n]["selections"] == h1[n]["selections"] for n in h0)

# 2) 20% dropout + 10% byzantine on the mesh completes with clean pool
plan = FT.FaultPlan(dropout=0.2, byzantine=0.1, corruption="nan", seed=2)
def build(pop):
    return ParticipatingFederation(
        pop, cfg,
        participation=UniformParticipation(fraction=0.75, min_clients=8),
        schedule=RoundSchedule(4, 10), engine="batched", mesh=make_mesh(),
        faults=plan)
pf = build(mkpop())
pf.fit(waves=2)
with tempfile.TemporaryDirectory() as d:
    pf.save(d)
    rf = ParticipatingFederation.restore(d, mkpop(), mesh=make_mesh())
    ha = pf.fit(waves=2)
    hb = rf.fit(waves=2)
st = pf.dispatch_stats
res["devices"] = st["devices"]
res["waves"] = st["waves"]
res["clients_dropped"] = st["clients_dropped"]
res["waves_degraded"] = st["waves_degraded"]
res["heads_rejected_total"] = (st["heads_rejected"]
                               + rf.dispatch_stats["heads_rejected"])
res["dropout_wave_completed"] = any(w.degraded for w in pf.fault_log) \
    and st["waves"] == 2
res["geometry_multiple_held"] = all(
    len(w["active"]) % 4 == 0 for w in pf.wave_log)
res["pool_finite"] = all(
    bool(np.all(np.isfinite(np.asarray(l))))
    for e in pf.pool_entries.values()
    for l in jax.tree_util.tree_leaves(e))
same = lambda x, y: np.array_equal(np.asarray(x, np.float64),
                                   np.asarray(y, np.float64),
                                   equal_nan=True)
res["restore_bit_identical"] = (
    all(same(ha[n]["val"], hb[n]["val"])
        and ha[n]["selections"] == hb[n]["selections"] for n in ha)
    and [w.to_json() for w in pf.fault_log]
    == [w.to_json() for w in rf.fault_log])
print("RESULT " + json.dumps(res))
"""


def _run_forced_devices(script: str, n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    return json.loads(line[-1][len("RESULT "):])


def test_faults_on_forced_4_device_mesh():
    """ISSUE 8 acceptance: the mesh engine with a disabled plan is
    bit-identical to no plan; with 20% dropout + 10% byzantine every wave
    completes on 4 devices at 4-multiple geometry, the counters fire, the
    pool stays finite, and an interrupted run restores bit-identically."""
    res = _run_forced_devices(_SUBPROCESS, 4)
    assert res["mesh_parity"] is True
    assert res["devices"] == 4
    assert res["waves"] == 2
    assert res["dropout_wave_completed"] is True
    assert res["geometry_multiple_held"] is True
    assert res["clients_dropped"] > 0
    assert res["waves_degraded"] > 0
    assert res["heads_rejected_total"] > 0
    assert res["pool_finite"] is True
    assert res["restore_bit_identical"] is True
