"""Optimizer library tests (built from scratch — optax is not available)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip offline
from hypothesis import given, settings, strategies as st

from repro.optim import (adam, adamw, apply_updates, chain,
                         clip_by_global_norm, cosine_schedule, sgd,
                         warmup_cosine_schedule)


def _quadratic_params():
    return {"x": jnp.array([3.0, -2.0]), "y": {"z": jnp.array(5.0)}}


def _loss(p):
    return jnp.sum(p["x"] ** 2) + p["y"]["z"] ** 2


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adam(0.3), adamw(0.3, weight_decay=0.0)])
def test_optimizers_minimize_quadratic(opt):
    params = _quadratic_params()
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(_loss)(params)
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(_loss(params)) < 1e-2


def test_clip_by_global_norm():
    clip = clip_by_global_norm(1.0)
    grads = {"a": jnp.array([3.0, 4.0])}        # norm 5
    upd, _ = clip.update(grads, clip.init(grads))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(upd["a"])), 1.0, rtol=1e-5)
    small = {"a": jnp.array([0.3, 0.4])}
    upd, _ = clip.update(small, clip.init(small))
    np.testing.assert_allclose(upd["a"], small["a"], rtol=1e-6)


def test_chain_composes():
    opt = chain(clip_by_global_norm(1.0), sgd(1.0))
    grads = {"a": jnp.array([30.0, 40.0])}
    state = opt.init(grads)
    upd, _ = opt.update(grads, state, grads)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(upd["a"])), 1.0,
                               rtol=1e-5)


def test_adam_bias_correction_first_step():
    opt = adam(0.1, b1=0.9, b2=0.999)
    params = {"a": jnp.array(0.0)}
    state = opt.init(params)
    grads = {"a": jnp.array(2.0)}
    upd, _ = opt.update(grads, state, params)
    # first Adam step magnitude = lr regardless of gradient scale
    np.testing.assert_allclose(abs(float(upd["a"])), 0.1, rtol=1e-4)


def test_schedules():
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(0)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1)
    wc = warmup_cosine_schedule(1.0, 10, 110)
    assert float(wc(0)) == pytest.approx(0.1)
    assert float(wc(9)) == pytest.approx(1.0)
    assert float(wc(109)) < 0.2


@settings(max_examples=25, deadline=None)
@given(lr=st.floats(1e-4, 0.5), g=st.floats(-10, 10, allow_nan=False))
def test_sgd_update_is_minus_lr_g(lr, g):
    opt = sgd(lr)
    params = {"a": jnp.array(1.0)}
    upd, _ = opt.update({"a": jnp.array(g)}, opt.init(params), params)
    np.testing.assert_allclose(float(upd["a"]), -lr * g, rtol=1e-5,
                               atol=1e-7)
