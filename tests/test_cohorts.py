"""Heterogeneous cohort engine: mixed-nf, ragged-length populations on the
batched fast path must reproduce the sequential oracle — identical
selections and round counts, validation histories equal to float precision
(the discrete decisions are exact; values can differ in the last ulp
because the cohort-stacked train step batches its matmuls differently from
the oracle's per-client steps, the same tolerance story as the homogeneous
engine's oracle-parity pins).  Within the batched family (fused vs chunked,
save/restore) results are bit-identical.

The mesh tests run over whatever devices the host exposes (1 in plain
tier-1 — the fallback path; 4 under the CI cohort-parity step's
``XLA_FLAGS=--xla_force_host_platform_device_count=4``); the subprocess
acceptance test ALWAYS exercises a genuine 4-device mesh against a mixed
population, regardless of the parent's device count."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cohorts as CO
from repro.core import mesh_federation as MF
from repro.core.federation import Callback, Federation, _selection_lut
from repro.core.hfl import FederatedClient, HFLConfig

ROOT = Path(__file__).resolve().parent.parent

# (nf, n_train) per client: 3 cohorts — two multi-client, one singleton —
# with ragged train lengths (47 also exercises the partial-batch drop)
MIXED = ((3, 60), (2, 40), (3, 60), (4, 47), (2, 40))


def _mk_clients(cfg, spec=MIXED, seed0=100, n_eval=30):
    out = []
    for i, (nf, n) in enumerate(spec):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m, nf=nf: (
            rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
            rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
            rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(n_eval),
                                   mk(n_eval), jax.random.PRNGKey(i)))
    return out


def _fit_quiet(fed, **kw):
    with pytest.warns(UserWarning, match="partial batch"):
        return fed.fit(**kw)


class _RoundCounter(Callback):
    def __init__(self):
        self.rounds = []

    def on_round(self, fed, epoch, rnd):
        self.rounds.append((epoch, rnd))


def _assert_oracle_parity(h_seq, h_bat, *, rtol=1e-6, atol=1e-6):
    assert set(h_seq) == set(h_bat)
    for name in h_seq:
        assert h_seq[name]["selections"] == h_bat[name]["selections"]
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"]
        np.testing.assert_allclose(h_seq[name]["val"], h_bat[name]["val"],
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Planning
# ---------------------------------------------------------------------------

def test_plan_groups_by_nf_and_shapes():
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    plan = CO.plan_cohorts(_mk_clients(cfg), R=20)
    assert len(plan.cohorts) == 3
    assert [(co.nf, co.members, co.n_sub) for co in plan.cohorts] == [
        (3, (0, 2), 3), (2, (1, 4), 2), (4, (3,), 2)]
    assert plan.C == 5 and plan.max_nf == 4 and plan.n_sub_max == 3
    assert plan.nfs == (3, 2, 3, 4, 2)
    assert plan.n_subs == (3, 2, 3, 2, 2)


def test_plan_feat_valid_mask():
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    fv = CO.plan_cohorts(_mk_clients(cfg), R=20).feat_valid()
    assert fv.shape == (5, 4)
    assert fv.sum(axis=1).tolist() == [3, 2, 3, 4, 2]
    assert fv[1].tolist() == [True, True, False, False]


def test_plan_same_nf_different_lengths_split_cohorts():
    """Same nf but ragged lengths cannot stack — separate cohorts."""
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    plan = CO.plan_cohorts(_mk_clients(cfg, ((3, 40), (3, 60), (3, 40))),
                           R=20)
    assert [(co.nf, co.members) for co in plan.cohorts] == [
        (3, (0, 2)), (3, (1,))]


def test_plan_rejects_mixed_head_width():
    cfg_a = HFLConfig(mode="always", epochs=1, R=20, w=3)
    cfg_b = HFLConfig(mode="always", epochs=1, R=20, w=4)
    clients = _mk_clients(cfg_a, ((2, 40),)) + [
        FederatedClient("cw", 2, cfg_b,
                        *(_mk_clients(cfg_b, ((2, 40),))[0].train,) * 3,
                        jax.random.PRNGKey(9))]
    with pytest.raises(ValueError, match="head widths"):
        CO.plan_cohorts(clients, R=20)


def test_hetero_lut_matches_homogeneous_lut_on_uniform_nf():
    """With uniform nf the padded LUT must degenerate to the homogeneous
    engine's rectangular one."""
    names = ["b", "a", "c"]
    np.testing.assert_array_equal(
        CO.hetero_selection_lut(names, [3, 3, 3], 3),
        _selection_lut(names, 3))


def test_hetero_lut_mixed_nf():
    """Padded flat indices map to the oracle's sorted-by-(name, feature)
    foreign positions, with ragged per-client widths."""
    names, nfs = ["t", "a", "z"], [2, 3, 1]   # selector "t": foreign = a, z
    lut = CO.hetero_selection_lut(names, nfs, max_nf=3)
    # for "t" (row 0): a's 3 features rank 0..2, z's single feature rank 3
    assert lut[0, 1 * 3:2 * 3].tolist() == [0, 1, 2]
    assert lut[0, 2 * 3:3 * 3].tolist() == [3, -1, -1]
    assert lut[0, 0:3].tolist() == [-1, -1, -1]          # own rows
    # for "a" (row 1): t's 2 features rank 0..1, z's one ranks 2
    assert lut[1, 0:3].tolist() == [0, 1, -1]
    assert lut[1, 2 * 3:3 * 3].tolist() == [2, -1, -1]


# ---------------------------------------------------------------------------
# Oracle parity (the acceptance surface)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("always", "hfl"))
def test_cohorted_matches_sequential_oracle(mode):
    """Mixed-nf ragged population: the cohort engine's selections and round
    counts are identical to the sequential oracle, validation histories
    equal to float precision, via ONE fused dispatch per epoch."""
    cfg = HFLConfig(mode=mode, epochs=5, R=20, patience=2)
    h_seq = _fit_quiet(Federation(_mk_clients(cfg), cfg,
                                  engine="sequential"))
    fed = Federation(_mk_clients(cfg), cfg, engine="batched")
    h_bat = _fit_quiet(fed)
    st = fed.dispatch_stats
    assert st["path"] == "fused" and st["cohorts"] == 3
    assert st["dispatches_per_epoch"] == 1.0
    assert [pc["clients"] for pc in st["per_cohort"]] == [2, 2, 1]
    assert [pc["sub_rounds"] for pc in st["per_cohort"]] == [3, 2, 2]
    _assert_oracle_parity(h_seq, h_bat)
    if mode == "always":   # every client federates in every live sub-round
        assert [h_bat[f"c{i}"]["rounds"] for i in range(5)] == \
            [15, 10, 15, 10, 10]


def test_fully_ragged_singleton_cohorts_match_oracle():
    """Every client its own cohort (all lengths distinct): still correct,
    still one dispatch per epoch."""
    spec = ((2, 40), (3, 60), (4, 80), (2, 55))
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    h_seq = _fit_quiet(Federation(_mk_clients(cfg, spec), cfg,
                                  engine="sequential"))
    fed = Federation(_mk_clients(cfg, spec), cfg, engine="batched")
    h_bat = _fit_quiet(fed)
    assert fed.dispatch_stats["cohorts"] == 4
    assert fed.dispatch_stats["dispatches_per_epoch"] == 1.0
    _assert_oracle_parity(h_seq, h_bat)


def test_bounded_pool_staleness_matches_oracle():
    """MaxStaleness on a ragged population exercises the subtle staleness
    clock: the pool ages once per sub-round in which federation could run
    among still-live clients, and exhausted clients' entries go stale."""
    from repro.core.policies import (AlphaBlend, ArgminSelection,
                                     FederationPolicies, MaxStaleness,
                                     PlateauSwitch)
    pol = FederationPolicies(switch=PlateauSwitch(patience=1),
                             selection=ArgminSelection(),
                             transfer=AlphaBlend(alpha=0.2),
                             pool=MaxStaleness(max_age=2))
    cfg = HFLConfig(mode="hfl", epochs=6, R=20, patience=1)
    h_seq = _fit_quiet(Federation(_mk_clients(cfg), cfg, policies=pol,
                                  engine="sequential"))
    h_bat = _fit_quiet(Federation(_mk_clients(cfg), cfg, policies=pol,
                                  engine="batched"))
    _assert_oracle_parity(h_seq, h_bat)


def test_cohorted_kernel_path_matches_vmap_path():
    """use_pool_kernel=True sweeps the padded union pool through the Pallas
    kernel (zero-padded invalid rows masked to inf) — selections must be
    identical to the vmap fallback."""
    import dataclasses
    cfg_v = HFLConfig(mode="always", epochs=2, R=20)
    cfg_k = dataclasses.replace(cfg_v, use_pool_kernel=True)
    h_v = _fit_quiet(Federation(_mk_clients(cfg_v), cfg_v, engine="batched"))
    h_k = _fit_quiet(Federation(_mk_clients(cfg_k), cfg_k, engine="batched"))
    for name in h_v:
        assert h_v[name]["selections"] == h_k[name]["selections"]


# ---------------------------------------------------------------------------
# Fused vs chunked; callbacks
# ---------------------------------------------------------------------------

def test_cohorted_fused_equals_chunked_bit_identical():
    """Per-round callbacks force the chunked path — same compiled body per
    sub-round, every on_round fired (n_sub_max per epoch), results
    BIT-identical to the fused path."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    h_fused = _fit_quiet(Federation(_mk_clients(cfg), cfg,
                                    engine="batched"))
    counter = _RoundCounter()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                     callbacks=[counter])
    h_chunk = _fit_quiet(fed)
    assert fed.dispatch_stats["path"] == "chunked"
    assert fed.dispatch_stats["dispatches_per_epoch"] == 3.0   # n_sub_max
    assert counter.rounds == [(e, r) for e in range(3) for r in range(3)]
    for name in h_fused:
        assert h_fused[name]["selections"] == h_chunk[name]["selections"]
        assert h_fused[name]["rounds"] == h_chunk[name]["rounds"]
        np.testing.assert_array_equal(h_fused[name]["val"],
                                      h_chunk[name]["val"])


# ---------------------------------------------------------------------------
# Save/restore through the cohort path
# ---------------------------------------------------------------------------

def test_cohorted_save_restore_bit_identical(tmp_path):
    cfg = HFLConfig(mode="hfl", epochs=6, R=20, patience=2)
    h_straight = _fit_quiet(Federation(_mk_clients(cfg), cfg,
                                       engine="batched"))
    fed = Federation(_mk_clients(cfg), cfg, engine="batched")
    _fit_quiet(fed, epochs=3)
    fed.save(tmp_path / "ck")
    h_resumed = _fit_quiet(Federation.restore(tmp_path / "ck",
                                              _mk_clients(cfg)))
    for name in h_straight:
        assert h_straight[name]["val"] == h_resumed[name]["val"]
        assert h_straight[name]["selections"] == \
            h_resumed[name]["selections"]
        assert h_straight[name]["best_val"] == h_resumed[name]["best_val"]


# ---------------------------------------------------------------------------
# Mesh (in-process over the local device count; 4 devices in the CI step)
# ---------------------------------------------------------------------------

# 2 cohorts x 4 clients: shards evenly over 1, 2 or 4 devices
MESH_SPEC = ((2, 40), (3, 60), (2, 40), (3, 60),
             (2, 40), (3, 60), (2, 40), (3, 60))


def test_cohorted_mesh_matches_no_mesh():
    """mesh= on a heterogeneous population: identical selections and round
    counts, values within float precision, whatever the local device
    count (per-cohort client blocks batch their train matmuls differently,
    so the last ulp can move — selections cannot)."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    h_plain = Federation(_mk_clients(cfg, MESH_SPEC), cfg,
                         engine="batched").fit()
    fed = Federation(_mk_clients(cfg, MESH_SPEC), cfg, engine="batched",
                     mesh=MF.make_mesh())
    h_mesh = fed.fit()
    st = fed.dispatch_stats
    assert st["cohorts"] == 2 and st["path"] == "fused"
    assert st["devices"] == (len(jax.devices())
                             if len(jax.devices()) > 1 else 1)
    _assert_oracle_parity(h_plain, h_mesh, rtol=1e-6, atol=1e-6)


def test_cohorted_mesh_rejects_non_divisible_cohorts():
    if len(jax.devices()) < 2:
        pytest.skip("divisibility only binds on a multi-device mesh")
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    spec = MESH_SPEC + ((2, 40),)     # one cohort no longer divides D
    fed = Federation(_mk_clients(cfg, spec), cfg, engine="batched",
                     mesh=MF.make_mesh())
    with pytest.raises(ValueError, match="cohort sizes"):
        fed.fit()


# ---------------------------------------------------------------------------
# Acceptance pin: mixed population on a forced 4-device mesh (subprocess —
# jax locks the host platform device count at first init)
# ---------------------------------------------------------------------------

_SUBPROCESS = r"""
import json
import numpy as np
import jax
assert jax.device_count() == 4, jax.devices()
from repro.core.federation import Federation
from repro.core import mesh_federation as MF
from repro.core.hfl import FederatedClient, HFLConfig

SPEC = ((2, 40), (3, 60), (2, 40), (3, 60),
        (2, 40), (3, 60), (2, 40), (3, 60))

def mk_clients(cfg, seed0=100):
    out = []
    for i, (nf, n) in enumerate(SPEC):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m, nf=nf: (
            rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
            rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
            rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"h{i:03d}", nf, cfg, mk(n), mk(30),
                                   mk(30), jax.random.PRNGKey(i)))
    return out

cfg = HFLConfig(mode="always", epochs=3, R=20)
h_oracle = Federation(mk_clients(cfg), cfg, engine="sequential").fit()
fed = Federation(mk_clients(cfg), cfg, engine="batched",
                 mesh=MF.make_mesh())
h_mesh = fed.fit()
st = fed.dispatch_stats
assert st["devices"] == 4 and st["cohorts"] == 2, st
assert st["path"] == "fused" and st["dispatches_per_epoch"] == 1.0, st
sel_identical = all(h_oracle[n]["selections"] == h_mesh[n]["selections"]
                    for n in h_oracle)
rounds_identical = all(h_oracle[n]["rounds"] == h_mesh[n]["rounds"]
                       for n in h_oracle)
val_close = all(np.allclose(h_oracle[n]["val"], h_mesh[n]["val"],
                            rtol=1e-6, atol=1e-6) for n in h_oracle)
print("RESULT " + json.dumps({"sel_identical": sel_identical,
                              "rounds_identical": rounds_identical,
                              "val_close": val_close}))
"""


def test_mixed_population_on_forced_4_device_mesh():
    """ISSUE 5 acceptance: a mixed-nf ragged population client-shards its
    cohorts over a genuine 4-device `clients` mesh with selections
    identical to the sequential oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    res = json.loads(line[-1][len("RESULT "):])
    assert res == {"sel_identical": True, "rounds_identical": True,
                   "val_close": True}


# ---------------------------------------------------------------------------
# Padded union-pool pieces
# ---------------------------------------------------------------------------

def test_masked_kernel_sweep_infs_invalid_rows():
    """pool_mlp_errors_features_masked: valid rows equal the unmasked sweep,
    invalid (zero-padded) rows come back +inf."""
    from repro.core import networks as N
    from repro.kernels.pool_mlp.ops import (pool_mlp_errors_features,
                                            pool_mlp_errors_features_masked)
    from repro.sharding import spec as S

    w, R, ns, nf = 3, 20, 6, 2
    heads = [S.materialize(N.head_schema(w), jax.random.PRNGKey(i))
             for i in range(ns)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *heads)
    # zero two rows, as feature padding does
    valid = np.array([True, True, False, True, False, True])
    stacked = jax.tree_util.tree_map(
        lambda p: p * valid.reshape((ns,) + (1,) * (p.ndim - 1)), stacked)
    xd = jax.random.normal(jax.random.PRNGKey(1), (nf, R, w))
    y = jax.random.normal(jax.random.PRNGKey(2), (R,))
    ref = pool_mlp_errors_features(stacked, xd, y, block_pool=4)
    out = pool_mlp_errors_features_masked(stacked, xd, y,
                                          jnp.asarray(valid), block_pool=4)
    assert np.all(np.isinf(np.asarray(out)[:, ~valid]))
    np.testing.assert_array_equal(np.asarray(out)[:, valid],
                                  np.asarray(ref)[:, valid])


def test_stack_hetero_pool_pads_and_roundtrips():
    from repro.core.hfl import HeadPool
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    clients = _mk_clients(cfg)
    pool = HeadPool()
    for c in clients:
        pool.publish(c.name, c.params["heads"], c.nf)
    names = [c.name for c in clients]
    nfs = [c.nf for c in clients]
    stacked = CO.stack_hetero_pool(pool, names, nfs, max_nf=4)
    for leaf in jax.tree_util.tree_leaves(stacked):
        assert leaf.shape[:2] == (5, 4)
    # padded rows are zero; real rows round-trip exactly
    for i, c in enumerate(clients):
        row = jax.tree_util.tree_map(lambda p: p[i], stacked)
        for k in jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda p: p[c.nf:], row)):
            assert not np.any(k)
        orig = c.params["heads"]
        for a, b in zip(jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda p: p[:c.nf], row)),
                jax.tree_util.tree_leaves(orig)):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Generated heterogeneous populations (data + experiment layers)
# ---------------------------------------------------------------------------

def test_make_hetero_population_cycles_nf():
    from repro.data.synthetic import make_hetero_population
    pop = make_hetero_population(6, seed=0, nf_choices=(2, 3, 4),
                                 n_patients=4, n_events=120)
    assert [len(h.feature_names) for h in pop] == [2, 3, 4, 2, 3, 4]
    assert all(h.streams[0].nf == len(h.feature_names) for h in pop)


def test_hetero_population_trains_on_cohort_engine():
    from repro.core.experiment import hetero_population_clients
    cfg = HFLConfig(mode="always", epochs=2, R=10)
    clients, packs = hetero_population_clients(
        4, cfg, seed=0, n_patients=5, n_events=150, nf_choices=(2, 3))
    assert {c.nf for c in clients} == {2, 3}
    fed = Federation(clients, cfg, engine="batched")
    hist = fed.fit()
    assert fed.dispatch_stats["cohorts"] >= 2
    for h in hist.values():
        assert len(h["val"]) == 2 and np.isfinite(h["test"])
