"""Unit tests for the pluggable federation policies (core/policies.py):
switch edge cases, selection variants, transfer rules, pool staleness, and
the spec round-trip that backs resumable checkpoints."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import networks as N
from repro.core.hfl import HeadPool, HFLConfig, blend, switch_active
from repro.core.policies import (AlphaBlend, AlwaysSwitch, ArgminSelection,
                                 FederationPolicies, LastWriteWins,
                                 MaxStaleness, NeverSwitch, PerFeatureAlpha,
                                 PlateauSwitch, ProbSwitch, RandomSelection,
                                 SoftmaxSelection, TopKSelection,
                                 plateaued, policy_from_spec)
from repro.sharding import spec as S


def _head(seed, w=3):
    return S.materialize(N.head_schema(w), jax.random.PRNGKey(seed))


def _stack(heads):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *heads)


# ---------------------------------------------------------------------------
# Switch: plateau rule edge cases (and the legacy switch_active wrapper)
# ---------------------------------------------------------------------------

def test_plateau_empty_history():
    for patience in (0, 1, 3):
        assert not plateaued([], patience)
        assert not switch_active([], HFLConfig(mode="hfl", patience=patience))


def test_plateau_patience_one():
    assert not plateaued([5.0], 1)            # needs patience+1 epochs
    assert plateaued([5.0, 6.0], 1)           # last epoch >= best-before
    assert plateaued([5.0, 5.0], 1)           # equality counts as no improve
    assert not plateaued([5.0, 4.0], 1)       # still improving


def test_plateau_then_improve_resets():
    # plateaued for 2 epochs...
    assert plateaued([5.0, 3.0, 3.5, 3.4], 2)
    # ...then a fresh improvement within the window clears eligibility
    assert not plateaued([5.0, 3.0, 3.5, 2.9], 2)
    assert not plateaued([5.0, 3.0, 3.5, 3.4, 2.9], 2)
    # and re-plateauing after the improvement re-arms it
    assert plateaued([5.0, 3.0, 3.5, 2.9, 3.0, 3.1], 2)


def test_plateau_switch_matches_legacy_switch_active():
    histories = [[], [5.0], [5, 4, 3], [5, 3, 3.5, 3.4, 3.6],
                 [5, 3, 3.5, 2.9, 3.6], [2.0, 2.0, 2.0, 2.0]]
    for p in (0, 1, 2, 3):
        cfg = HFLConfig(mode="hfl", patience=p)
        pol = PlateauSwitch(patience=p)
        rng = np.random.default_rng(0)
        for h in histories:
            assert pol.active(h, rng) == switch_active(h, cfg), (p, h)


def test_always_never_prob_switch():
    rng = np.random.default_rng(0)
    assert AlwaysSwitch().active([], rng)
    assert not NeverSwitch().active([5.0] * 10, rng)
    assert not ProbSwitch(0.0).active([], rng)
    assert ProbSwitch(1.0).active([], rng)
    draws = [ProbSwitch(0.5).active([], np.random.default_rng(7))
             for _ in range(5)]
    redraws = [ProbSwitch(0.5).active([], np.random.default_rng(7))
               for _ in range(5)]
    assert draws == redraws                    # seeded determinism
    hits = sum(ProbSwitch(0.5).active([], rng) for _ in range(200))
    assert 60 < hits < 140                     # roughly Bernoulli(0.5)


# ---------------------------------------------------------------------------
# Selection variants
# ---------------------------------------------------------------------------

def test_argmin_and_topk1_select_min_error():
    errs = np.array([3.0, 0.5, 2.0, np.inf], np.float32)
    valid = np.isfinite(errs)
    rng = np.random.default_rng(0)
    assert ArgminSelection().select_host(errs, valid, rng) == 1
    assert TopKSelection(1).select_host(errs, valid, rng) == 1
    j = ArgminSelection().select_batched(jnp.asarray(errs)[None, :], None,
                                         None, nf=1, ns=4, i=0, bounded=False)
    assert int(j[0]) == 1


def test_topk_stays_inside_k_best_and_valid():
    errs = np.array([0.1, 0.2, 0.3, 5.0, np.inf, np.inf], np.float32)
    valid = np.isfinite(errs)
    rng = np.random.default_rng(0)
    picks = {TopKSelection(3).select_host(errs, valid, rng)
             for _ in range(50)}
    assert picks <= {0, 1, 2}
    assert len(picks) > 1                      # actually explores the top-k
    key = jax.random.PRNGKey(0)
    e = jnp.asarray(errs)[None, :]
    for s in range(20):
        j = TopKSelection(3).select_batched(
            e, None, jax.random.fold_in(key, s), nf=1, ns=6, i=0,
            bounded=False)
        assert int(j[0]) in (0, 1, 2)


def test_topk_k_larger_than_valid_pool():
    errs = np.array([0.4, np.inf, np.inf], np.float32)
    valid = np.isfinite(errs)
    rng = np.random.default_rng(0)
    for _ in range(10):
        assert TopKSelection(5).select_host(errs, valid, rng) == 0


def test_softmax_prefers_low_error_and_avoids_excluded():
    errs = np.array([0.01, 4.0, np.inf], np.float32)
    valid = np.isfinite(errs)
    rng = np.random.default_rng(0)
    picks = [SoftmaxSelection(0.5).select_host(errs, valid, rng)
             for _ in range(200)]
    assert 2 not in picks
    assert picks.count(0) > picks.count(1)
    key = jax.random.PRNGKey(3)
    e = jnp.asarray(errs)[None, :]
    bpicks = [int(SoftmaxSelection(0.5).select_batched(
        e, None, jax.random.fold_in(key, s), nf=1, ns=3, i=0,
        bounded=False)[0]) for s in range(100)]
    assert 2 not in bpicks
    assert bpicks.count(0) > bpicks.count(1)


def test_random_selection_masks():
    rng = np.random.default_rng(0)
    valid = np.array([False, True, False, True])
    picks = {RandomSelection().select_host(None, valid, rng)
             for _ in range(50)}
    assert picks == {1, 3}
    # batched legacy path: uniform over foreign entries only (own excluded)
    nf, C = 2, 3
    ns = C * nf
    for s in range(30):
        j = RandomSelection().select_batched(
            None, None, jax.random.PRNGKey(s), nf=nf, ns=ns, i=1,
            bounded=False)
        assert all(int(x) not in (2, 3) for x in j)    # client 1's own rows
    # bounded path: categorical over the exclusion mask
    excluded = jnp.asarray([True, False, True, True, False, True])
    for s in range(20):
        j = RandomSelection().select_batched(
            None, excluded, jax.random.PRNGKey(s), nf=nf, ns=ns, i=0,
            bounded=True)
        assert all(int(x) in (1, 4) for x in j)


# ---------------------------------------------------------------------------
# Transfer rules
# ---------------------------------------------------------------------------

def test_alpha_blend_matches_legacy_blend():
    a, b = _stack([_head(0), _head(1)]), _stack([_head(2), _head(3)])
    out_legacy = blend(a, b, 0.3)
    out_policy = AlphaBlend(0.3).apply(a, b)
    for x, y in zip(jax.tree_util.tree_leaves(out_legacy),
                    jax.tree_util.tree_leaves(out_policy)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-6)


def test_per_feature_alpha_blends_each_head_differently():
    t = _stack([_head(0), _head(1)])
    s = _stack([_head(2), _head(3)])
    out = PerFeatureAlpha((0.0, 1.0)).apply(t, s)
    for pt, ps, po in zip(jax.tree_util.tree_leaves(t),
                          jax.tree_util.tree_leaves(s),
                          jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(po[0]), np.asarray(pt[0]))
        np.testing.assert_allclose(np.asarray(po[1]), np.asarray(ps[1]))


# ---------------------------------------------------------------------------
# Pool staleness
# ---------------------------------------------------------------------------

def test_pool_ages_and_fresh_mask():
    pool = HeadPool()
    pool.publish("alice", _stack([_head(0), _head(1)]), nf=2)
    pool.publish("bob", _stack([_head(2), _head(3)]), nf=2)
    assert pool.fresh_mask("carol", max_age=0).all()
    pool.tick()
    pool.tick()
    pool.publish("alice", _stack([_head(4), _head(5)]), nf=2)  # age resets
    mask = pool.fresh_mask("carol", max_age=1)
    keys = [k for k in sorted(pool.entries)]
    by_key = dict(zip(keys, mask))
    assert by_key[("alice", 0)] and by_key[("alice", 1)]
    assert not by_key[("bob", 0)] and not by_key[("bob", 1)]
    # entries are hidden, never deleted (asynchrony: a republish revives)
    assert ("bob", 0) in pool.entries
    assert pool.fresh_mask("carol", max_age=None).all()
    assert pool.age_of("bob") == 2 and pool.age_of("alice") == 0


def test_pool_policy_bounded_flag():
    assert not LastWriteWins().bounded
    assert MaxStaleness(4).bounded and MaxStaleness(4).max_age == 4


# ---------------------------------------------------------------------------
# Bundle factory + spec round-trip
# ---------------------------------------------------------------------------

def test_from_config_maps_legacy_modes():
    cfg = HFLConfig(mode="hfl", patience=5, alpha=0.4)
    pol = FederationPolicies.from_config(cfg)
    assert pol == FederationPolicies(PlateauSwitch(5), ArgminSelection(),
                                     AlphaBlend(0.4), LastWriteWins())
    assert FederationPolicies.from_config(
        dataclasses.replace(cfg, mode="no")).switch == NeverSwitch()
    prand = FederationPolicies.from_config(
        dataclasses.replace(cfg, mode="random"))
    assert prand.switch == AlwaysSwitch()
    assert prand.selection == RandomSelection()
    assert FederationPolicies.from_config(
        dataclasses.replace(cfg, mode="always")).selection == \
        ArgminSelection()
    with pytest.raises(ValueError, match="unknown HFL mode"):
        FederationPolicies.from_config(dataclasses.replace(cfg, mode="boom"))


def test_spec_json_roundtrip():
    pol = FederationPolicies(ProbSwitch(0.25), TopKSelection(4),
                             PerFeatureAlpha((0.1, 0.2, 0.3)),
                             MaxStaleness(7))
    rebuilt = FederationPolicies.from_spec(
        json.loads(json.dumps(pol.spec())))
    assert rebuilt == pol


def test_unknown_policy_kind_rejected():
    with pytest.raises(ValueError, match="unknown policy kind"):
        policy_from_spec({"kind": "NotAPolicy"})


def test_degenerate_selection_params_rejected():
    with pytest.raises(ValueError, match="temperature"):
        SoftmaxSelection(0.0)
    with pytest.raises(ValueError, match="temperature"):
        SoftmaxSelection(-1.0)
    with pytest.raises(ValueError, match="k must be"):
        TopKSelection(0)


def test_plateaued_mask_matches_scalar_rule():
    """The jittable vectorized plateau mask and PlateauSwitch.active_mask
    both reproduce the scalar plateaued() elementwise across edge cases."""
    from repro.core.policies import plateaued_mask

    histories = [
        [5.0, 4.0, 3.0, 2.0],          # improving: not plateaued
        [5.0, 4.0, 4.0, 4.5],          # stalled for 2
        [1.0, 2.0, 3.0, 4.0],          # monotonically worse
        [2.0, 1.0, 1.0, 0.5],          # dips then improves
    ]
    rng = np.random.default_rng(0)
    for patience in (0, 1, 2, 3, 5):
        expect = [plateaued(h, patience) for h in histories]
        mask = np.asarray(plateaued_mask(np.asarray(histories), patience))
        assert mask.tolist() == expect, patience
        sw = PlateauSwitch(patience=patience)
        assert sw.active_mask(histories, rng).tolist() == expect, patience
    # empty histories (epoch 0)
    assert np.asarray(plateaued_mask(np.empty((3, 0)), 2)).tolist() == \
        [False] * 3
    assert PlateauSwitch(2).active_mask([[], [], []], rng).tolist() == \
        [False] * 3


def test_plateau_active_mask_exact_float64_and_ragged_fallback():
    """active_mask compares in exact float64 (a sub-float32 improvement
    must count as improvement, as in the scalar rule) and falls back to the
    per-client loop on ragged history lengths."""
    rng = np.random.default_rng(0)
    sw = PlateauSwitch(patience=1)
    h = [[1.0, 1.0 - 1e-12]]           # improvement below f32 resolution
    assert [plateaued(x, 1) for x in h] == [False]
    assert sw.active_mask(h, rng).tolist() == [False]
    ragged = [[3.0, 2.0], [3.0, 3.0, 3.0]]
    expect = [plateaued(x, 1) for x in ragged]
    assert sw.active_mask(ragged, rng).tolist() == expect
