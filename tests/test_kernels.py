"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm.ops import mlstm_chunkwise
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.pool_mlp.ops import pool_mlp_errors
from repro.kernels.pool_mlp.ref import pool_errors_ref
from repro.kernels.rg_lru.ops import rglru_scan
from repro.kernels.rg_lru.ref import linear_scan_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,KV,D", [
    (256, 4, 4, 64),     # MHA
    (256, 4, 2, 64),     # GQA
    (512, 8, 1, 32),     # MQA
    (128, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                        v.swapaxes(1, 2)).swapaxes(1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap", [(64, 0.0), (None, 30.0),
                                            (32, 20.0), (1, 0.0)])
def test_flash_attention_window_softcap(window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, D = 1, 256, 2, 1, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    out = flash_attention(q, k, v, window=window, logit_softcap=softcap)
    ref = attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
                        window=window, logit_softcap=softcap).swapaxes(1, 2)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_flash_attention_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, KV, D = 1, 512, 2, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    from repro.kernels.flash_attention.kernel import flash_attention_bhsd
    o1 = flash_attention_bhsd(q.swapaxes(1, 2), k.swapaxes(1, 2),
                              v.swapaxes(1, 2), bq=128, bkv=256)
    o2 = flash_attention_bhsd(q.swapaxes(1, 2), k.swapaxes(1, 2),
                              v.swapaxes(1, 2), bq=512, bkv=64)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rg_lru linear scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,d,chunk", [(256, 32, 64), (128, 128, 128),
                                       (512, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rglru_scan_shapes(S, d, chunk, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    B = 2
    a = jax.nn.sigmoid(jax.random.normal(k1, (B, S, d), dtype))
    b = jax.random.normal(k2, (B, S, d), dtype)
    out = rglru_scan(a, b, chunk=chunk)
    ref = linear_scan_ref(a, b)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_rglru_chunk_invariance():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    a = jax.nn.sigmoid(jax.random.normal(k1, (1, 256, 8)))
    b = jax.random.normal(k2, (1, 256, 8))
    o1 = rglru_scan(a, b, chunk=32)
    o2 = rglru_scan(a, b, chunk=256)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mlstm chunkwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,dh,chunk", [(256, 2, 32, 64), (128, 4, 16, 32),
                                          (256, 1, 64, 128)])
def test_mlstm_chunkwise_shapes(S, H, dh, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh)) / jnp.sqrt(dh)
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = 2.0 + jax.random.normal(ks[4], (B, S, H))
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
    ref = mlstm_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


def test_mlstm_extreme_gates_stable():
    """Stabilizer property: huge input gates / tiny forget gates must not
    produce NaN/Inf (the m-trick)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, S, H, dh = 1, 128, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    ig = 40.0 + jax.random.normal(ks[3], (B, S, H))
    fg = -40.0 + jax.random.normal(ks[4], (B, S, H))
    out = mlstm_chunkwise(q, k, v, ig, fg, chunk=32)
    ref = mlstm_ref(q, k, v, ig, fg)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# pool_mlp (Eq. 7 fused scoring)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ns,R,w,bp", [(10, 50, 3, 8), (4, 20, 5, 4),
                                       (16, 50, 3, 16), (3, 7, 2, 8)])
def test_pool_mlp_shapes(ns, R, w, bp):
    from repro.core.networks import head_schema
    from repro.sharding import spec as S

    pool = [S.materialize(head_schema(w), jax.random.PRNGKey(i))
            for i in range(ns)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pool)
    xd = jax.random.normal(jax.random.PRNGKey(99), (R, w))
    y = jax.random.normal(jax.random.PRNGKey(98), (R,))
    out = pool_mlp_errors(stacked, xd, y, block_pool=bp)
    ref = pool_errors_ref(stacked, xd, y)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    assert int(jnp.argmin(out)) == int(jnp.argmin(ref))


def _stacked_pool(ns, w, seed0=0):
    from repro.core.networks import head_schema
    from repro.sharding import spec as S
    pool = [S.materialize(head_schema(w), jax.random.PRNGKey(seed0 + i))
            for i in range(ns)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pool)


def test_pool_mlp_poisoned_rows_pinned_to_inf():
    """NaN/Inf pool heads must come back +inf — never NaN (argmin over NaN
    is backend-dependent) — and agree with the vmap fallback's pinning on
    every row, finite rows bit-matching the clean sweep."""
    from repro.core.hfl import pool_errors
    from repro.kernels.pool_mlp.ops import pool_mlp_errors_features

    ns, R, w, nf = 8, 20, 3, 2
    stacked = dict(_stacked_pool(ns, w))
    clean = pool_mlp_errors_features(
        stacked, jax.random.normal(jax.random.PRNGKey(9), (nf, R, w)),
        jax.random.normal(jax.random.PRNGKey(8), (R,)))
    stacked["w0"] = stacked["w0"].at[1].set(jnp.nan)
    stacked["b4"] = stacked["b4"].at[5].set(jnp.inf)
    xd = jax.random.normal(jax.random.PRNGKey(9), (nf, R, w))
    y = jax.random.normal(jax.random.PRNGKey(8), (R,))
    out = pool_mlp_errors_features(stacked, xd, y)
    ref = jax.vmap(lambda xf: pool_errors(stacked, xf, y))(xd)
    assert bool(jnp.all(jnp.isposinf(out[:, 1])))
    assert bool(jnp.all(jnp.isposinf(out[:, 5])))
    assert bool(jnp.all(jnp.isfinite(jnp.delete(out, jnp.array([1, 5]),
                                                axis=1))))
    # kernel and fallback agree everywhere (inf == inf; finite rows close)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    keep = [i for i in range(ns) if i not in (1, 5)]
    np.testing.assert_allclose(np.asarray(out[:, keep]),
                               np.asarray(clean[:, keep]),
                               rtol=1e-6, atol=0)
    assert int(jnp.argmin(out[0])) not in (1, 5)


def test_pool_mlp_nan_probe_pinned_to_inf():
    """A NaN probe batch poisons every score for that feature: both the
    kernel and the vmap fallback must return +inf across the row, so the
    selection layer sees a uniform worst-case, not NaN."""
    from repro.core.hfl import pool_errors
    from repro.kernels.pool_mlp.ops import pool_mlp_errors_features

    ns, R, w, nf = 6, 10, 3, 2
    stacked = _stacked_pool(ns, w)
    xd = jax.random.normal(jax.random.PRNGKey(3), (nf, R, w))
    xd = xd.at[1, 4, 0].set(jnp.nan)               # one bad sample
    y = jax.random.normal(jax.random.PRNGKey(4), (R,))
    out = pool_mlp_errors_features(stacked, xd, y)
    ref = jax.vmap(lambda xf: pool_errors(stacked, xf, y))(xd)
    assert bool(jnp.all(jnp.isfinite(out[0])))
    assert bool(jnp.all(jnp.isposinf(out[1])))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_pool_mlp_masked_and_shard_pin_nan():
    """The masked union-pool sweep and the per-device chunk sweep inherit
    the pinning: invalid rows AND poisoned rows are +inf, and a chunk
    equals the corresponding slice of the full sweep."""
    from repro.kernels.pool_mlp.ops import (pool_mlp_errors_features,
                                            pool_mlp_errors_features_masked,
                                            pool_mlp_errors_shard)

    ns, R, w, nf = 8, 10, 3, 2
    stacked = dict(_stacked_pool(ns, w))
    stacked["w2"] = stacked["w2"].at[2].set(jnp.nan)
    xd = jax.random.normal(jax.random.PRNGKey(5), (nf, R, w))
    y = jax.random.normal(jax.random.PRNGKey(6), (R,))
    valid = jnp.array([True] * 6 + [False] * 2)
    out = pool_mlp_errors_features_masked(stacked, xd, y, valid)
    assert bool(jnp.all(jnp.isposinf(out[:, 2])))      # poisoned
    assert bool(jnp.all(jnp.isposinf(out[:, 6:])))     # invalid
    full = pool_mlp_errors_features(stacked, xd, y)
    lo, hi = 0, 4
    chunk = jax.tree_util.tree_map(lambda t: t[lo:hi], stacked)
    sh = pool_mlp_errors_shard(chunk, xd, y)
    np.testing.assert_array_equal(np.asarray(sh),
                                  np.asarray(full[:, lo:hi]))


def test_pool_mlp_raw_kernel_rejects_ragged_pool():
    """Padding lives in ops.pool_mlp_errors* only; the raw kernel entry
    point must refuse a pool that is not a block multiple with a real
    error, not an assert."""
    from repro.core.networks import head_schema
    from repro.kernels.pool_mlp.kernel import pool_mlp_pallas
    from repro.sharding import spec as S

    ns, R, w = 5, 10, 3
    pool = [S.materialize(head_schema(w), jax.random.PRNGKey(i))
            for i in range(ns)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pool)
    weights = tuple(stacked[k] for k in ("w0", "b0", "w1", "b1", "w2", "b2",
                                         "w3", "b3", "w4", "b4"))
    xd = jax.random.normal(jax.random.PRNGKey(0), (R, w))
    y = jax.random.normal(jax.random.PRNGKey(1), (R,))
    with pytest.raises(ValueError, match="multiple of block_pool"):
        pool_mlp_pallas(xd, y, weights, block_pool=4)
