"""Sharded-vs-single-device numerical equivalence: the same train step on a
(2, 4) device mesh must produce the same loss as on 1 device — the end-to-end
proof that the sharding rules change WHERE the math runs, not WHAT it
computes.  Runs in a subprocess (jax locks the host device count)."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.lm_pipeline import LMPipelineConfig, TokenPipeline
from repro.launch import steps

ROOT = Path(__file__).resolve().parent.parent

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import smoke_config
from repro.data.lm_pipeline import LMPipelineConfig, TokenPipeline
from repro.launch import steps
from repro.launch.dryrun import named

cfg = smoke_config("{arch}")
opt = steps.default_optimizer(1e-3)
state = steps.init_state(cfg, opt, jax.random.PRNGKey(0))
pipe = TokenPipeline(LMPipelineConfig(batch=8, seq_len=32,
                                      vocab_size=cfg.vocab_size,
                                      n_patches=8), cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with mesh:
    st_specs = named(steps.state_pspecs(cfg, opt, mesh), mesh)
    from repro.configs.base import INPUT_SHAPES, InputShape
    shp = InputShape("t", 32, 8, "train")
    b_specs = named(steps.batch_pspecs(cfg, shp, mesh), mesh)
    ts = jax.jit(steps.make_train_step(cfg, opt, dtype=jnp.float32),
                 in_shardings=(st_specs, b_specs),
                 out_shardings=(st_specs, None))
    losses = []
    for step in range(3):
        batch = {{k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}}
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
print("LOSSES", losses)
"""


@pytest.mark.parametrize(
    "arch",
    ["qwen3-0.6b",
     pytest.param("olmoe-1b-7b", marks=pytest.mark.xfail(
         strict=False,
         reason="TRACKING (pre-existing at PR-4 HEAD): sharded olmoe losses "
                "drift ~0.8% from single-device — MoE top-k capacity "
                "dropping reorders tokens under the (2,4) mesh, so "
                "different tokens are dropped, a routing-semantics gap "
                "(not float noise; needs a deterministic cross-shard drop "
                "order in models/layers/moe.py)"))])
def test_sharded_equals_single_device(arch):
    # single-device reference
    cfg = smoke_config(arch)
    opt = steps.default_optimizer(1e-3)
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0))
    pipe = TokenPipeline(LMPipelineConfig(batch=8, seq_len=32,
                                          vocab_size=cfg.vocab_size,
                                          n_patches=8), cfg)
    ts = jax.jit(steps.make_train_step(cfg, opt, dtype=jnp.float32))
    ref = []
    for step in range(3):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, m = ts(state, batch)
        ref.append(float(m["loss"]))

    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(arch=arch)],
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=560)
    assert "LOSSES" in proc.stdout, proc.stdout + proc.stderr[-2000:]
    got = eval(proc.stdout.split("LOSSES", 1)[1].strip())
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
