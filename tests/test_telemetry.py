"""Flight-recorder telemetry (repro.core.telemetry + tools/trace_export).

Pins the ISSUE-10 acceptance surface: ``telemetry=None``, a fully
disabled plan, and no argument at all trace the byte-identical graph on
the batched, cohort, and (subprocess, forced-4-device) mesh engines —
identical validation histories AND identical selections; an enabled plan
surfaces the per-round in-graph series from a still-single-dispatch
epoch, and those series exactly match the sequential oracle's selection
log at exchange cadences k in {1, 2}; the flight recorder's ring buffer
is bounded; the JSONL -> Chrome-trace/Perfetto export is pinned by
golden files; and a checkpointed recorder restores bit-identically and
keeps its monotonic clock counting upward."""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.core import telemetry as TEL
from repro.core.experiment import tensor_population
from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import HFLConfig
from repro.core.policies import policy_from_spec

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

from trace_export import (assert_spans_nest, chrome_trace,  # noqa: E402
                          load_jsonl, validate_trace)


def _cfg(**kw):
    kw.setdefault("epochs", 3)
    kw.setdefault("R", 10)
    kw.setdefault("mode", "always")
    kw.setdefault("seed", 0)
    return HFLConfig(**kw)


def _pop(cfg, n=6, nf_choices=(3,), seed=0):
    return tensor_population(n, cfg, seed=seed, nf_choices=nf_choices,
                             n_train=20, n_eval=10)


def _fit(cfg, n=6, nf_choices=(3,), engine="batched", exchange_every=1,
         **fed_kw):
    clients = _pop(cfg, n, nf_choices).build(range(n))
    fed = Federation(clients, cfg, engine=engine,
                     schedule=RoundSchedule(cfg.epochs, cfg.R,
                                            exchange_every=exchange_every),
                     **fed_kw)
    hist = fed.fit()
    return fed, hist


# ---------------------------------------------------------------------------
# TelemetryPlan units
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError, match="ring_size"):
        TEL.TelemetryPlan(ring_size=0)
    with pytest.raises(ValueError, match="ring_size"):
        TEL.TelemetryPlan(ring_size=-5)
    assert TEL.TelemetryPlan().enabled
    assert TEL.TelemetryPlan(rounds=False).enabled       # spans still on
    assert not TEL.TelemetryPlan(rounds=False, spans=False).enabled


def test_plan_spec_round_trip():
    plan = TEL.TelemetryPlan(rounds=True, spans=False, ring_size=128,
                             profile=True)
    spec = plan.spec()
    assert policy_from_spec(spec) == plan
    assert policy_from_spec(json.loads(json.dumps(spec))) == plan


def test_federation_rejects_non_plan():
    cfg = _cfg(epochs=1)
    clients = _pop(cfg, 2).build(range(2))
    with pytest.raises(TypeError, match="TelemetryPlan"):
        Federation(clients, cfg, telemetry={"rounds": True})


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_metric_aliases_resolve_with_warning():
    assert TEL.canonical_name("bytes_gathered") == "pool_bytes_gathered"
    assert TEL.canonical_name("rejected_heads") == "heads_rejected"
    assert TEL.canonical_name("heads_rejected") == "heads_rejected"
    with pytest.warns(DeprecationWarning, match="bytes_gathered"):
        out = TEL.resolve_aliases({"bytes_gathered": 7, "devices": 1})
    assert out == {"pool_bytes_gathered": 7, "devices": 1}
    # canonical keys win on collision with their own deprecated alias
    with pytest.warns(DeprecationWarning):
        out = TEL.resolve_aliases({"heads_rejected": 3,
                                   "rejected_heads": 9})
    assert out["heads_rejected"] == 3


def test_metrics_schema_is_json_clean_and_self_describing():
    sch = TEL.schema()
    assert json.loads(json.dumps(sch)) == sch
    for name, m in sch.items():
        assert m["kind"] in TEL.KINDS, name
        assert m["description"], name
    # every deprecated alias points at a catalog entry and is listed back
    for old, new in TEL.DEPRECATED_ALIASES.items():
        assert new in sch
        assert old in sch[new]["aliases"]


def test_validate_stats_rejects_unknown_and_aliased_keys():
    TEL.validate_stats({"heads_rejected": 2, "devices": 1})
    with pytest.raises(ValueError, match="made_up_metric"):
        TEL.validate_stats({"made_up_metric": 1})
    with pytest.raises(ValueError, match="deprecated alias"):
        TEL.validate_stats({"rejected_heads": 2})
    with pytest.raises(ValueError, match="heads_rejected"):
        TEL.validate_stats({"heads_rejected": 2.5})


@pytest.mark.parametrize("engine", ("sequential", "batched"))
def test_engine_dispatch_stats_use_canonical_names(engine):
    """Every engine emits catalog names with registered types — the
    satellite-1 unification pin."""
    fed, _ = _fit(_cfg(epochs=2), engine=engine)
    TEL.validate_stats(fed.dispatch_stats)


def test_cohort_dispatch_stats_use_canonical_names():
    fed, _ = _fit(_cfg(epochs=2), nf_choices=(3, 4))
    assert fed.dispatch_stats["cohorts"] == 2
    TEL.validate_stats(fed.dispatch_stats)


# ---------------------------------------------------------------------------
# Bit-parity: telemetry off == telemetry absent, every engine
# ---------------------------------------------------------------------------

def _histories_equal(h0, h1):
    return all(h0[n]["val"] == h1[n]["val"]
               and h0[n]["selections"] == h1[n]["selections"]
               for n in h0)


@pytest.mark.parametrize("nf_choices", ((3,), (3, 4)),
                         ids=("batched", "cohort"))
def test_disabled_plan_bit_parity(nf_choices):
    """No argument, telemetry=None, and a disabled plan produce identical
    histories AND selections on the single-device batched and cohort
    engines; so does the fully enabled plan (the carry is observation,
    never interference)."""
    cfg = _cfg()
    runs = [
        _fit(cfg, nf_choices=nf_choices)[1],
        _fit(cfg, nf_choices=nf_choices, telemetry=None)[1],
        _fit(cfg, nf_choices=nf_choices,
             telemetry=TEL.TelemetryPlan(rounds=False, spans=False))[1],
        _fit(cfg, nf_choices=nf_choices, telemetry=TEL.TelemetryPlan())[1],
    ]
    for other in runs[1:]:
        assert _histories_equal(runs[0], other)


def test_single_dispatch_with_carry():
    """The metrics carry rides the fused epoch scan: one epoch is still
    ONE dispatch with telemetry fully enabled."""
    fed, _ = _fit(_cfg(), telemetry=TEL.TelemetryPlan())
    assert fed.dispatch_stats["dispatches_per_epoch"] == 1.0
    assert fed.dispatch_stats["path"] == "fused"


# ---------------------------------------------------------------------------
# Per-round series vs the sequential oracle's selection log
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", (1, 2))
def test_round_series_match_sequential_oracle(k):
    """mode="always": every active client federates on every exchange
    round, so the in-graph series must show exactly nf foreign picks per
    client per round event, the decoded round count must equal the
    oracle's per-client selection-log length, and the batched selections
    must equal the oracle's — at cadence k in {1, 2}."""
    cfg = _cfg(epochs=2)
    nf = 3
    fed_b, hist_b = _fit(cfg, exchange_every=k,
                         telemetry=TEL.TelemetryPlan())
    fed_s, hist_s = _fit(cfg, engine="sequential", exchange_every=k)
    for n in hist_b:
        assert hist_b[n]["selections"] == hist_s[n]["selections"]
    rounds = [e for e in fed_b._recorder.events if e["type"] == "round"]
    names = sorted(hist_s)
    n_sel = {n: len(hist_s[n]["selections"]) for n in names}
    assert len(rounds) == n_sel[names[0]]      # equal lengths, mode=always
    for ev in rounds:
        assert ev["foreign_picks"] == nf * len(names)
        assert ev["foreign_per_client"] == [nf] * len(names)
        assert ev["self_keeps"] == 0
        assert ev["score_min"] is not None
        assert ev["score_mean"] is not None
        assert ev["score_min"] <= ev["score_mean"]
    total = sum(nf * c for c in n_sel.values())
    assert fed_b._recorder.counters["foreign_picks"] == total


def test_round_series_sentinels_when_not_federating():
    """mode="no": no selection ever scores, so the series records zero
    foreign picks and null score aggregates — the sentinel path."""
    fed, _ = _fit(_cfg(mode="no", epochs=2),
                  telemetry=TEL.TelemetryPlan())
    rounds = [e for e in fed._recorder.events if e["type"] == "round"]
    assert rounds
    for ev in rounds:
        assert ev["foreign_picks"] == 0
        assert ev["score_min"] is None and ev["score_mean"] is None


# ---------------------------------------------------------------------------
# FlightRecorder mechanics
# ---------------------------------------------------------------------------

def test_ring_buffer_bounded_keeps_newest():
    rec = TEL.FlightRecorder(TEL.TelemetryPlan(ring_size=8))
    for i in range(100):
        rec.mark(f"m{i}")
    assert len(rec.events) == 8
    assert [e["name"] for e in rec.events] == [f"m{i}"
                                               for i in range(92, 100)]


def test_span_nesting_depth_and_counters():
    rec = TEL.FlightRecorder(TEL.TelemetryPlan())
    with rec.span("fit", epochs=1):
        with rec.span("dispatch", epoch=0):
            rec.count("client_rounds", 4)
        rec.count("client_rounds", 2)
    spans = {e["name"]: e for e in rec.events if e["type"] == "span"}
    assert spans["dispatch"]["depth"] == 1 and spans["fit"]["depth"] == 0
    assert spans["fit"]["dur"] >= spans["dispatch"]["dur"]
    assert rec.snapshot() == {"client_rounds": 6}


def test_disabled_spans_record_nothing():
    rec = TEL.FlightRecorder(TEL.TelemetryPlan(spans=False))
    with rec.span("fit"):
        rec.mark("m")
    assert not rec.events
    with TEL.span(None, "anything"):      # module-level no-op form
        pass


def test_recorder_json_round_trip_continues_clock():
    rec = TEL.FlightRecorder(TEL.TelemetryPlan(ring_size=16))
    with rec.span("fit"):
        rec.count("client_rounds", 3)
    data = json.loads(json.dumps(rec.to_json()))
    rec2 = TEL.FlightRecorder.from_json(TEL.TelemetryPlan(ring_size=16),
                                        data)
    assert list(rec2.events) == list(rec.events)
    assert rec2.snapshot() == rec.snapshot()
    last = max(e["ts"] + e.get("dur", 0) for e in rec.events)
    with rec2.span("later"):
        pass
    assert rec2.events[-1]["ts"] >= last  # monotonic past the restored end


# ---------------------------------------------------------------------------
# Export: JSONL + Chrome-trace/Perfetto golden files
# ---------------------------------------------------------------------------

def test_export_golden_files():
    """The golden JSONL event log converts to exactly the golden trace —
    the export format is pinned, not just structurally valid."""
    events = load_jsonl(ROOT / "tests/golden/telemetry_events.jsonl")
    trace = chrome_trace(events, metrics={"foreign_picks": 2,
                                          "client_rounds": 4})
    golden = json.loads(
        (ROOT / "tests/golden/telemetry_trace.json").read_text())
    assert trace == golden
    validate_trace(trace)
    assert_spans_nest(trace["traceEvents"])


def test_live_run_exports_valid_trace(tmp_path):
    fed, _ = _fit(_cfg(epochs=2), telemetry=TEL.TelemetryPlan())
    rec = fed._recorder
    jsonl = tmp_path / "run.jsonl"
    rec.dump_jsonl(jsonl)
    events = load_jsonl(jsonl)
    assert events == list(rec.events)
    trace = chrome_trace(events, metrics=rec.snapshot())
    validate_trace(trace)
    assert_spans_nest(trace["traceEvents"])
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"fit", "dispatch", "exchange"} <= names
    assert any(e["ph"] == "C" for e in trace["traceEvents"])


def test_trace_export_cli(tmp_path):
    src = ROOT / "tests/golden/telemetry_events.jsonl"
    out = tmp_path / "trace.json"
    r = subprocess.run([sys.executable, str(ROOT / "tools/trace_export.py"),
                        "--in", str(src), "--out", str(out), "--validate"],
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr
    validate_trace(json.loads(out.read_text()))


def test_validate_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({})
    with pytest.raises(ValueError, match="missing 'ph'"):
        validate_trace({"traceEvents": [{"name": "x", "ts": 0, "pid": 1,
                                         "tid": 1}]})
    with pytest.raises(ValueError, match="negative ts"):
        validate_trace({"traceEvents": [{"name": "x", "ph": "i", "ts": -1,
                                         "pid": 1, "tid": 1}]})
    with pytest.raises(ValueError, match="dur"):
        validate_trace({"traceEvents": [{"name": "x", "ph": "X", "ts": 0,
                                         "pid": 1, "tid": 1}]})


def test_assert_spans_nest_rejects_partial_overlap():
    ok = [{"name": "a", "ph": "X", "ts": 0, "dur": 100, "pid": 1, "tid": 1},
          {"name": "b", "ph": "X", "ts": 10, "dur": 20, "pid": 1, "tid": 1},
          {"name": "c", "ph": "X", "ts": 50, "dur": 50, "pid": 1, "tid": 1}]
    assert_spans_nest(ok)
    bad = ok + [{"name": "d", "ph": "X", "ts": 90, "dur": 30,
                 "pid": 1, "tid": 1}]
    with pytest.raises(ValueError, match="partially overlaps"):
        assert_spans_nest(bad)


# ---------------------------------------------------------------------------
# Checkpoint: the recorder rides the manifest and continues the trace
# ---------------------------------------------------------------------------

def test_federation_checkpoint_continues_trace():
    cfg = _cfg(epochs=4)
    plan = TEL.TelemetryPlan(ring_size=256)
    clients = _pop(cfg).build(range(6))
    fed = Federation(clients, cfg, schedule=RoundSchedule(4, cfg.R),
                     telemetry=plan)
    fed.fit(epochs=2)
    mid_events = list(fed._recorder.events)
    mid_counts = fed._recorder.snapshot()
    with tempfile.TemporaryDirectory() as d:
        fed.save(d)
        fed2 = Federation.restore(d, _pop(cfg).build(range(6)))
        assert fed2.telemetry == plan
        assert list(fed2._recorder.events) == mid_events
        assert fed2._recorder.snapshot() == mid_counts
        ha = fed.fit(epochs=2)
        hb = fed2.fit(epochs=2)
    assert _histories_equal(ha, hb)
    # the restored recorder CONTINUED: more events, larger counters, and
    # every post-restore timestamp lands after the restored window
    assert len(fed2._recorder.events) > len(mid_events)
    assert fed2._recorder.snapshot()["client_rounds"] \
        > mid_counts["client_rounds"]
    last_mid = max(e["ts"] + e.get("dur", 0) for e in mid_events)
    new = [e for e in fed2._recorder.events if e not in mid_events]
    assert new and all(e["ts"] >= last_mid for e in new)
    assert fed2._recorder.snapshot() == fed._recorder.snapshot()


def test_checkpoint_without_telemetry_restores_none():
    cfg = _cfg(epochs=1)
    fed, _ = _fit(cfg)
    with tempfile.TemporaryDirectory() as d:
        fed.save(d)
        fed2 = Federation.restore(d, _pop(cfg).build(range(6)))
    assert fed2.telemetry is None and fed2._recorder is None


# ---------------------------------------------------------------------------
# VerboseLogger throughput line
# ---------------------------------------------------------------------------

def test_verbose_logger_reports_wall_and_throughput(capsys):
    cfg = _cfg(epochs=2)
    clients = _pop(cfg).build(range(6))
    fed = Federation(clients, cfg, engine="batched",
                     telemetry=TEL.TelemetryPlan())
    fed.fit(verbose=True)
    out = capsys.readouterr().out
    assert "wall:" in out
    assert "client-rounds/s:" in out
    assert "staleness:" in out     # batched + rounds on: age aggregates


def test_verbose_logger_wall_line_without_telemetry(capsys):
    """Satellite 2: the wall/throughput line reports even with no plan —
    only the staleness suffix needs the in-graph series."""
    cfg = _cfg(epochs=1)
    clients = _pop(cfg).build(range(6))
    fed = Federation(clients, cfg, engine="batched")
    fed.fit(verbose=True)
    out = capsys.readouterr().out
    assert "wall:" in out and "client-rounds/s:" in out
    assert "staleness:" not in out


# ---------------------------------------------------------------------------
# Forced-4-device mesh: parity + live series (subprocess, like test_faults)
# ---------------------------------------------------------------------------

_SUBPROCESS = r"""
import json
import jax
assert jax.device_count() == 4, jax.devices()
from repro.core.experiment import tensor_population
from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import HFLConfig
from repro.core.mesh_federation import make_mesh
from repro.core.telemetry import TelemetryPlan

cfg = HFLConfig(epochs=2, R=10, mode="always", seed=3)
mkpop = lambda: tensor_population(8, cfg, seed=1, nf_choices=(3,),
                                  n_train=20, n_eval=10)
res = {}

def full(telemetry):
    fed = Federation(mkpop().build(range(8)), cfg,
                     schedule=RoundSchedule(2, 10), engine="batched",
                     mesh=make_mesh(), telemetry=telemetry)
    return fed, fed.fit()

f0, h0 = full(None)
f1, h1 = full(TelemetryPlan(rounds=False, spans=False))
f2, h2 = full(TelemetryPlan())
res["parity"] = all(
    h0[n]["val"] == h1[n]["val"] == h2[n]["val"]
    and h0[n]["selections"] == h1[n]["selections"] == h2[n]["selections"]
    for n in h0)
res["devices"] = f2.dispatch_stats["devices"]
res["dispatches_per_epoch"] = f2.dispatch_stats["dispatches_per_epoch"]
rounds = [e for e in f2._recorder.events if e["type"] == "round"]
res["n_rounds"] = len(rounds)
res["foreign_ok"] = all(e["foreign_picks"] == 3 * 8 for e in rounds)
res["scores_ok"] = all(e["score_min"] is not None
                       and e["score_min"] <= e["score_mean"]
                       for e in rounds)
res["counter"] = f2._recorder.counters.get("foreign_picks", 0)
print("RESULT " + json.dumps(res))
"""


def _run_forced_devices(script: str, n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    return json.loads(line[-1][len("RESULT "):])


def test_telemetry_on_forced_4_device_mesh():
    """ISSUE 10 acceptance: on a forced 4-device mesh, telemetry=None ==
    disabled plan == enabled plan (val + selections); the enabled plan
    still runs ONE dispatch per epoch and surfaces per-round series whose
    replicated aggregates match the single-device semantics."""
    res = _run_forced_devices(_SUBPROCESS, 4)
    assert res["parity"]
    assert res["devices"] == 4
    assert res["dispatches_per_epoch"] == 1.0
    assert res["n_rounds"] == 2 * 2      # 2 epochs x 2 exchange rounds
    assert res["foreign_ok"] and res["scores_ok"]
    assert res["counter"] == 4 * 3 * 8
