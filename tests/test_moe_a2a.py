"""All-to-all expert-parallel MoE vs the gather-dispatch oracle."""
import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.layers.moe import moe_apply, moe_schema
from repro.models.layers.moe_a2a import ep_axes_for, moe_apply_a2a
from repro.sharding import spec as S

ROOT = Path(__file__).resolve().parent.parent


def _dropless(moe):
    return dataclasses.replace(moe, capacity_factor=float(moe.n_experts))


def test_a2a_matches_gather_single_device():
    cfg = smoke_config("olmoe-1b-7b")
    mcfg = _dropless(cfg.moe)
    params = S.materialize(moe_schema(cfg.d_model, mcfg, cfg.act),
                           jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep = ep_axes_for(mcfg, mesh)
    out_g, aux_g = moe_apply(params, x, mcfg, cfg.act)
    with mesh:
        out_a, aux_a = moe_apply_a2a(params, x, mcfg, cfg.act, mesh, ep)
    np.testing.assert_allclose(out_a, out_g, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_a), float(aux_g), rtol=1e-6)


def test_ep_axes_selection():
    cfg = smoke_config("olmoe-1b-7b")          # 4 experts
    mesh11 = jax.make_mesh((1, 1), ("data", "model"))
    assert ep_axes_for(cfg.moe, mesh11) == ("data", "model")
    m3 = dataclasses.replace(cfg.moe, n_experts=3)
    assert ep_axes_for(m3, mesh11) == ("data", "model")  # 3 % 1 == 0


_MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, numpy as np
import jax.numpy as jnp
from repro.configs import smoke_config
from repro.models.layers.moe import moe_apply, moe_schema
from repro.models.layers.moe_a2a import ep_axes_for, moe_apply_a2a
from repro.sharding import spec as S
from jax.sharding import PartitionSpec as P, NamedSharding

cfg = smoke_config("olmoe-1b-7b")
mcfg = dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
params = S.materialize(moe_schema(cfg.d_model, mcfg, cfg.act), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
mesh = jax.make_mesh((2, 2), ("data", "model"))
ep = ep_axes_for(mcfg, mesh)
assert ep == ("data", "model"), ep
out_g, aux_g = moe_apply(params, x, mcfg, cfg.act)
with mesh:
    ps = NamedSharding(mesh, P("data", None, None))
    xs = jax.device_put(x, ps)
    f = jax.jit(lambda p, xx: moe_apply_a2a(p, xx, mcfg, cfg.act, mesh, ep))
    out_a, aux_a = f(params, xs)
np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_g), rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(float(aux_a), float(aux_g), rtol=1e-5)
print("MULTIDEV_OK")
"""


def test_a2a_matches_gather_multidevice():
    """Real 2x2 device mesh (subprocess: jax locks the device count).

    JAX_PLATFORMS=cpu is load-bearing: the hand-built env must pin the CPU
    backend, or on hosts with an accelerator runtime installed (e.g. a
    baked-in libtpu) the bare subprocess hangs for minutes trying to
    initialize it and the forced host-device-count flag never applies."""
    proc = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=560)
    assert "MULTIDEV_OK" in proc.stdout, proc.stdout + proc.stderr


def test_a2a_grad_finite():
    cfg = smoke_config("deepseek-v3-671b")
    mcfg = _dropless(cfg.moe)
    params = S.materialize(moe_schema(cfg.d_model, mcfg, cfg.act),
                           jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep = ep_axes_for(mcfg, mesh)

    def loss(p, xx):
        with mesh:
            out, aux = moe_apply_a2a(p, xx, mcfg, cfg.act, mesh, ep)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params, x)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
