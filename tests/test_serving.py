"""Serving engine tests: batched generation over KV/recurrent caches."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import model as M
from repro.serving.engine import GenerationConfig, ServingEngine
from repro.sharding import spec as S


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "recurrentgemma-2b",
                                  "musicgen-medium"])
def test_generate_shapes(arch):
    cfg = smoke_config(arch)
    params = S.materialize(M.model_schema(cfg), jax.random.PRNGKey(0))
    B, P, G = 2, 8, 6
    eng = ServingEngine(cfg, params, cache_len=P + G)
    if cfg.n_codebooks > 1:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (B, cfg.n_codebooks, P), 0,
                                     cfg.vocab_size)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                     cfg.vocab_size)
    out = eng.generate(prompts, GenerationConfig(max_new_tokens=G, seed=3))
    assert out.shape[-1] == G
    assert out.shape[0] == B
    assert int(out.max()) < cfg.vocab_size and int(out.min()) >= 0


def test_greedy_temperature_determinism():
    cfg = smoke_config("granite-3-2b")
    params = S.materialize(M.model_schema(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, cache_len=12)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                 cfg.vocab_size)
    g = GenerationConfig(max_new_tokens=8, temperature=1e-4, seed=0)
    a = eng.generate(prompts, g)
    b = eng.generate(prompts, g)
    assert (jnp.asarray(a) == jnp.asarray(b)).all()
