"""HFL-for-transformers tests: shared-subtree masking, blend step semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.hfl_llm import (default_shared_predicate, make_blend_step,
                                shared_fraction, shared_mask)
from repro.models import model as M
from repro.sharding import spec as S


def test_shared_excludes_experts_and_recurrence():
    assert not default_shared_predicate(("seg0", "l0", "moe", "wg"))
    assert not default_shared_predicate(("seg0", "l0", "rglru", "w_in"))
    assert not default_shared_predicate(("vis_proj",))
    assert default_shared_predicate(("seg0", "l0", "attn", "wq"))
    assert default_shared_predicate(("embed",))
    assert default_shared_predicate(("seg0", "l0", "mlstm", "wu"))
    assert not default_shared_predicate(("seg0", "l0", "mlstm", "wi"))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "olmoe-1b-7b", "xlstm-350m"])
def test_partial_sharing_fraction(arch):
    """Security property: strictly part of the network is shared."""
    f = shared_fraction(smoke_config(arch))
    assert 0.0 < f < 1.0


def test_blend_step_moves_only_shared_leaves():
    cfg = smoke_config("qwen3-0.6b")
    schema = M.model_schema(cfg)
    p0 = S.materialize(schema, jax.random.PRNGKey(0))
    p1 = S.materialize(schema, jax.random.PRNGKey(1))
    stacked = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), p0, p1)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 2, 16), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    blend = make_blend_step(cfg, alpha=0.2, dtype=jnp.float32)
    new_params, losses = jax.jit(blend)(stacked, batch)
    assert losses.shape == (2, 2)
    mask = shared_mask(cfg)
    flat_mask = jax.tree_util.tree_leaves(mask)
    for m, old, new in zip(flat_mask, jax.tree_util.tree_leaves(stacked),
                           jax.tree_util.tree_leaves(new_params)):
        if not m:
            np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_blend_selects_lower_loss_candidate():
    """If candidate j has much lower loss for client c, blending must pull
    client c's shared params toward candidate j (Eq. 7 -> Eq. 8)."""
    cfg = smoke_config("qwen3-0.6b")
    schema = M.model_schema(cfg)
    p0 = S.materialize(schema, jax.random.PRNGKey(0))
    # candidate 1 = candidate 0 scaled: identical clients -> diagonal argmin
    stacked = jax.tree_util.tree_map(lambda a: jnp.stack([a, a * 1.5]), p0)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 2, 16), 0,
                                cfg.vocab_size)
    blend = make_blend_step(cfg, alpha=0.5, dtype=jnp.float32)
    new_params, losses = jax.jit(blend)(stacked, {"tokens": tokens})
    assert bool(jnp.all(jnp.isfinite(losses)))
