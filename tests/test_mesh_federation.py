"""Client-sharded federation parity: the fused epoch under a `clients` mesh
must be selection- and value-identical to the single-device engine, on every
device count.  In-process tests build a mesh over whatever devices the host
exposes (1 in plain tier-1, 4 under the CI mesh-parity step's
``XLA_FLAGS=--xla_force_host_platform_device_count=4``); the subprocess
acceptance test ALWAYS exercises a genuine 4-device mesh with a 32-client
population, including a bit-exact save/restore round-trip, regardless of the
parent's device count (jax locks the host device count at first init)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import mesh_federation as MF
from repro.core.federation import Callback, Federation
from repro.core.hfl import FederatedClient, HFLConfig

ROOT = Path(__file__).resolve().parent.parent


def _mk_clients(cfg, C=8, nf=2, n=40, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(40), mk(40),
                                   jax.random.PRNGKey(i)))
    return out


class _RoundCounter(Callback):
    def __init__(self):
        self.rounds = []

    def on_round(self, fed, epoch, rnd):
        self.rounds.append((epoch, rnd))


def _assert_identical(h_a, h_b, *, exact_val=True):
    assert set(h_a) == set(h_b)
    for name in h_a:
        assert h_a[name]["selections"] == h_b[name]["selections"]
        assert h_a[name]["rounds"] == h_b[name]["rounds"]
        if exact_val:
            np.testing.assert_array_equal(h_a[name]["val"], h_b[name]["val"])
        else:
            np.testing.assert_allclose(h_a[name]["val"], h_b[name]["val"],
                                       rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Mesh construction + validation
# ---------------------------------------------------------------------------

def test_make_mesh_defaults_to_local_devices():
    mesh = MF.make_mesh()
    assert mesh.axis_names == ("clients",)
    assert MF.mesh_devices(mesh) == len(jax.devices())


def test_make_mesh_rejects_multi_axis():
    with pytest.raises(ValueError, match="1-D mesh"):
        MF.make_mesh(("clients", "model"))


def test_mesh_requires_batched_engine():
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    with pytest.raises(ValueError, match="engine='batched'"):
        Federation(_mk_clients(cfg, C=2), cfg, engine="sequential",
                   mesh=MF.make_mesh())


def test_mesh_rejects_non_divisible_population():
    if len(jax.devices()) < 2:
        pytest.skip("divisibility only binds on a multi-device mesh")
    cfg = HFLConfig(mode="always", epochs=1, R=20)
    C = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="shard evenly"):
        Federation(_mk_clients(cfg, C=C), cfg, engine="batched",
                   mesh=MF.make_mesh())


# ---------------------------------------------------------------------------
# In-process parity over the local device count (1 in tier-1, 4 in the CI
# mesh step — same assertions either way)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("always", "hfl"))
def test_mesh_matches_no_mesh(mode):
    """mesh= must not change a single number: identical selections, round
    counts, and bit-identical validation histories vs the plain batched
    engine, whatever the local device count."""
    cfg = HFLConfig(mode=mode, epochs=4, R=20, patience=2)
    h_plain = Federation(_mk_clients(cfg), cfg, engine="batched").fit()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                     mesh=MF.make_mesh())
    h_mesh = fed.fit()
    assert fed.dispatch_stats["path"] == "fused"
    assert fed.dispatch_stats["devices"] == \
        (len(jax.devices()) if len(jax.devices()) > 1 else 1)
    assert fed.dispatch_stats["dispatches_per_epoch"] == 1.0
    _assert_identical(h_plain, h_mesh)


def test_single_device_mesh_falls_back():
    """A one-device mesh takes the plain single-device path (no shard_map),
    and is — trivially — selection-identical to running without a mesh."""
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    mesh1 = MF.make_mesh(devices=jax.devices()[:1])
    fed = Federation(_mk_clients(cfg, C=3), cfg, engine="batched",
                     mesh=mesh1)
    assert fed._exec_mesh() is None
    h_mesh = fed.fit()
    assert fed.dispatch_stats["devices"] == 1
    h_plain = Federation(_mk_clients(cfg, C=3), cfg, engine="batched").fit()
    _assert_identical(h_plain, h_mesh)


def test_mesh_chunked_path_parity():
    """Per-round callbacks force the chunked path under a mesh too — same
    compiled sharded body per sub-round, every on_round fired, identical
    results."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    h_plain = Federation(_mk_clients(cfg), cfg, engine="batched").fit()
    counter = _RoundCounter()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                     mesh=MF.make_mesh(), callbacks=[counter])
    h_mesh = fed.fit()
    assert fed.dispatch_stats["path"] == "chunked"
    assert counter.rounds == [(e, r) for e in range(3) for r in range(2)]
    _assert_identical(h_plain, h_mesh)


def test_mesh_save_restore_bit_identical(tmp_path):
    cfg = HFLConfig(mode="hfl", epochs=6, R=20, patience=2)
    mesh = MF.make_mesh()
    h_straight = Federation(_mk_clients(cfg), cfg, engine="batched",
                            mesh=mesh).fit()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched", mesh=mesh)
    fed.fit(epochs=3)
    fed.save(tmp_path / "ck")
    manifest = json.loads((tmp_path / "ck" / "manifest.json").read_text())
    assert manifest["mesh_devices"] == MF.mesh_devices(mesh)
    # checkpoints are mesh-agnostic: resume sharded AND unsharded
    h_mesh = Federation.restore(tmp_path / "ck", _mk_clients(cfg),
                                mesh=mesh).fit()
    h_plain = Federation.restore(tmp_path / "ck", _mk_clients(cfg)).fit()
    for h_resumed in (h_mesh, h_plain):
        for name in h_straight:
            assert h_straight[name]["val"] == h_resumed[name]["val"]
            assert h_straight[name]["selections"] == \
                h_resumed[name]["selections"]
            assert h_straight[name]["best_val"] == h_resumed[name]["best_val"]


def test_schema_derived_pspecs_partition_client_axis():
    """The ParamSpec schema -> FED_RULES -> PartitionSpec pipeline puts the
    `clients` mesh axis on the leading (stacked-client) dimension of every
    parameter leaf and nothing else — the schema layer is what decides the
    federation sharding."""
    from jax.sharding import PartitionSpec as P
    mesh = MF.make_mesh()
    specs = MF.param_pspecs(nf=3, w=4, n_clients=len(jax.devices()) * 2,
                            mesh=mesh)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert leaves, "schema produced no PartitionSpecs"
    for ps in leaves:
        assert isinstance(ps, P)
        assert tuple(ps) in ((MF.CLIENT_AXIS,), ()), ps


# ---------------------------------------------------------------------------
# Acceptance pin: 32 clients on a forced 4-device mesh (subprocess — jax
# locks the host platform device count at first init)
# ---------------------------------------------------------------------------

_SUBPROCESS = r"""
import json, os, sys, tempfile
import numpy as np
import jax
assert jax.device_count() == 4, jax.devices()
from repro.core import mesh_federation as MF
from repro.core.federation import Federation
from repro.core.hfl import FederatedClient, HFLConfig

def mk_clients(cfg, C=32, nf=2, n=40, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"h{i:03d}", nf, cfg, mk(n), mk(40),
                                   mk(40), jax.random.PRNGKey(i)))
    return out

cfg = HFLConfig(mode="always", epochs=3, R=20)
mesh = MF.make_mesh()

h_oracle = Federation(mk_clients(cfg), cfg, engine="batched").fit()
fed = Federation(mk_clients(cfg), cfg, engine="batched", mesh=mesh)
h_mesh = fed.fit()
expect = {
    "engine": "batched", "path": "fused", "devices": 4, "cohorts": 1,
    "epochs": 3, "dispatches": 3, "dispatches_per_epoch": 1.0,
    "exchange_every": 1,
}
assert {k: fed.dispatch_stats[k] for k in expect} == expect, \
    fed.dispatch_stats
assert fed.dispatch_stats["pool_bytes_gathered"] > 0, fed.dispatch_stats
sel_identical = all(h_oracle[n]["selections"] == h_mesh[n]["selections"]
                    for n in h_oracle)
val_identical = all(h_oracle[n]["val"] == h_mesh[n]["val"]
                    for n in h_oracle)

with tempfile.TemporaryDirectory() as d:
    ck = os.path.join(d, "ck")
    h_straight = Federation(mk_clients(cfg), cfg, engine="batched",
                            mesh=mesh).fit()
    fed2 = Federation(mk_clients(cfg), cfg, engine="batched", mesh=mesh)
    fed2.fit(epochs=1)
    fed2.save(ck)
    h_resumed = Federation.restore(ck, mk_clients(cfg), mesh=mesh).fit()
    ck_identical = all(
        h_straight[n]["val"] == h_resumed[n]["val"]
        and h_straight[n]["selections"] == h_resumed[n]["selections"]
        and h_straight[n]["best_val"] == h_resumed[n]["best_val"]
        for n in h_straight)

print("RESULT " + json.dumps({"sel_identical": sel_identical,
                              "val_identical": val_identical,
                              "ck_identical": ck_identical}))
"""


def _run_forced_devices(script: str, n_devices: int) -> dict:
    """Run ``script`` in a subprocess with a forced n-device CPU host (jax
    locks the host platform device count at first init) and return its
    RESULT json."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout
    return json.loads(line[-1][len("RESULT "):])


def test_32_clients_on_forced_4_device_mesh():
    """ISSUE 4 acceptance: with XLA_FLAGS=--xla_force_host_platform_device_
    count=4, a 32-client population runs the fused epoch on a 4-device
    `clients` mesh with selections identical to the single-device oracle,
    and Federation.save/restore round-trips the sharded state bit-exactly."""
    res = _run_forced_devices(_SUBPROCESS, 4)
    assert res == {"sel_identical": True, "val_identical": True,
                   "ck_identical": True}


# ---------------------------------------------------------------------------
# Acceptance pin: bounded-staleness cadence on a forced 8-device mesh —
# comms counters shrink with exchange_every, and a checkpoint written from
# the 8-device mesh restores bit-identically onto one device
# ---------------------------------------------------------------------------

_SUBPROCESS_8 = r"""
import json, os, sys, tempfile
import numpy as np
import jax
assert jax.device_count() == 8, jax.devices()
from repro.core import mesh_federation as MF
from repro.core.federation import Federation, RoundSchedule
from repro.core.hfl import FederatedClient, HFLConfig

def mk_clients(cfg, C=16, nf=2, n=60, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"h{i:03d}", nf, cfg, mk(n), mk(40),
                                   mk(40), jax.random.PRNGKey(i)))
    return out

cfg = HFLConfig(mode="always", epochs=2, R=20)   # n=60 -> 3 sub-rounds
mesh = MF.make_mesh()
res, stats = {}, {}
for k in (1, 2):
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=k)
    fed = Federation(mk_clients(cfg), cfg, engine="batched",
                     schedule=sched, mesh=mesh)
    h_mesh = fed.fit()
    stats[k] = fed.dispatch_stats
    h_or = Federation(mk_clients(cfg), cfg, engine="batched",
                      schedule=sched).fit()
    res[f"sel_identical_k{k}"] = all(
        h_or[n]["selections"] == h_mesh[n]["selections"] for n in h_or)
    res[f"rounds_identical_k{k}"] = all(
        h_or[n]["rounds"] == h_mesh[n]["rounds"] for n in h_or)
    res[f"val_close_k{k}"] = all(
        np.allclose(h_or[n]["val"], h_mesh[n]["val"], rtol=1e-6, atol=1e-7)
        for n in h_or)
res["devices_8"] = stats[1]["devices"] == 8
# comms counters: k=2 exchanges 1 of 3 sub-rounds per epoch (vs 3) and
# gathers proportionally fewer bytes
res["exchange_rounds"] = [stats[1]["exchange_rounds"],
                          stats[2]["exchange_rounds"]]
res["counters_shrink"] = (
    stats[2]["exchange_rounds"] < stats[1]["exchange_rounds"]
    and 0 < stats[2]["pool_bytes_gathered"] < stats[1]["pool_bytes_gathered"]
    and stats[1]["exchange_rounds"] == cfg.epochs * 3
    and stats[2]["exchange_rounds"] == cfg.epochs * 1)

# 8-device save -> 1-device (no-mesh) restore, bit-identical continuation
sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=2)
with tempfile.TemporaryDirectory() as d:
    ck = os.path.join(d, "ck")
    h_straight = Federation(mk_clients(cfg), cfg, engine="batched",
                            schedule=sched, mesh=mesh).fit()
    fed2 = Federation(mk_clients(cfg), cfg, engine="batched",
                      schedule=sched, mesh=mesh)
    fed2.fit(epochs=1)
    fed2.save(ck)
    manifest = json.load(open(os.path.join(ck, "manifest.json")))
    restored = Federation.restore(ck, mk_clients(cfg))   # no mesh: 1 device
    h_resumed = restored.fit()
    res["manifest_cadence"] = (
        manifest["schedule"]["exchange_every"] == 2
        and manifest["mesh_devices"] == 8
        and restored.schedule.exchange_every == 2)
    res["ck_identical"] = all(
        h_straight[n]["val"] == h_resumed[n]["val"]
        and h_straight[n]["selections"] == h_resumed[n]["selections"]
        and h_straight[n]["best_val"] == h_resumed[n]["best_val"]
        for n in h_straight)

print("RESULT " + json.dumps(res))
"""


def test_cadence_comms_and_restore_on_forced_8_device_mesh():
    """ISSUE 6 acceptance: on a forced 8-virtual-device mesh, dispatch_stats
    comms counters shrink as exchange_every grows (fewer exchange rounds,
    fewer pool bytes gathered), selections stay identical to the 1-device
    oracle at every cadence, and a checkpoint saved from the 8-device mesh
    restores bit-identically onto a single device."""
    res = _run_forced_devices(_SUBPROCESS_8, 8)
    assert res["devices_8"], res
    assert res["counters_shrink"], res
    assert res["manifest_cadence"], res
    assert res["ck_identical"], res
    for k in (1, 2):
        assert res[f"sel_identical_k{k}"], res
        assert res[f"rounds_identical_k{k}"], res
        assert res[f"val_close_k{k}"], res
