"""Unit tests for the HFL mechanism: Eq. 7 selection, Eq. 8 blend, switch,
pool asynchrony (paper §4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import networks as N
from repro.core.hfl import (FederatedClient, HeadPool, HFLConfig, blend,
                            federated_round, pool_errors)
from repro.sharding import spec as S


def _head(seed, w=3):
    return S.materialize(N.head_schema(w), jax.random.PRNGKey(seed))


def _stack(heads):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *heads)


def test_selection_picks_min_error_head():
    w, R = 3, 50
    heads = [_head(i) for i in range(5)]
    xd = jax.random.normal(jax.random.PRNGKey(9), (R, w))
    # construct y to exactly match head 3's predictions
    y = N.head_apply(heads[3], xd)
    errs = pool_errors(_stack(heads), xd, y)
    assert int(jnp.argmin(errs)) == 3
    assert float(errs[3]) < 1e-10


def test_blend_is_convex_combination():
    a, b = _head(0), _head(1)
    out = blend(_stack([a]), _stack([b]), alpha=0.25)
    for pa, pb, po in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b),
                          jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(po[0], 0.25 * pb + 0.75 * pa, rtol=1e-6)


def test_blend_alpha_zero_is_identity():
    a, b = _head(0), _head(1)
    out = blend(_stack([a]), _stack([b]), alpha=0.0)
    for pa, po in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(po[0], pa)


def test_pool_keeps_stale_versions():
    pool = HeadPool()
    h0 = _stack([_head(0), _head(1)])
    pool.publish("alice", h0, nf=2)
    h1 = _stack([_head(2), _head(3)])
    pool.publish("bob", h1, nf=2)
    stacked, keys = pool.stacked_for("carol")
    assert len(keys) == 4
    # bob goes silent; alice republishes - bob's stale entries must remain
    pool.publish("alice", _stack([_head(5), _head(6)]), nf=2)
    stacked2, keys2 = pool.stacked_for("carol")
    assert len(keys2) == 4
    assert ("bob", 0) in keys2 and ("bob", 1) in keys2


def _mk_client(mode="hfl", seed=0, n=120):
    rng = np.random.default_rng(seed)
    cfg = HFLConfig(mode=mode, epochs=1, R=20)
    mk = lambda m: (rng.normal(size=(m, 2, 3)).astype(np.float32),
                    rng.normal(size=(m, 2, 3)).astype(np.float32),
                    rng.normal(size=m).astype(np.float32))
    return FederatedClient("c", 2, cfg, mk(n), mk(30), mk(30),
                           jax.random.PRNGKey(seed))


def test_switch_requires_plateau():
    c = _mk_client("hfl")
    c.val_history = [5.0, 4.0, 3.0]        # still improving
    assert not c.fl_active()
    c.val_history = [5.0, 3.0, 3.5, 3.4, 3.6]  # 3 epochs >= best-before
    assert c.fl_active()
    c.val_history = [5.0, 3.0, 3.5, 2.9, 3.6]  # improved 2 epochs ago
    assert not c.fl_active()


def test_switch_zero_patience_is_eligible_after_first_epoch():
    import dataclasses
    c = _mk_client("hfl")
    c.cfg = dataclasses.replace(c.cfg, patience=0)
    assert not c.fl_active()            # no validation history yet
    c.val_history = [5.0]
    assert c.fl_active()


def test_mode_gates():
    c = _mk_client("no")
    c.val_history = [5, 5, 5, 5, 5]
    assert not c.fl_active()
    c = _mk_client("always")
    assert c.fl_active()
    c = _mk_client("random")
    assert c.fl_active()


def test_federated_round_blends_toward_selected():
    c = _mk_client("always")
    pool = HeadPool()
    other = _stack([_head(7), _head(8)])
    pool.publish("other", other, nf=2)
    xs, xd, y = c.train
    c._recent = (xd[:20], y[:20])
    before = jax.tree_util.tree_map(lambda x: x.copy(), c.params["heads"])
    chosen = federated_round(c, pool, np.random.default_rng(0))
    assert chosen is not None and len(chosen) == 2
    # heads must have moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(c.params["heads"])))
    assert moved


def test_random_mode_ignores_errors():
    c = _mk_client("random")
    pool = HeadPool()
    pool.publish("other", _stack([_head(7), _head(8), _head(9)]), nf=3)
    xs, xd, y = c.train
    c._recent = (xd[:20], y[:20])
    rng = np.random.default_rng(123)
    seen = set()
    for _ in range(10):
        seen.update(federated_round(c, pool, rng))
    assert len(seen) > 1  # random selection explores


def test_pool_kernel_matches_vmap_scoring():
    heads = _stack([_head(i) for i in range(6)])
    xd = jax.random.normal(jax.random.PRNGKey(1), (50, 3))
    y = jax.random.normal(jax.random.PRNGKey(2), (50,))
    from repro.kernels.pool_mlp.ops import pool_mlp_errors
    np.testing.assert_allclose(pool_mlp_errors(heads, xd, y, block_pool=4),
                               pool_errors(heads, xd, y), rtol=1e-5, atol=1e-6)
