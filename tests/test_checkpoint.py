"""Checkpoint round-trip + save-best policy tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager, load, save


def _tree():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "b": jnp.zeros(3, jnp.bfloat16)},
        "opt": ({}, {"step": jnp.int32(7), "m": [jnp.ones(2)]}),
        "meta": {"name": "x", "lr": 0.01, "flag": True, "none": None},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path / "ck.msgpack", t)
    r = load(tmp_path / "ck.msgpack")
    assert r["meta"] == {"name": "x", "lr": 0.01, "flag": True, "none": None}
    np.testing.assert_array_equal(r["params"]["w"],
                                  np.asarray(t["params"]["w"]))
    assert r["params"]["b"].dtype == np.dtype("bfloat16") or \
        str(r["params"]["b"].dtype) == "bfloat16"
    assert isinstance(r["opt"], tuple)
    assert r["opt"][1]["step"] == 7


def test_manager_keep_and_best(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save_step(s, {"v": jnp.float32(s)})
    ckpts = sorted((tmp_path).glob("step_*.msgpack"))
    assert len(ckpts) == 2
    latest = mgr.latest()
    assert float(latest["v"]) == 4.0

    assert mgr.save_best(3.0, {"v": jnp.float32(1)})
    assert not mgr.save_best(4.0, {"v": jnp.float32(2)})   # worse: rejected
    assert mgr.save_best(2.0, {"v": jnp.float32(3)})
    assert float(mgr.best()["v"]) == 3.0


# ---------------------------------------------------------------------------
# Trust-state round-trips: the DP accountant and the reputation book must
# replay BIT-identically through a mid-fit save -> restore (tests/test_trust
# pins the layer's semantics; these pin its persistence)
# ---------------------------------------------------------------------------

def _trust_pop(cfg, n=8):
    from repro.core.experiment import tensor_population
    return tensor_population(n, cfg, seed=0, nf_choices=(3,),
                             n_train=20, n_eval=10)


def test_dp_accountant_replays_through_mid_fit_restore(tmp_path):
    """Epsilon is recomputed analytically from integer release counts, so
    a restored accountant must carry EXACTLY the saved counts and a
    continued fit must spend epsilon exactly as the uninterrupted run."""
    from repro.core import trust as TR
    from repro.core.hfl import HFLConfig
    from repro.core.participation import (ParticipatingFederation,
                                          UniformParticipation)
    cfg = HFLConfig(epochs=2, R=10, mode="always", seed=0)
    trust = TR.TrustPlan(dp=TR.DPNoise(clip=10.0, sigma=0.8, seed=3))
    mk = lambda: ParticipatingFederation(
        _trust_pop(cfg), cfg,
        participation=UniformParticipation(fraction=0.5, min_clients=2),
        engine="batched", trust=trust)
    pf = mk()
    pf.fit(waves=2)
    assert pf.accountant.counts and pf.accountant.max_epsilon > 0
    pf.save(tmp_path)
    rf = ParticipatingFederation.restore(tmp_path, _trust_pop(cfg))
    assert rf.accountant.to_json() == pf.accountant.to_json()
    assert rf.accountant.max_epsilon == pf.accountant.max_epsilon
    assert rf.clip_events == pf.clip_events

    ha, hb = pf.fit(waves=2), rf.fit(waves=2)
    assert pf.accountant.to_json() == rf.accountant.to_json()
    assert pf.dispatch_stats["epsilon_spent"] == \
        rf.dispatch_stats["epsilon_spent"]
    assert pf.dispatch_stats["clip_events"] == \
        rf.dispatch_stats["clip_events"]
    for n in ha:
        assert ha[n]["val"] == hb[n]["val"]
        assert ha[n]["selections"] == hb[n]["selections"]


def test_reputation_book_replays_through_mid_fit_restore(tmp_path):
    """Mid-quarantine restore: strikes and the quarantine set survive the
    manifest round-trip and the continued run keeps quarantined clients
    out of sampling exactly as the uninterrupted run does."""
    from repro.core import faults as FT
    from repro.core import trust as TR
    from repro.core.hfl import HFLConfig
    from repro.core.participation import (ParticipatingFederation,
                                          UniformParticipation)
    cfg = HFLConfig(epochs=2, R=10, mode="always", seed=0)
    kw = dict(
        participation=UniformParticipation(fraction=0.5, min_clients=2),
        engine="batched",
        faults=FT.FaultPlan(byzantine=0.3, corruption="signflip", seed=7),
        trust=TR.TrustPlan(watermark=TR.HeadWatermark()))
    pf = ParticipatingFederation(_trust_pop(cfg), cfg, **kw)
    pf.fit(waves=4)
    assert sum(pf.reputation.strikes.values()) > 0   # mid-quarantine state
    pf.save(tmp_path)
    rf = ParticipatingFederation.restore(tmp_path, _trust_pop(cfg))
    assert rf.reputation.to_json() == pf.reputation.to_json()
    assert rf.wm_failures == pf.wm_failures

    ha, hb = pf.fit(waves=4), rf.fit(waves=4)
    assert pf.reputation.to_json() == rf.reputation.to_json()
    assert pf.dispatch_stats["quarantined"] \
        == rf.dispatch_stats["quarantined"] != []
    assert [w["active"] for w in pf.wave_log] \
        == [w["active"] for w in rf.wave_log]
    for n in ha:
        assert ha[n]["val"] == hb[n]["val"]


def test_federation_trust_counters_round_trip(tmp_path):
    """Federation.save carries the integer trust counters (_dp_counts /
    _wm_failures) so a restored federation's dispatch_stats epsilon
    resumes from the saved spend instead of resetting to zero."""
    from repro.core import trust as TR
    from repro.core.experiment import tensor_population
    from repro.core.federation import Federation
    from repro.core.hfl import HFLConfig
    cfg = HFLConfig(epochs=2, R=10, mode="always", seed=0)
    mk = lambda: tensor_population(4, cfg, seed=0, nf_choices=(3,),
                                   n_train=20, n_eval=10).build(range(4))
    trust = TR.TrustPlan(dp=TR.DPNoise(clip=10.0, sigma=0.8))
    fed = Federation(mk(), cfg, engine="batched", trust=trust)
    fed.fit()
    eps = fed.dispatch_stats["epsilon_spent"]
    assert eps > 0
    fed.save(tmp_path)
    rf = Federation.restore(tmp_path, mk())
    assert rf._dp_counts == fed._dp_counts
    assert rf._wm_failures == fed._wm_failures
    # dispatch_stats only materializes after a fit; the analytic spend is
    # already recomputable from the restored counters
    assert rf._trust_stats()["epsilon_spent"] == eps
