"""Checkpoint round-trip + save-best policy tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager, load, save


def _tree():
    return {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "b": jnp.zeros(3, jnp.bfloat16)},
        "opt": ({}, {"step": jnp.int32(7), "m": [jnp.ones(2)]}),
        "meta": {"name": "x", "lr": 0.01, "flag": True, "none": None},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path / "ck.msgpack", t)
    r = load(tmp_path / "ck.msgpack")
    assert r["meta"] == {"name": "x", "lr": 0.01, "flag": True, "none": None}
    np.testing.assert_array_equal(r["params"]["w"],
                                  np.asarray(t["params"]["w"]))
    assert r["params"]["b"].dtype == np.dtype("bfloat16") or \
        str(r["params"]["b"].dtype) == "bfloat16"
    assert isinstance(r["opt"], tuple)
    assert r["opt"][1]["step"] == 7


def test_manager_keep_and_best(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(5):
        mgr.save_step(s, {"v": jnp.float32(s)})
    ckpts = sorted((tmp_path).glob("step_*.msgpack"))
    assert len(ckpts) == 2
    latest = mgr.latest()
    assert float(latest["v"]) == 4.0

    assert mgr.save_best(3.0, {"v": jnp.float32(1)})
    assert not mgr.save_best(4.0, {"v": jnp.float32(2)})   # worse: rejected
    assert mgr.save_best(2.0, {"v": jnp.float32(3)})
    assert float(mgr.best()["v"]) == 3.0
