"""Synthetic clinical generator + LM pipeline tests."""
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import synthetic as syn
from repro.data.lm_pipeline import LMPipelineConfig, TokenPipeline


def test_one_value_per_tick():
    s = syn.make_patient(np.random.default_rng(0), "carevue", 200)
    assert s.channels.shape == (200,)
    assert s.nf == 4
    assert (s.channels <= s.nf).all()
    assert np.all(np.diff(s.times) > 0)        # irregular but increasing


def test_hospitals_are_heterogeneous():
    a = syn.HOSPITALS["carevue"]["features"]
    b = syn.HOSPITALS["metavision"]["features"]
    assert {f[0] for f in a} != {f[0] for f in b}   # different feature spaces
    assert syn.HOSPITALS["metavision"]["n_patients"] < \
        syn.HOSPITALS["carevue"]["n_patients"]      # smaller target domain


def test_splits_disjoint():
    d = syn.make_hospital("metavision", n_patients=20, n_events=50)
    tr, va, te = (set(d.splits[k]) for k in ("train", "valid", "test"))
    assert not (tr & va) and not (tr & te) and not (va & te)
    assert len(tr | va | te) == 20


def test_relabel_roundtrip_counts():
    s = syn.make_patient(np.random.default_rng(1), "carevue", 300)
    for lbl in range(s.nf + 1):
        r = syn.relabel(s, lbl)
        assert (r.channels == r.nf).sum() == (s.channels == lbl).sum()


def test_lm_pipeline_deterministic():
    cfg = smoke_config("qwen3-0.6b")
    p = TokenPipeline(LMPipelineConfig(batch=2, seq_len=32,
                                       vocab_size=cfg.vocab_size), cfg)
    b1, b2 = p.batch_at(3), p.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].max() < cfg.vocab_size


def test_vlm_pipeline_shapes():
    cfg = smoke_config("qwen2-vl-7b")
    p = TokenPipeline(LMPipelineConfig(batch=2, seq_len=64,
                                       vocab_size=cfg.vocab_size,
                                       n_patches=16), cfg)
    b = p.batch_at(0)
    assert b["image_embeds"].shape[:2] == (2, 16)
    assert b["positions"].shape == (3, 2, 64)
    # patch grid positions: h/w vary within the image prefix, t constant
    assert b["positions"][0, 0, :16].max() == 0
    assert b["positions"][1, 0, :16].max() > 0


def test_audio_delay_pattern():
    cfg = smoke_config("musicgen-medium")
    p = TokenPipeline(LMPipelineConfig(batch=1, seq_len=32,
                                       vocab_size=cfg.vocab_size), cfg)
    b = p.batch_at(0)
    assert b["tokens"].shape == (1, cfg.n_codebooks, 32)
    # delay pattern: codebook k is right-shifted by k (zeros in front)
    for k in range(cfg.n_codebooks):
        assert (b["tokens"][0, k, :k] == 0).all()
