"""Bounded-staleness exchange cadence (``RoundSchedule.exchange_every=k``):
the batched/fused/mesh engines under a k-sub-round cadence must match a
sequential-oracle run with the SAME cadence — selections and per-client
round counts identical, validation histories to float precision — for
k ∈ {1, 2, 5}, on homogeneous AND cohort populations, and k=1 must stay
bit-identical to today's per-sub-round exchange (the default schedule).

The mesh-built runs fall back to the single-device path under plain
tier-1 (1 local device) and exercise genuine sharded cadence under the CI
mesh-parity step's forced 4-device host; the subprocess tests in
test_mesh_federation.py additionally pin an 8-device mesh.
"""
import numpy as np
import pytest

import jax

from repro.core import mesh_federation as MF
from repro.core.federation import Callback, Federation, RoundSchedule
from repro.core.hfl import FederatedClient, HFLConfig
from repro.core.policies import (AlphaBlend, AlwaysSwitch, ArgminSelection,
                                 FederationPolicies, MaxStaleness)

KS = (1, 2, 5)


def _mk_clients(cfg, C=8, nf=2, n=60, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(40), mk(40),
                                   jax.random.PRNGKey(i)))
    return out


def _mk_hetero(cfg, seed0=100):
    """Two cohorts (sizes 4 + 4 — divisible by the CI step's 4-device
    mesh): nf=2 with 3 sub-rounds and nf=3 with 4 sub-rounds per epoch."""
    out = []
    spec = [(2, 60)] * 4 + [(3, 80)] * 4
    for i, (nf, n) in enumerate(spec):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m, nf=nf: (
            rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
            rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
            rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(40), mk(40),
                                   jax.random.PRNGKey(i)))
    return out


def _assert_oracle_parity(h_seq, h_eng, *, exact_val=False):
    assert set(h_seq) == set(h_eng)
    for n in h_seq:
        assert h_seq[n]["selections"] == h_eng[n]["selections"]
        assert h_seq[n]["rounds"] == h_eng[n]["rounds"]
        if exact_val:
            np.testing.assert_array_equal(h_seq[n]["val"], h_eng[n]["val"])
        else:
            np.testing.assert_allclose(h_seq[n]["val"], h_eng[n]["val"],
                                       rtol=1e-6, atol=1e-7)


class _RoundCounter(Callback):
    def __init__(self):
        self.rounds = []

    def on_round(self, fed, epoch, rnd):
        self.rounds.append((epoch, rnd))


# ---------------------------------------------------------------------------
# RoundSchedule surface
# ---------------------------------------------------------------------------

def test_round_schedule_validates_cadence():
    with pytest.raises(ValueError, match="exchange_every"):
        RoundSchedule(2, 20, exchange_every=0)
    s = RoundSchedule(2, 20, exchange_every=2)
    np.testing.assert_array_equal(s.exchange_mask(5),
                                  [False, True, False, True, False])
    assert s.exchanges(5) == 2
    assert RoundSchedule(2, 20).exchange_every == 1          # the default
    assert RoundSchedule(2, 20).exchange_mask(3).all()


# ---------------------------------------------------------------------------
# Oracle parity, k ∈ {1, 2, 5}, homogeneous and cohort populations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("hetero", (False, True), ids=("homog", "cohort"))
def test_cadence_matches_sequential_oracle(k, hetero):
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=k)
    mk = _mk_hetero if hetero else _mk_clients
    fs = Federation(mk(cfg), cfg, engine="sequential", schedule=sched)
    h_seq = fs.fit()
    fb = Federation(mk(cfg), cfg, engine="batched", schedule=sched)
    h_bat = fb.fit()
    _assert_oracle_parity(h_seq, h_bat)
    for fed in (fs, fb):
        assert fed.dispatch_stats["exchange_every"] == k
    assert fb.dispatch_stats["exchange_rounds"] == \
        fs.dispatch_stats["exchange_rounds"]


@pytest.mark.parametrize("k", KS)
@pytest.mark.parametrize("hetero", (False, True), ids=("homog", "cohort"))
def test_cadence_on_mesh_matches_oracle(k, hetero):
    """The mesh engine under cadence vs the sequential oracle — genuine
    sharded execution when the host exposes >1 device (the CI mesh step),
    the single-device fallback otherwise; identical assertions either
    way."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=k)
    mk = _mk_hetero if hetero else _mk_clients
    h_seq = Federation(mk(cfg), cfg, engine="sequential",
                       schedule=sched).fit()
    fm = Federation(mk(cfg), cfg, engine="batched", schedule=sched,
                    mesh=MF.make_mesh())
    h_mesh = fm.fit()
    _assert_oracle_parity(h_seq, h_mesh)
    assert fm.dispatch_stats["exchange_every"] == k
    if MF.mesh_devices(fm._exec_mesh()) == 1:
        assert fm.dispatch_stats["pool_bytes_gathered"] == 0
    elif k == 1 and cfg.epochs > 0:
        assert fm.dispatch_stats["pool_bytes_gathered"] > 0


def test_k1_is_bit_identical_to_default_schedule():
    """exchange_every=1 must trace the historical flat scan: bit-identical
    validation histories and identical selections vs a run that never
    mentions the cadence."""
    cfg = HFLConfig(mode="hfl", epochs=4, R=20, patience=2)
    h_default = Federation(_mk_clients(cfg), cfg, engine="batched").fit()
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=1)
    h_k1 = Federation(_mk_clients(cfg), cfg, engine="batched",
                      schedule=sched).fit()
    _assert_oracle_parity(h_default, h_k1, exact_val=True)


def test_k1_bit_identical_on_cohorts():
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    h_default = Federation(_mk_hetero(cfg), cfg, engine="batched").fit()
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=1)
    h_k1 = Federation(_mk_hetero(cfg), cfg, engine="batched",
                      schedule=sched).fit()
    _assert_oracle_parity(h_default, h_k1, exact_val=True)


# ---------------------------------------------------------------------------
# MaxStaleness interplay: ages tick per EXCHANGE round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", (1, 2))
def test_cadence_rides_max_staleness(k):
    """The cadence's defining interaction: under a bounded pool, ages count
    exchange opportunities (not sub-rounds), so max_age keeps its meaning
    at every k.  Oracle parity pins it."""
    cfg = HFLConfig(mode="always", epochs=4, R=20)
    pol = FederationPolicies(switch=AlwaysSwitch(),
                             selection=ArgminSelection(),
                             transfer=AlphaBlend(alpha=cfg.alpha),
                             pool=MaxStaleness(max_age=1))
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=k)
    h_seq = Federation(_mk_clients(cfg, C=4), cfg, engine="sequential",
                       schedule=sched, policies=pol).fit()
    h_bat = Federation(_mk_clients(cfg, C=4), cfg, engine="batched",
                       schedule=sched, policies=pol).fit()
    _assert_oracle_parity(h_seq, h_bat)


# ---------------------------------------------------------------------------
# Chunked path, accounting, checkpointing
# ---------------------------------------------------------------------------

def test_chunked_path_applies_cadence():
    """Per-round callbacks force the chunked path; the cadence must gate
    each sub-round's dispatch identically (a non-exchange round is a
    do_federate=False dispatch) — same results as the fused run, every
    on_round still fired."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=2)
    h_fused = Federation(_mk_clients(cfg), cfg, engine="batched",
                         schedule=sched).fit()
    counter = _RoundCounter()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                     schedule=sched, callbacks=[counter])
    h_chunk = fed.fit()
    assert fed.dispatch_stats["path"] == "chunked"
    assert counter.rounds == [(e, r) for e in range(3) for r in range(3)]
    _assert_oracle_parity(h_fused, h_chunk)
    assert fed.dispatch_stats["exchange_rounds"] == 3   # 1 of 3 rounds/epoch


def test_exchange_accounting():
    """dispatch_stats arithmetic: n=60/R=20 gives 3 sub-rounds per epoch, so
    k=2 exchanges once per epoch, k=5 never; per-client round counts track
    exchange participations; a single-device run gathers zero bytes."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    for k, per_epoch in ((1, 3), (2, 1), (5, 0)):
        sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=k)
        fed = Federation(_mk_clients(cfg, C=4), cfg, engine="batched",
                         schedule=sched)
        h = fed.fit()
        assert fed.dispatch_stats["exchange_rounds"] == 3 * per_epoch
        assert fed.dispatch_stats["pool_bytes_gathered"] == 0
        for n in h:
            assert h[n]["rounds"] == 3 * per_epoch


def test_exchange_every_round_trips_through_checkpoint(tmp_path):
    cfg = HFLConfig(mode="always", epochs=4, R=20)
    sched = RoundSchedule(cfg.epochs, cfg.R, exchange_every=2)
    h_straight = Federation(_mk_clients(cfg, C=4), cfg, engine="batched",
                            schedule=sched).fit()
    fed = Federation(_mk_clients(cfg, C=4), cfg, engine="batched",
                     schedule=sched)
    fed.fit(epochs=2)
    fed.save(tmp_path / "ck")
    restored = Federation.restore(tmp_path / "ck", _mk_clients(cfg, C=4))
    assert restored.schedule.exchange_every == 2
    h_resumed = restored.fit()
    _assert_oracle_parity(h_straight, h_resumed, exact_val=True)
