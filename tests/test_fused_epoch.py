"""The fused-epoch batched engine: one compiled dispatch per epoch must be
selection-identical to the sequential oracle AND to its own chunked
(per-round-dispatch) fallback; `needs_per_round` callbacks still receive
every on_round; dispatch accounting pins the O(1)-dispatches-per-epoch
claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation import Callback, Federation
from repro.core.hfl import FederatedClient, HFLConfig


def _mk_clients(cfg, C=3, nf=2, n=40, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(40), mk(40),
                                   jax.random.PRNGKey(i)))
    return out


class _RoundCounter(Callback):
    """Overrides on_round -> auto-detected as needs_per_round."""

    def __init__(self):
        self.rounds = []

    def on_round(self, fed, epoch, rnd):
        self.rounds.append((epoch, rnd))


class _SilentRoundCounter(_RoundCounter):
    """Same override, but explicitly opts OUT of per-round delivery — the
    fused path stays engaged and on_round never fires."""

    needs_per_round = False


def _head_gap(c1, c2):
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree_util.tree_leaves(c1.params["heads"]),
                   jax.tree_util.tree_leaves(c2.params["heads"])))


# ---------------------------------------------------------------------------
# Parity: fused epoch vs chunked fallback vs the sequential oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ("always", "hfl"))
def test_fused_equals_chunked_on_batched_engine(mode):
    """The one-dispatch epoch scan and the per-round chunked scan are the
    same computation: identical selections, bit-close head params."""
    cfg = HFLConfig(mode=mode, epochs=4, R=20, patience=2)
    cs_fused = _mk_clients(cfg)
    cs_chunk = _mk_clients(cfg)
    fed_fused = Federation(cs_fused, cfg, engine="batched")
    h_fused = fed_fused.fit()
    counter = _RoundCounter()
    fed_chunk = Federation(cs_chunk, cfg, engine="batched",
                           callbacks=[counter])
    h_chunk = fed_chunk.fit()
    assert fed_fused.dispatch_stats["path"] == "fused"
    assert fed_chunk.dispatch_stats["path"] == "chunked"
    for name in h_fused:
        assert h_fused[name]["selections"] == h_chunk[name]["selections"]
        assert h_fused[name]["rounds"] == h_chunk[name]["rounds"]
        np.testing.assert_allclose(h_fused[name]["val"],
                                   h_chunk[name]["val"],
                                   rtol=1e-6, atol=1e-7)
    for c1, c2 in zip(cs_fused, cs_chunk):
        assert _head_gap(c1, c2) < 1e-6
    # 40 samples / R=20 -> 2 sub-rounds x 4 epochs of on_round events
    assert counter.rounds == [(e, r) for e in range(4) for r in range(2)]


def test_fused_epoch_matches_sequential_oracle():
    """Acceptance pin: the fused-epoch engine's selections are identical to
    the sequential oracle's (no callbacks -> the fused path is what runs)."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    cs_seq = _mk_clients(cfg, C=4, nf=3)
    cs_bat = _mk_clients(cfg, C=4, nf=3)
    h_seq = Federation(cs_seq, cfg, engine="sequential").fit()
    fed_bat = Federation(cs_bat, cfg, engine="batched")
    h_bat = fed_bat.fit()
    assert fed_bat.dispatch_stats["path"] == "fused"
    for name in h_seq:
        assert h_seq[name]["selections"] == h_bat[name]["selections"]
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"] > 0
        np.testing.assert_allclose(h_seq[name]["val"], h_bat[name]["val"],
                                   rtol=1e-5, atol=1e-6)
    for c1, c2 in zip(cs_seq, cs_bat):
        assert _head_gap(c1, c2) < 1e-5


# ---------------------------------------------------------------------------
# Callback routing: needs_per_round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("sequential", "batched"))
def test_needs_per_round_callbacks_receive_every_round(engine):
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    counter = _RoundCounter()
    Federation(_mk_clients(cfg), cfg, engine=engine,
               callbacks=[counter]).fit()
    assert counter.rounds == [(e, r) for e in range(3) for r in range(2)]


def test_explicit_opt_out_keeps_fused_path():
    """needs_per_round=False beats the on_round-override auto-detection:
    the fused path runs and the override never fires."""
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    silent = _SilentRoundCounter()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                     callbacks=[silent])
    fed.fit()
    assert fed.dispatch_stats["path"] == "fused"
    assert silent.rounds == []


def test_default_callbacks_do_not_break_fusion():
    """The built-in epoch-level callbacks (VerboseLogger / MetricsCapture /
    SaveBestCallback) must engage the fused path automatically."""
    from repro.core.federation import (MetricsCapture, SaveBestCallback,
                                       VerboseLogger)
    import tempfile

    cfg = HFLConfig(mode="always", epochs=2, R=20)
    with tempfile.TemporaryDirectory() as d:
        cbs = [VerboseLogger(), MetricsCapture(), SaveBestCallback(d)]
        fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                         callbacks=cbs)
        fed.fit()
    assert fed.dispatch_stats["path"] == "fused"
    assert len(cbs[1].epochs) == 2
    assert cbs[2].n_saves >= 1


# ---------------------------------------------------------------------------
# Dispatch accounting
# ---------------------------------------------------------------------------

def test_fused_path_is_one_dispatch_per_epoch():
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    fed = Federation(_mk_clients(cfg), cfg, engine="batched")
    fed.fit()
    st = fed.dispatch_stats
    assert st["path"] == "fused" and st["engine"] == "batched"
    assert st["epochs"] == 3 and st["dispatches"] == 3
    assert st["dispatches_per_epoch"] == 1.0


def test_chunked_path_is_one_dispatch_per_round():
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    fed = Federation(_mk_clients(cfg), cfg, engine="batched",
                     callbacks=[_RoundCounter()])
    fed.fit()
    st = fed.dispatch_stats
    # 40 samples / R=20 -> 2 sub-rounds per epoch
    assert st["path"] == "chunked" and st["dispatches_per_epoch"] == 2.0


def test_sequential_dispatch_stats_scale_with_clients():
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    fed = Federation(_mk_clients(cfg, C=3), cfg, engine="sequential")
    fed.fit()
    st = fed.dispatch_stats
    assert st["engine"] == "sequential" and st["path"] == "per-round"
    # per epoch: 3 clients x 2 train rounds + 3 x 2 x nf=2 scorings + 3 evals
    assert st["dispatches_per_epoch"] == 3 * 2 + 3 * 2 * 2 + 3


# ---------------------------------------------------------------------------
# Save/restore through the fused path stays bit-identical
# ---------------------------------------------------------------------------

def test_fused_save_restore_bit_identical(tmp_path):
    cfg = HFLConfig(mode="hfl", epochs=6, R=20, patience=2)
    h_straight = Federation(_mk_clients(cfg), cfg, engine="batched").fit()
    fed = Federation(_mk_clients(cfg), cfg, engine="batched")
    fed.fit(epochs=3)
    fed.save(tmp_path / "ck")
    h_resumed = Federation.restore(tmp_path / "ck", _mk_clients(cfg)).fit()
    for name in h_straight:
        assert h_straight[name]["val"] == h_resumed[name]["val"]
        assert h_straight[name]["selections"] == \
            h_resumed[name]["selections"]
        assert h_straight[name]["best_val"] == h_resumed[name]["best_val"]
