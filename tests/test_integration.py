"""End-to-end integration: training reduces loss; HFL transfers knowledge on
the two-hospital synthetic task (the paper's core claim, miniature)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.hfl import HFLConfig
from repro.core.experiment import run_task, train_hfl
from repro.data.lm_pipeline import LMPipelineConfig, TokenPipeline
from repro.launch import steps


def test_lm_training_reduces_loss():
    cfg = smoke_config("qwen3-0.6b")
    pipe = TokenPipeline(LMPipelineConfig(batch=8, seq_len=128,
                                          vocab_size=cfg.vocab_size), cfg)
    opt = steps.default_optimizer(1e-2)
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0))
    ts = jax.jit(steps.make_train_step(cfg, opt))
    losses = []
    for step in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_hfl_beats_no_transfer_on_small_target():
    """Paper's core claim, miniature: with a small target domain, HFL (with
    selection + switch) should not be worse than HFL-No (no transfer), and
    transfer rounds must actually fire."""
    cfg = HFLConfig(epochs=10, R=20, seed=0)
    res_hfl = train_hfl("metavision", 4, cfg, seed=0, n_patients=16,
                        n_events=150)
    res_no = train_hfl("metavision", 4,
                       dataclasses.replace(cfg, mode="no"),
                       seed=0, n_patients=16, n_events=150)
    assert res_no["rounds"] == 0
    # identical until the switch fires; afterwards HFL must stay competitive
    assert res_hfl["test"] <= res_no["test"] * 1.25


def test_hfl_always_fires_every_round():
    cfg = HFLConfig(epochs=2, R=20, mode="always", seed=0)
    res = train_hfl("metavision", 0, cfg, seed=0, n_patients=10, n_events=100)
    assert res["rounds"] > 0


def test_federated_llm_two_client_step():
    """make_hfl_train_step: two clients update independently (no gradient
    mixing) — divergent params stay divergent."""
    cfg = smoke_config("granite-3-2b")
    opt = steps.default_optimizer(1e-3)
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0), n_clients=2)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 2, 32), 0,
                                cfg.vocab_size)
    ts = jax.jit(steps.make_hfl_train_step(cfg, opt))
    state2, m = ts(state, {"tokens": tokens})
    assert m["loss"].shape == (2,)
    # per-client params must differ after updating on different batches
    w2 = state2["params"]["embed"]
    assert float(jnp.max(jnp.abs(w2[0] - w2[1]))) > 0
