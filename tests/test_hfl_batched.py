"""Batched multi-client engine vs the sequential reference oracle: identical
selections, bit-close blended heads, same switching behavior; plus
vmap-vs-Pallas parity for the fused multi-feature pool scoring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import networks as N
from repro.core.hfl import (FederatedClient, HFLConfig, pool_errors,
                            run_federated_training)
from repro.sharding import spec as S


def _mk_clients(cfg, C=4, nf=3, n=40, seed0=100):
    out = []
    for i in range(C):
        rng = np.random.default_rng(seed0 + i)
        mk = lambda m: (rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=(m, nf, cfg.w)).astype(np.float32),
                        rng.normal(size=m).astype(np.float32))
        out.append(FederatedClient(f"c{i}", nf, cfg, mk(n), mk(30), mk(30),
                                   jax.random.PRNGKey(i)))
    return out


def _head_gap(c1, c2):
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in
               zip(jax.tree_util.tree_leaves(c1.params["heads"]),
                   jax.tree_util.tree_leaves(c2.params["heads"])))


def test_batched_matches_sequential_always_mode():
    """4-client run, every round federated: same selected pool indices,
    head params within 1e-5 (the acceptance bar — in practice bit-equal)."""
    cfg = HFLConfig(mode="always", epochs=3, R=20)
    cs_seq = _mk_clients(cfg)
    cs_bat = _mk_clients(cfg)
    h_seq = run_federated_training(cs_seq, cfg, engine="sequential")
    h_bat = run_federated_training(cs_bat, cfg, engine="batched")
    for name in h_seq:
        assert h_seq[name]["selections"] == h_bat[name]["selections"]
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"] > 0
        np.testing.assert_allclose(h_seq[name]["val"], h_bat[name]["val"],
                                   rtol=1e-5, atol=1e-6)
    for c1, c2 in zip(cs_seq, cs_bat):
        assert _head_gap(c1, c2) < 1e-5


def test_batched_matches_sequential_switching():
    """hfl mode: the plateau-gated switching fires the same rounds on both
    engines (same val histories -> same fl_active schedule)."""
    cfg = HFLConfig(mode="hfl", epochs=8, R=20, patience=2)
    h_seq = run_federated_training(_mk_clients(cfg, C=3, nf=2), cfg,
                                   engine="sequential")
    h_bat = run_federated_training(_mk_clients(cfg, C=3, nf=2), cfg,
                                   engine="batched")
    rounds = [h_seq[n]["rounds"] for n in h_seq]
    assert any(r > 0 for r in rounds)     # the switch actually fired
    for name in h_seq:
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"]
        assert h_seq[name]["selections"] == h_bat[name]["selections"]


def test_batched_no_mode_never_federates():
    cfg = HFLConfig(mode="no", epochs=2, R=20)
    hist = run_federated_training(_mk_clients(cfg, C=2), cfg,
                                  engine="batched")
    for h in hist.values():
        assert h["rounds"] == 0 and h["selections"] == []


def test_batched_accepts_heterogeneous_clients():
    """Mixed-nf populations no longer error on the batched engine — they
    route through the cohort engine transparently and still match the
    sequential oracle's selections (the full parity surface is pinned by
    tests/test_cohorts.py)."""
    cfg = HFLConfig(mode="always", epochs=2, R=20)
    mk = lambda: (_mk_clients(cfg, C=2, nf=3) + _mk_clients(cfg, C=1, nf=2))
    cs_b, cs_s = mk(), mk()
    cs_b[2].name = cs_s[2].name = "c9"
    h_bat = run_federated_training(cs_b, cfg, engine="batched")
    h_seq = run_federated_training(cs_s, cfg, engine="sequential")
    for name in h_seq:
        assert h_seq[name]["selections"] == h_bat[name]["selections"]
        assert h_seq[name]["rounds"] == h_bat[name]["rounds"] > 0


def test_batched_kernel_path_matches_vmap_path():
    """use_pool_kernel=True routes the fused round through the Pallas pool
    sweep; selections and heads must match the vmap fallback."""
    cfg_v = HFLConfig(mode="always", epochs=2, R=20)
    cfg_k = dataclasses.replace(cfg_v, use_pool_kernel=True)
    cs_v = _mk_clients(cfg_v, C=3, nf=2)
    cs_k = _mk_clients(cfg_k, C=3, nf=2)
    h_v = run_federated_training(cs_v, cfg_v, engine="batched")
    h_k = run_federated_training(cs_k, cfg_k, engine="batched")
    for name in h_v:
        assert h_v[name]["selections"] == h_k[name]["selections"]
    for c1, c2 in zip(cs_v, cs_k):
        assert _head_gap(c1, c2) < 1e-5


def test_pool_errors_features_vmap_vs_pallas():
    """Multi-feature pool scoring: the Pallas sweep equals the vmap oracle."""
    from repro.kernels.pool_mlp.ops import pool_mlp_errors_features

    w, R, ns, nf = 3, 20, 6, 4
    heads = [S.materialize(N.head_schema(w), jax.random.PRNGKey(i))
             for i in range(ns)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *heads)
    xd = jax.random.normal(jax.random.PRNGKey(1), (nf, R, w))
    y = jax.random.normal(jax.random.PRNGKey(2), (R,))
    ref = jax.vmap(lambda xf: pool_errors(stacked, xf, y))(xd)
    out = pool_mlp_errors_features(stacked, xd, y, block_pool=4)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_population_runs_on_both_engines():
    from repro.core.experiment import train_population

    cfg = HFLConfig(mode="always", epochs=2, R=20)
    h_b = train_population(3, cfg, engine="batched", seed=1,
                           n_patients=8, n_events=150)
    h_s = train_population(3, cfg, engine="sequential", seed=1,
                           n_patients=8, n_events=150)
    assert set(h_b) == set(h_s) == {"h000", "h001", "h002"}
    for name in h_b:
        assert h_b[name]["selections"] == h_s[name]["selections"]
        assert np.isfinite(h_b[name]["test"])
