"""Unit + hypothesis property tests for the paper's §3 feature tensors."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests degrade to skip offline
from hypothesis import given, settings, strategies as st

from repro.core.feature_tensors import (EventStream, pack_feature_tensors,
                                        pack_feature_tensors_ref)


def make_stream(channels, values=None):
    channels = np.asarray(channels, np.int32)
    nf = int(channels.max())  # label = max channel id by construction here
    if values is None:
        values = np.arange(1.0, len(channels) + 1.0, dtype=np.float32)
    times = np.cumsum(np.ones(len(channels), np.float32))
    return EventStream(channels=channels, values=np.asarray(values, np.float32),
                       times=times, nf=nf)


def test_sparse_tensor_is_raw_window():
    # channels: f0 f1 f0 label  (nf=2)
    s = make_stream([0, 1, 0, 2], [10, 20, 30, 99])
    xs, xd, y = pack_feature_tensors(s, w=3)
    assert y.tolist() == [99.0]
    # window looks back from the label tick: ticks 2,1,0 -> f0=30, f1=20, f0=10
    assert xs[0, 0].tolist() == [30.0, 0.0, 10.0]
    assert xs[0, 1].tolist() == [0.0, 20.0, 0.0]


def test_dense_tensor_is_last_available():
    s = make_stream([0, 0, 0, 1, 2], [1, 2, 3, 7, 99])
    xs, xd, y = pack_feature_tensors(s, w=2)
    # dense: most recent w available values of each feature
    assert xd[0, 0].tolist() == [3.0, 2.0]
    assert xd[0, 1].tolist() == [7.0, 0.0]   # only one observation yet


def test_multiple_labels_accumulate_history():
    s = make_stream([0, 2, 0, 2], [5, 90, 6, 91])
    xs, xd, y = pack_feature_tensors(s, w=2)
    assert y.tolist() == [90.0, 91.0]
    assert xd[0, 0].tolist() == [5.0, 0.0]
    assert xd[1, 0].tolist() == [6.0, 5.0]


@settings(max_examples=60, deadline=None)
@given(
    nf=st.integers(1, 4),
    w=st.integers(1, 5),
    n=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_fast_packing_matches_oracle(nf, w, n, seed):
    rng = np.random.default_rng(seed)
    channels = rng.integers(0, nf + 1, size=n).astype(np.int32)
    values = rng.normal(size=n).astype(np.float32)
    times = np.cumsum(rng.exponential(size=n)).astype(np.float32)
    s = EventStream(channels=channels, values=values, times=times, nf=nf)
    xs1, xd1, y1 = pack_feature_tensors(s, w)
    xs2, xd2, y2 = pack_feature_tensors_ref(s, w)
    np.testing.assert_allclose(xs1, xs2)
    np.testing.assert_allclose(xd1, xd2)
    np.testing.assert_allclose(y1, y2)


@settings(max_examples=60, deadline=None)
@given(
    nf=st.integers(1, 4),
    w=st.integers(1, 5),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_packed_tensors_round_trip_stream_values(nf, w, n, seed):
    """Round-trip invariant: every value the packed tensors carry maps back
    to the EXACT stream event it came from, and every slot that should be
    empty is zero.  Values are drawn strictly positive so 0 unambiguously
    means "no observation" — the packing is then invertible:

      * y[k]           == the k-th label event's value;
      * xs[k, c, l]    == the value at tick (t_k - 1 - l) iff that tick
                          carried feature c, else 0 (the raw window);
      * xd[k, i, :]    == feature i's last-w observed values before t_k,
                          most-recent-first, zero-padded (the shift
                          register) — i.e. the stream's per-feature
                          observation suffix is recoverable from the row.
    """
    rng = np.random.default_rng(seed)
    channels = rng.integers(0, nf + 1, size=n).astype(np.int32)
    values = (1.0 + rng.random(size=n)).astype(np.float32)   # > 0 always
    times = np.cumsum(rng.exponential(size=n)).astype(np.float32)
    s = EventStream(channels=channels, values=values, times=times, nf=nf)
    xs, xd, y = pack_feature_tensors(s, w)
    label_ticks = np.nonzero(channels == nf)[0]
    assert len(y) == len(label_ticks)
    np.testing.assert_array_equal(y, values[label_ticks])
    for k, t in enumerate(label_ticks):
        # sparse: exact tick-by-tick inversion of the raw window
        for l in range(w):
            tick = t - 1 - l
            for c in range(nf):
                if tick >= 0 and channels[tick] == c:
                    assert xs[k, c, l] == values[tick]
                else:
                    assert xs[k, c, l] == 0.0
        # dense: the per-feature observation suffix, most-recent-first
        for i in range(nf):
            obs = values[(channels[:t] == i).nonzero()[0]]
            expect = list(obs[::-1][:w]) + [0.0] * (w - min(w, len(obs)))
            assert xd[k, i].tolist() == expect


@settings(max_examples=30, deadline=None)
@given(nf=st.integers(1, 3), w=st.integers(1, 4), seed=st.integers(0, 10**6))
def test_dense_rows_are_time_ordered_suffixes(nf, w, seed):
    """Property: each dense row at label k+1 extends/shifts the row at k."""
    rng = np.random.default_rng(seed)
    n = 40
    channels = rng.integers(0, nf + 1, size=n).astype(np.int32)
    values = rng.normal(size=n).astype(np.float32)
    s = EventStream(channels=channels, values=values,
                    times=np.arange(n, dtype=np.float32), nf=nf)
    xs, xd, y = pack_feature_tensors(s, w)
    # between consecutive labels, a feature's dense row either stays the same
    # (no new observation) or is shifted right by the new values
    for k in range(1, len(y)):
        for i in range(nf):
            prev, cur = xd[k - 1, i], xd[k, i]
            ok = np.array_equal(prev, cur)
            if not ok:
                # some shift amount 1..w must explain it
                ok = any(np.array_equal(cur[m:], prev[: w - m])
                         for m in range(1, w + 1))
            assert ok, (prev, cur)
