"""End-to-end driver (deliverable b): federated pre-training of a ~100M-param
qwen3-family model with the paper's HFL mechanism between 2 clients.

  PYTHONPATH=src python examples/federated_pretrain.py --steps 300

Each client trains on its OWN corpus (different seeds => different data
distributions).  Every R steps, if a client's validation loss has plateaued
(switching mechanism), the blend step runs: each client scores every
published shared subtree on its recent batch (Eq. 7) and alpha-blends the
winner (Eq. 8).  Only the shared subtree (attention + embeddings) moves —
routed experts / recurrence / projectors would stay local (DESIGN.md §4).

On real hardware this runs under the multi-pod mesh with clients on the
`pod` axis (see launch/dryrun.py); on CPU it runs the same code on 1 device.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import AttnConfig, LayerSpec, ModelConfig, Segment
from repro.core.hfl_llm import make_blend_step, shared_fraction
from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.lm_pipeline import LMPipelineConfig, TokenPipeline
from repro.launch import steps
from repro.sharding import spec as S


def model_100m() -> ModelConfig:
    """~100M-param qwen3-family config (12L x 768, vocab 32k)."""
    return ModelConfig(
        name="qwen3-100m", family="dense",
        vocab_size=32_000, d_model=768, d_ff=2304,
        segments=(Segment((LayerSpec("attn", "mlp"),), 12),),
        attn=AttnConfig(n_heads=12, n_kv_heads=4, head_dim=64,
                        rope_theta=1_000_000.0, qk_norm=True),
        act="silu", tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--R", type=int, default=25, help="federated period")
    ap.add_argument("--alpha", type=float, default=0.2)
    ap.add_argument("--patience", type=int, default=3)
    ap.add_argument("--lr", type=float, default=6e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_federated_ckpt")
    ap.add_argument("--tiny", action="store_true",
                    help="4L/256d model for CI-speed runs")
    args = ap.parse_args()

    cfg = model_100m()
    if args.tiny:
        cfg = dataclasses.replace(
            cfg, d_model=256, d_ff=768, vocab_size=2048,
            segments=(Segment((LayerSpec("attn", "mlp"),), 4),),
            attn=dataclasses.replace(cfg.attn, n_heads=4, n_kv_heads=2,
                                     head_dim=64))
    from repro.models.model import model_schema
    n_params = S.count_params(model_schema(cfg))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"shared fraction {shared_fraction(cfg):.2f}")

    C = 2
    opt = steps.default_optimizer(args.lr)
    state = steps.init_state(cfg, opt, jax.random.PRNGKey(0), n_clients=C)
    pipes = [TokenPipeline(LMPipelineConfig(batch=args.batch, seq_len=args.seq,
                                            vocab_size=cfg.vocab_size,
                                            seed=100 + c), cfg)
             for c in range(C)]
    val_batches = [
        {k: jnp.asarray(v) for k, v in pipes[c].batch_at(10_000).items()}
        for c in range(C)]

    train_step = jax.jit(steps.make_hfl_train_step(cfg, opt))
    blend_step = jax.jit(make_blend_step(cfg, alpha=args.alpha))

    from repro.models.model import lm_loss

    @jax.jit
    def val_loss_fn(params_stacked):
        def one(p, b):
            return lm_loss(p, cfg, b)[0]
        return jnp.stack([one(jax.tree_util.tree_map(lambda x: x[c],
                                                     params_stacked),
                              val_batches[c]) for c in range(C)])

    mgr = CheckpointManager(args.ckpt, keep=2)
    val_hist = [[] for _ in range(C)]
    best = [float("inf")] * C
    n_blends = 0
    t0 = time.time()
    recent = None
    for step in range(args.steps):
        batch = {
            k: jnp.stack([jnp.asarray(pipes[c].batch_at(step)[k])
                          for c in range(C)])
            for k in pipes[0].batch_at(step)}
        state, metrics = train_step(state, batch)
        recent = batch
        if (step + 1) % args.R == 0:
            vl = val_loss_fn(state["params"])
            plateaued = []
            for c in range(C):
                val_hist[c].append(float(vl[c]))
                h = val_hist[c]
                p = args.patience
                plat = (len(h) > p and
                        all(v >= min(h[:-p]) for v in h[-p:]))
                plateaued.append(plat)
                best[c] = min(best[c], float(vl[c]))
            if any(plateaued):     # switching mechanism
                state = dict(state)
                state["params"], losses = blend_step(state["params"], recent)
                n_blends += 1
                print(f"  [blend @ {step+1}] losses=\n{losses}")
            losses_s = " ".join(f"c{c}={float(vl[c]):.3f}" for c in range(C))
            print(f"step {step+1:4d}  train={[round(float(x),3) for x in metrics['loss']]} "
                  f"val: {losses_s}  ({(time.time()-t0)/(step+1):.2f}s/step)",
                  flush=True)
            mgr.save_best(float(jnp.mean(vl)), state["params"])
    mgr.save_step(args.steps, state)
    print(f"done: {args.steps} steps, {n_blends} federated blends, "
          f"best val {best}, wall {time.time()-t0:.0f}s, "
          f"ckpt -> {args.ckpt}")


if __name__ == "__main__":
    main()
