"""Quickstart: the paper's HFL system end-to-end on the simulated two-hospital
sparse clinical data (5 minutes on CPU).

  PYTHONPATH=src python examples/quickstart.py [--epochs 12]

Trains the target hospital (metavision, small) and the source hospital
(carevue, large) as decentralized federated clients: each packs dense/sparse
feature tensors (paper §3), trains the H/E/P network (Table 4), publishes
head weights to the asynchronous pool, and — whenever its validation loss
plateaus (the switching mechanism) — selects the best-matching heterogeneous
head by Eq. 7 and blends it in by Eq. 8.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses

from repro.core.experiment import train_hfl
from repro.core.hfl import HFLConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--label", type=int, default=4,
                    help="which channel to predict (0..4), paper: MF5")
    ap.add_argument("--patients", type=int, default=24)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    cfg = HFLConfig(epochs=args.epochs)
    print(f"== HFL (selection + switch), target=metavision MF{args.label+1} ==")
    res = train_hfl("metavision", args.label, cfg, n_patients=args.patients,
                    verbose=args.verbose)
    print(f"HFL      test MSE {res['test']:10.2f}  (federated rounds: "
          f"{res['rounds']})")

    res_no = train_hfl("metavision", args.label,
                       dataclasses.replace(cfg, mode="no"),
                       n_patients=args.patients)
    print(f"HFL-No   test MSE {res_no['test']:10.2f}  (no transfer)")
    delta = 100 * (1 - res["test"] / res_no["test"])
    print(f"=> heterogeneous transfer changed test MSE by {delta:+.1f}% "
          f"on the small target domain")
    if args.epochs < 30:
        print("   (note: below ~30 epochs the Table-4 heads are not yet "
              "load-bearing and transfer provably cannot move the final "
              "prediction — run with --epochs 50 for the paper protocol; "
              "see EXPERIMENTS.md §Repro 'Budget sensitivity')")


if __name__ == "__main__":
    main()
