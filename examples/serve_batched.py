"""Batched serving example: prefill + KV-cache decode with ring-buffered
sliding windows, for any assigned architecture.

  PYTHONPATH=src python examples/serve_batched.py --arch gemma2-9b --smoke
  PYTHONPATH=src python examples/serve_batched.py --arch xlstm-350m --smoke

Uses the reduced smoke config by default (full configs need the TPU pod —
see launch/dryrun.py for the production lowering of serve_step).
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.launch import steps
from repro.models import model as M
from repro.sharding import spec as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = S.materialize(M.model_schema(cfg), jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen
    cache_len = P + G

    key = jax.random.PRNGKey(1)
    if cfg.n_codebooks > 1:
        prompts = jax.random.randint(key, (B, cfg.n_codebooks, P), 0,
                                     cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    serve = jax.jit(steps.make_serve_step(cfg, cache_len))
    cache = M.init_cache(cfg, B, cache_len, jnp.bfloat16)

    # prefill by stepping the decode path (production uses the fused prefill
    # kernel path; this keeps the example simple and exercises the cache)
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = serve(params, cache, prompts[..., t:t + 1],
                              jnp.int32(t))
    t_prefill = time.time() - t0

    # batched sampling loop
    tokens = []
    cur = prompts[..., -1:]
    t0 = time.time()
    for t in range(P, P + G):
        logits, cache = serve(params, cache, cur, jnp.int32(t))
        key, sub = jax.random.split(key)
        flat = logits.astype(jnp.float32) / args.temperature
        nxt = jax.random.categorical(sub, flat, axis=-1)   # (B,1) / (B,1,K)
        if cfg.n_codebooks > 1:
            cur = nxt.swapaxes(1, 2)                        # (B,K,1)
        else:
            cur = nxt
        tokens.append(cur)
    t_gen = time.time() - t0
    out = jnp.concatenate(tokens, axis=-1)
    print(f"arch={cfg.name} batch={B} prompt={P} gen={G}")
    print(f"prefill: {t_prefill:.2f}s   decode: {t_gen:.2f}s "
          f"({B * G / t_gen:.1f} tok/s on CPU interpret path)")
    print("sampled token matrix shape:", out.shape)
    print("first sequence:", out[0].ravel()[:24].tolist())


if __name__ == "__main__":
    main()
