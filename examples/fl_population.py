"""N-hospital federated population on the composable Federation API.

  PYTHONPATH=src python examples/fl_population.py [--clients 16]

Generates `--clients` synthetic hospitals (each observing the shared latent
physiology through its own perturbed observation operator — see
repro.data.synthetic.population_spec), then trains them as one
:class:`repro.core.federation.Federation`.  The default policy bundle is the
paper's: plateau-gated switching, Eq.-7 argmin selection, Eq.-8
alpha-blending, last-write-wins pool asynchrony — every piece swappable from
the command line:

  --selection softmax --temperature 0.5     # softmax-weighted selection
  --selection topk --k 3                    # uniform over the 3 best heads
  --max-staleness 4                         # hide pool entries older than 4
  --switch-prob 0.5                         # Bernoulli per-epoch switching
  --exchange-every 2                        # pool exchange every 2 sub-rounds

``--population N`` switches to SAMPLED PARTICIPATION over a lazily
declared N-hospital population (`repro.core.participation`): only each
wave's sampled clients ever materialize or occupy the device, everyone
else lives in the host-side ClientStore, and the head pool carries
knowledge across waves.  ``--fraction`` sets the per-wave sample and
``--participation {uniform,weighted,stratified}`` picks the sampling
policy (stratified keeps each wave's cohort geometry identical, so wave
2+ reuses wave 1's compiled epoch).  ``--epochs`` then counts WAVES:

  --population 100000 --fraction 0.0003 --participation stratified

``--fault-rate`` / ``--byzantine-frac`` turn on DETERMINISTIC FAULT
INJECTION for --population runs (`repro.core.faults.FaultPlan`): each
wave drops clients with probability ``--fault-rate`` (the wave re-rounds
its geometry and continues) and poisons each survivor's published heads
with probability ``--byzantine-frac`` — the in-graph pool admission
guard quarantines the poisoned heads so they never reach a neighbour.
The summary line reports what was survived:

  --population 64 --fraction 0.25 --fault-rate 0.2 --byzantine-frac 0.1

With ``--engine batched`` (default) every Adam step is vmapped across
hospitals and each federated opportunity runs as ONE fused selection+blend
scan; ``--engine sequential`` runs the reference oracle instead — same
selections, ~an order of magnitude slower at this scale.  ``--mesh``
client-shards the batched engine over every local device (a 1-D
``clients`` mesh — see docs/SCALING.md; selections stay identical, and on
a 1-device host it falls back to the plain path).

``--hetero`` generates a MIXED-nf population (hospitals cycle through
``--nf-choices`` feature counts): the batched engine partitions it into
homogeneous cohorts automatically and exchanges heads through a padded
union pool (`repro.core.cohorts`) — still one fused dispatch per epoch,
still the oracle's selections.  The summary line reports the cohort
layout.

``--telemetry`` turns on the flight recorder
(`repro.core.telemetry.TelemetryPlan`): in-graph per-round series (still
one fused dispatch per epoch) plus host-side gather/dispatch/exchange/
scatter spans in a bounded ring buffer.  ``--trace-out run.json``
additionally exports the recording as Chrome-trace/Perfetto JSON
(open it at https://ui.perfetto.dev) with the counter registry snapshot
under a top-level ``metrics`` key:

  --population 64 --fraction 0.25 --telemetry --trace-out run.json

``--save-dir d`` checkpoints the full federation at the end (and ``--resume``
restarts from such a checkpoint and trains ``--epochs`` MORE epochs —
bit-identical to never having stopped).
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.experiment import (hetero_population_clients,
                                   population_clients)
from repro.core.federation import (Federation, MetricsCapture,
                                   RoundSchedule)
from repro.core.hfl import HFLConfig
from repro.core.policies import (FederationPolicies, MaxStaleness,
                                 ProbSwitch, SoftmaxSelection, TopKSelection)


def build_policies(args, cfg) -> FederationPolicies:
    pol = FederationPolicies.from_config(cfg)       # legacy-mode shorthand
    if args.selection == "softmax":
        pol = dataclasses.replace(
            pol, selection=SoftmaxSelection(args.temperature))
    elif args.selection == "topk":
        pol = dataclasses.replace(pol, selection=TopKSelection(args.k))
    if args.max_staleness is not None:
        pol = dataclasses.replace(pol, pool=MaxStaleness(args.max_staleness))
    if args.switch_prob is not None:
        pol = dataclasses.replace(pol, switch=ProbSwitch(args.switch_prob))
    return pol


def _policy_flags_customized(args) -> bool:
    return (args.selection != "mode" or args.mode != "hfl"
            or args.max_staleness is not None
            or args.switch_prob is not None)


_PARTICIPATIONS = {"uniform": "UniformParticipation",
                   "weighted": "WeightedParticipation",
                   "stratified": "StratifiedParticipation"}


def telemetry_plan(args):
    """--telemetry / --trace-out: the flight-recorder plan (or None)."""
    if not (args.telemetry or args.trace_out):
        return None
    from repro.core.telemetry import TelemetryPlan
    return TelemetryPlan()


def export_trace(fed, args):
    """Summarize the flight recording; export Perfetto JSON if asked."""
    rec = getattr(fed, "_recorder", None)
    if rec is None:
        return
    # one metrics payload: the recorder's counters plus every numeric
    # dispatch_stats entry the engines reported (canonical names)
    snap = dict(rec.snapshot())
    for k, v in (fed.dispatch_stats or {}).items():
        if isinstance(v, (int, float)) and k not in snap:
            snap[k] = v
    spans = sum(1 for e in rec.events if e["type"] == "span")
    rounds = sum(1 for e in rec.events if e["type"] == "round")
    print(f"=> telemetry: {spans} spans + {rounds} round records in the "
          f"ring ({len(rec.events)}/{rec.plan.ring_size}), counters: "
          + ", ".join(f"{k}={snap[k]}" for k in sorted(snap)
                      if isinstance(snap[k], int)))
    if args.trace_out:
        sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                               / "tools"))
        from trace_export import (assert_spans_nest, chrome_trace,
                                  validate_trace)
        trace = chrome_trace(rec.events, metrics=snap)
        validate_trace(trace)
        assert_spans_nest(trace["traceEvents"])
        Path(args.trace_out).write_text(json.dumps(trace))
        print(f"=> trace: {len(trace['traceEvents'])} events -> "
              f"{args.trace_out} (open at https://ui.perfetto.dev)")


def run_sampled(args, mesh):
    """--population N: sampled partial participation over a lazy population
    (repro.core.participation) — the resident working set is the WAVE, not
    the population."""
    from repro.core import participation as PT
    from repro.core.experiment import lazy_hetero_population

    cfg = HFLConfig(epochs=args.epochs, mode=args.mode, R=20)
    nf_choices = tuple(int(x) for x in args.nf_choices.split(","))
    pop = lazy_hetero_population(
        args.population, cfg, n_patients=args.patients,
        n_events=args.events, nf_choices=nf_choices,
        weighted_sizes=args.participation == "weighted")
    faults = None
    if args.fault_rate or args.byzantine_frac:
        from repro.core.faults import FaultPlan
        faults = FaultPlan(dropout=args.fault_rate,
                           byzantine=args.byzantine_frac,
                           corruption="nan")
    if args.resume:
        if not args.save_dir:
            raise SystemExit("--resume requires --save-dir")
        pf = PT.ParticipatingFederation.restore(args.save_dir, pop,
                                                mesh=mesh)
        print(f"== resumed {args.population}-hospital sampled federation "
              f"at wave {pf.wave} ==")
        t0 = time.time()
        pf.fit(waves=pf.wave + args.epochs, verbose=args.verbose)
    else:
        policy_cls = getattr(PT, _PARTICIPATIONS[args.participation])
        pf = PT.ParticipatingFederation(
            pop, cfg, policies=build_policies(args, cfg),
            participation=policy_cls(fraction=args.fraction, min_clients=2),
            schedule=RoundSchedule(args.epochs, cfg.R,
                                   exchange_every=args.exchange_every),
            mesh=mesh, faults=faults, telemetry=telemetry_plan(args))
        print(f"== {args.population}-hospital population, "
              f"{args.participation} participation "
              f"(fraction={args.fraction}), {args.epochs} waves =="
              + (f" [faults: dropout={args.fault_rate:g}, "
                 f"byzantine={args.byzantine_frac:g}]" if faults else ""))
        t0 = time.time()
        pf.fit(verbose=args.verbose)
    wall = time.time() - t0
    st = pf.dispatch_stats
    print(f"=> {st['waves']} waves x {st['resident_clients']} resident "
          f"clients of {st['population']:,} declared; device working set "
          f"{st['resident_state_bytes'] / 1e6:.1f}MB, store "
          f"{st['store_clients']} clients / {st['store_bytes'] / 1e6:.1f}MB "
          f"host-side, gathered {st['gather_bytes'] / 1e6:.1f}MB in "
          f"{wall:.1f}s")
    if st.get("clients_dropped") or st.get("heads_rejected") \
            or st.get("stragglers"):
        print(f"=> faults survived: {st['clients_dropped']} clients "
              f"dropped across {st['waves_degraded']} degraded waves, "
              f"{st['stragglers']} stragglers, {st['heads_rejected']} "
              f"poisoned heads quarantined at the pool gate")
    export_trace(pf, args)
    if args.save_dir:
        pf.save(args.save_dir)
        print(f"=> sampled federation checkpointed to {args.save_dir} "
              f"(restore with --resume)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--engine", choices=("batched", "sequential"),
                    default=None,
                    help="default: batched for fresh runs, the CHECKPOINTED "
                         "engine for --resume")
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--patients", type=int, default=10)
    ap.add_argument("--events", type=int, default=300)
    ap.add_argument("--mode", default="hfl",
                    choices=("hfl", "no", "random", "always"))
    ap.add_argument("--selection", default="mode",
                    choices=("mode", "softmax", "topk"),
                    help="override the mode's selection policy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="hide pool entries unrefreshed for this many rounds")
    ap.add_argument("--switch-prob", type=float, default=None,
                    help="Bernoulli(p) per-epoch switching policy "
                         "(ProbSwitch; previously spelled --participation)")
    ap.add_argument("--population", type=int, default=None,
                    help="declare this many hospitals LAZILY and train by "
                         "sampled participation (repro.core.participation) "
                         "— --epochs counts waves; see --fraction / "
                         "--participation")
    ap.add_argument("--fraction", type=float, default=0.1,
                    help="participation fraction per wave (--population)")
    ap.add_argument("--participation", default="stratified",
                    choices=sorted(_PARTICIPATIONS),
                    help="wave sampling policy for --population runs")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="per-wave client dropout probability "
                         "(repro.core.faults.FaultPlan; --population only)")
    ap.add_argument("--byzantine-frac", type=float, default=0.0,
                    help="per-wave probability a sampled client publishes "
                         "poisoned (NaN) heads — the pool admission guard "
                         "quarantines them (--population only)")
    ap.add_argument("--mesh", action="store_true",
                    help="client-shard the batched engine over all local "
                         "devices (docs/SCALING.md; falls back to the "
                         "single-device path on 1 device)")
    ap.add_argument("--hetero", action="store_true",
                    help="generate a MIXED-nf population (feature counts "
                         "cycling --nf-choices): the batched engine "
                         "cohort-plans it automatically (repro.core."
                         "cohorts), the sequential oracle loops it")
    ap.add_argument("--nf-choices", default="3,4,5",
                    help="comma-separated feature counts cycled across "
                         "hospitals under --hetero")
    ap.add_argument("--exchange-every", type=int, default=1,
                    help="bounded-staleness cadence: run the pool exchange "
                         "only on every k-th sub-round (docs/SCALING.md)")
    ap.add_argument("--telemetry", action="store_true",
                    help="flight-recorder telemetry (repro.core.telemetry): "
                         "in-graph per-round series + host-side spans")
    ap.add_argument("--trace-out", default=None,
                    help="export the flight recording as Chrome-trace/"
                         "Perfetto JSON here (implies --telemetry)")
    ap.add_argument("--save-dir", default=None,
                    help="checkpoint the federation here after training")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --save-dir, train --epochs more")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    mesh = None
    if args.mesh:
        from repro.core.mesh_federation import make_mesh
        mesh = make_mesh()
    if args.population:
        run_sampled(args, mesh)
        return
    cfg = HFLConfig(epochs=args.epochs, mode=args.mode, R=20)
    if args.hetero:
        nf_choices = tuple(int(x) for x in args.nf_choices.split(","))
        clients, packs = hetero_population_clients(
            args.clients, cfg, n_patients=args.patients,
            n_events=args.events, nf_choices=nf_choices)
    else:
        clients, packs = population_clients(args.clients, cfg,
                                            n_patients=args.patients,
                                            n_events=args.events)
    scale = {p["name"]: p["label_var"] for p in packs}  # raw-unit MSEs
    metrics = MetricsCapture()
    if args.resume:
        if not args.save_dir:
            raise SystemExit("--resume requires --save-dir")
        if _policy_flags_customized(args):
            print("note: --resume continues with the CHECKPOINTED policy "
                  "bundle; --mode/--selection/--max-staleness/"
                  "--switch-prob are ignored", file=sys.stderr)
        fed = Federation.restore(args.save_dir, clients,
                                 engine=args.engine, callbacks=[metrics],
                                 mesh=mesh)
        print(f"== resumed {args.clients}-hospital federation at epoch "
              f"{fed.epoch}, engine={fed.engine} ==")
        rounds0 = sum(fed.n_rounds.values())
        t0 = time.time()
        hist = fed.fit(epochs=args.epochs, verbose=args.verbose)
    else:
        sched = RoundSchedule(cfg.epochs, cfg.R,
                              exchange_every=args.exchange_every)
        fed = Federation(clients, cfg, policies=build_policies(args, cfg),
                         schedule=sched, engine=args.engine or "batched",
                         callbacks=[metrics], mesh=mesh,
                         telemetry=telemetry_plan(args))
        print(f"== {args.clients}-hospital population, engine={fed.engine}, "
              f"mode={args.mode}, selection={args.selection}"
              + (f", mesh={mesh.devices.size}dev" if mesh is not None
                 else "") + " ==")
        rounds0 = 0
        t0 = time.time()
        hist = fed.fit(verbose=args.verbose)
    wall = time.time() - t0

    tests = sorted((h["test"] * scale[name], name, h["rounds"])
                   for name, h in hist.items())
    total_rounds = sum(h["rounds"] for h in hist.values())
    new_rounds = total_rounds - rounds0      # rounds run in THIS segment
    print(f"{'hospital':>10} {'test MSE':>12} {'fed rounds':>10}")
    for mse, name, rounds in tests[:5]:
        print(f"{name:>10} {mse:12.2f} {rounds:10d}")
    if len(tests) > 5:
        print(f"{'...':>10} ({len(tests) - 5} more hospitals)")
    st = fed.dispatch_stats or {}
    cohort_note = ""
    if st.get("cohorts", 1) > 1:
        sizes = [pc["clients"] for pc in st.get("per_cohort", [])]
        cohort_note = (f", {st['cohorts']} cohorts {sizes} "
                       f"@ {st['dispatches_per_epoch']:.0f} dispatch/epoch")
    print(f"=> {new_rounds} federated rounds ({total_rounds} cumulative) "
          f"across {args.clients} hospitals, {len(metrics.epochs)} epochs "
          f"captured, in {wall:.1f}s "
          f"({max(new_rounds, 1) / wall:.1f} client-rounds/s){cohort_note}")
    export_trace(fed, args)
    if args.save_dir:
        fed.save(args.save_dir)
        print(f"=> federation checkpointed to {args.save_dir} "
              f"(restore with --resume)")


if __name__ == "__main__":
    main()
