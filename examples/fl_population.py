"""N-hospital federated population on the batched engine.

  PYTHONPATH=src python examples/fl_population.py [--clients 16]

Generates `--clients` synthetic hospitals (each observing the shared latent
physiology through its own perturbed observation operator — see
repro.data.synthetic.population_spec), then trains them as one federated
population with the batched multi-client engine: every Adam step is vmapped
across hospitals and each federated opportunity runs as ONE fused
selection+blend scan (Eq. 7 argmin + Eq. 8 blending for all clients and
features, no host sync).  `--engine sequential` runs the reference oracle
instead — same selections, ~an order of magnitude slower at this scale.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.experiment import train_population
from repro.core.hfl import HFLConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--engine", choices=("batched", "sequential"),
                    default="batched")
    ap.add_argument("--epochs", type=int, default=12)
    ap.add_argument("--patients", type=int, default=10)
    ap.add_argument("--events", type=int, default=300)
    ap.add_argument("--mode", default="hfl",
                    choices=("hfl", "no", "random", "always"))
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    cfg = HFLConfig(epochs=args.epochs, mode=args.mode, R=20)
    print(f"== {args.clients}-hospital population, engine={args.engine}, "
          f"mode={args.mode} ==")
    t0 = time.time()
    hist = train_population(args.clients, cfg, engine=args.engine,
                            n_patients=args.patients, n_events=args.events,
                            verbose=args.verbose)
    wall = time.time() - t0
    tests = sorted((h["test"], name, h["rounds"]) for name, h in hist.items())
    total_rounds = sum(h["rounds"] for h in hist.values())
    print(f"{'hospital':>10} {'test MSE':>12} {'fed rounds':>10}")
    for mse, name, rounds in tests[:5]:
        print(f"{name:>10} {mse:12.2f} {rounds:10d}")
    if len(tests) > 5:
        print(f"{'...':>10} ({len(tests) - 5} more hospitals)")
    print(f"=> {total_rounds} federated rounds across {args.clients} "
          f"hospitals in {wall:.1f}s "
          f"({total_rounds / wall:.1f} client-rounds/s)")


if __name__ == "__main__":
    main()
